#!/usr/bin/env python3
"""Direction-aware perf-regression guard for the bench-smoke CI job.

Compares the `values` section of a fresh BENCH_<name>.json against the
committed baseline (artifacts/bench-baseline.json).

The original guard treated every metric as a throughput ("bigger is
better") and only warned when `current < baseline * (1 - tolerance)`.
That check is *inverted* for time-valued metrics: a `_secs` latency that
doubles sailed straight through, while a latency that *improved* enough
would have tripped the warning. This version resolves a direction per
metric and applies the floor/ceiling on the right side:

- explicit: `baseline["directions"][key]` is "higher" or "lower";
- suffix convention otherwise: keys ending in `_secs`, `_ms`, `_ns` or
  `_latency` are lower-is-better; keys ending in `_per_s`, `_mb_per_s`,
  `_gb_per_s`, `_gflops`, `_speedup` or `_ops` are higher-is-better;
- anything else defaults to higher-is-better with a note, so a typo'd
  key is visible in the log rather than silently guessed.

A higher-is-better metric regresses when
`current < baseline * (1 - tolerance)`; a lower-is-better metric when
`current > baseline * (1 + tolerance)`.

Enforcement: metrics listed in `baseline["enforce"]` (and actually
blessed, i.e. non-null) fail the job with exit 1 on regression. All
other regressions are GitHub `::warning::` annotations only — stable
metrics graduate into `enforce` once their blessed numbers prove quiet;
noisy ones stay warn-only. Null (unblessed) baselines and metrics
missing from the fresh run are skipped with a note, so the guard is a
no-op until numbers are blessed.

Usage:
    bench_guard.py <baseline.json> <fresh BENCH_*.json>
    bench_guard.py --self-test
"""

import json
import os
import sys
import tempfile

LOWER_SUFFIXES = ("_secs", "_ms", "_ns", "_latency")
HIGHER_SUFFIXES = ("_per_s", "_mb_per_s", "_gb_per_s", "_gflops", "_speedup", "_ops")


def direction_of(key: str, overrides: dict) -> str:
    """Resolve 'higher' or 'lower' (is better) for a metric key."""
    explicit = overrides.get(key)
    if explicit in ("higher", "lower"):
        return explicit
    if explicit is not None:
        print(f"::warning::bench guard: bad direction '{explicit}' for '{key}', "
              "expected 'higher' or 'lower'; using suffix convention")
    if key.endswith(LOWER_SUFFIXES):
        return "lower"
    if key.endswith(HIGHER_SUFFIXES):
        return "higher"
    print(f"note: no direction for '{key}' (no override, unknown suffix); "
          "assuming higher-is-better")
    return "higher"


def regressed(cur: float, base: float, tol: float, direction: str) -> bool:
    if direction == "lower":
        return cur > base * (1.0 + tol)
    return cur < base * (1.0 - tol)


def guard(baseline: dict, fresh: dict, fresh_path: str = "<fresh>") -> int:
    tol = float(baseline.get("tolerance", 0.5))
    overrides = baseline.get("directions") or {}
    enforce = set(baseline.get("enforce") or [])
    base_values = baseline.get("values", {})
    fresh_values = fresh.get("values", {})
    if fresh.get("quick"):
        print("note: fresh run is SLEC_BENCH_QUICK — numbers are smoke-grade")

    unblessed, warned, failed, ok = [], [], [], []
    for key, base in sorted(base_values.items()):
        if base is None:
            unblessed.append(key)
            continue
        cur = fresh_values.get(key)
        if cur is None:
            print(f"::warning::bench guard: metric '{key}' absent from {fresh_path}")
            continue
        direction = direction_of(key, overrides)
        if regressed(cur, base, tol, direction):
            bound = base * (1.0 - tol) if direction == "higher" else base * (1.0 + tol)
            side = "<" if direction == "higher" else ">"
            msg = (f"perf regression: {key} = {cur:.3g} {side} {bound:.3g} "
                   f"(baseline {base:.3g}, tolerance {tol:.0%}, {direction}-is-better)")
            if key in enforce:
                failed.append(key)
                print(f"::error::{msg}")
            else:
                warned.append(key)
                print(f"::warning::{msg}")
        else:
            ok.append(key)
            print(f"ok: {key} = {cur:.3g} (baseline {base:.3g}, {direction}-is-better)")

    if unblessed:
        print(f"unblessed (skipped): {', '.join(unblessed)}")
    print(f"bench guard: {len(ok)} ok, {len(warned)} warned, "
          f"{len(failed)} failed, {len(unblessed)} unblessed")
    return 1 if failed else 0


def self_test() -> int:
    """Pin the direction logic — run in CI before the real guard."""
    cases = [
        # (name, key, overrides, cur, base, expect_regressed)
        ("throughput drop trips", "encode_mb_per_s", {}, 40.0, 100.0, True),
        ("throughput ok", "encode_mb_per_s", {}, 95.0, 100.0, False),
        ("throughput gain never trips", "encode_mb_per_s", {}, 300.0, 100.0, False),
        # The inverted cases the old guard got wrong:
        ("latency doubling trips", "decode_secs", {}, 2.0, 0.9, True),
        ("latency ok", "decode_secs", {}, 1.0, 0.9, False),
        ("latency improvement never trips", "decode_secs", {}, 0.1, 0.9, False),
        ("speedup is higher-better", "encode_speedup", {}, 1.0, 4.0, True),
        ("gflops is higher-better", "gemm_1024_gflops", {}, 10.0, 100.0, True),
        ("override beats suffix", "weird_secs", {"weird_secs": "higher"}, 1.0, 10.0, True),
        ("override lower", "score", {"score": "lower"}, 100.0, 10.0, True),
        ("unknown suffix defaults higher", "mystery", {}, 1.0, 10.0, True),
    ]
    tol = 0.5
    bad = 0
    for name, key, overrides, cur, base, expect in cases:
        got = regressed(cur, base, tol, direction_of(key, overrides))
        status = "pass" if got == expect else "FAIL"
        if got != expect:
            bad += 1
        print(f"self-test {status}: {name} ({key}: {cur} vs {base})")
    # End-to-end: an enforced blessed regression must exit non-zero,
    # a warn-only one must not.
    baseline = {
        "tolerance": 0.5,
        "values": {"a_mb_per_s": 100.0, "b_secs": 1.0, "c_mb_per_s": None},
        "enforce": ["a_mb_per_s"],
    }
    fresh = {"values": {"a_mb_per_s": 10.0, "b_secs": 50.0}}
    if guard(baseline, fresh) != 1:
        print("self-test FAIL: enforced regression did not fail the guard")
        bad += 1
    baseline["enforce"] = []
    if guard(baseline, fresh) != 0:
        print("self-test FAIL: warn-only regression must not fail the guard")
        bad += 1
    # End-to-end through the `directions` override path: a `_secs` key
    # pinned "higher" (e.g. a budget-utilisation metric that happens to
    # carry the suffix) must regress on a *drop* when enforced — and the
    # identical drop must pass once the override is removed, since the
    # suffix convention then reads it as an improved latency.
    overridden = {
        "tolerance": 0.5,
        "values": {"budget_secs": 10.0},
        "directions": {"budget_secs": "higher"},
        "enforce": ["budget_secs"],
    }
    dropped = {"values": {"budget_secs": 1.0}}
    if guard(overridden, dropped) != 1:
        print("self-test FAIL: enforced 'higher' override must fail on a drop")
        bad += 1
    del overridden["directions"]
    if guard(overridden, dropped) != 0:
        print("self-test FAIL: without the override the suffix rules the drop fine")
        bad += 1
    # Missing / unparseable inputs produce per-file diagnostics, not
    # tracebacks: a missing fresh file only warns (the bench may not
    # have run), a missing baseline and any garbled file are errors.
    with tempfile.TemporaryDirectory() as tmp:
        gone = os.path.join(tmp, "gone.json")
        garbled = os.path.join(tmp, "garbled.json")
        with open(garbled, "w") as f:
            f.write("{not json")
        io_cases = [
            ("missing fresh file is a warning", gone, "fresh", 0),
            ("missing baseline is an error", gone, "baseline", 2),
            ("garbled fresh file is an error", garbled, "fresh", 1),
            ("garbled baseline is an error", garbled, "baseline", 2),
        ]
        for name, path, role, expect in io_cases:
            data, rc = load_json_file(path, role)
            status = "pass" if data is None and rc == expect else "FAIL"
            if status == "FAIL":
                bad += 1
            print(f"self-test {status}: {name} (rc {rc}, expected {expect})")
    print(f"self-test: {bad} failure(s)")
    return 1 if bad else 0


def load_json_file(path: str, role: str):
    """Load one JSON input with a per-file diagnostic instead of a traceback.

    Returns `(data, rc)`: `data` is None when the file is unusable, and
    `rc` is the exit code to propagate. A missing *fresh* file is a
    warning (the bench may simply not have run; rc 0). A missing
    baseline is a configuration error (rc 2), and an unparseable file of
    either role is an error naming the path and the parse position.
    """
    try:
        with open(path) as f:
            return json.load(f), 0
    except FileNotFoundError:
        if role == "baseline":
            print(f"::error::bench guard: baseline {path} is missing — "
                  "commit the blessed baseline or fix the path")
            return None, 2
        print(f"::warning::bench guard: {path} missing — bench did not run?")
        return None, 0
    except json.JSONDecodeError as e:
        print(f"::error::bench guard: {path} is not valid JSON "
              f"(line {e.lineno} col {e.colno}: {e.msg})")
        return None, 2 if role == "baseline" else 1


def main() -> int:
    if len(sys.argv) == 2 and sys.argv[1] == "--self-test":
        return self_test()
    if len(sys.argv) != 3:
        print(f"usage: {sys.argv[0]} <baseline.json> <bench.json> | --self-test",
              file=sys.stderr)
        return 2
    baseline_path, fresh_path = sys.argv[1], sys.argv[2]
    baseline, rc = load_json_file(baseline_path, "baseline")
    if baseline is None:
        return rc
    fresh, rc = load_json_file(fresh_path, "fresh")
    if fresh is None:
        return rc
    return guard(baseline, fresh, fresh_path)


if __name__ == "__main__":
    sys.exit(main())

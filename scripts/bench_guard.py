#!/usr/bin/env python3
"""Warn-only perf-regression guard for the bench-smoke CI job.

Compares the `values` section of a fresh BENCH_<name>.json against the
committed baseline (artifacts/bench-baseline.json). A metric regresses
when `current < baseline * (1 - tolerance)`; the tolerance is generous
because shared CI runners are noisy. Regressions are reported as GitHub
`::warning::` annotations and the exit code is always 0 — the guard
informs reviewers, it does not gate merges. Baseline entries that are
null (not yet blessed) or missing from the fresh run are skipped with a
note.

Usage: bench_guard.py <baseline.json> <fresh BENCH_*.json>
"""

import json
import sys


def main() -> int:
    if len(sys.argv) != 3:
        print(f"usage: {sys.argv[0]} <baseline.json> <bench.json>", file=sys.stderr)
        return 2
    baseline_path, fresh_path = sys.argv[1], sys.argv[2]
    with open(baseline_path) as f:
        baseline = json.load(f)
    try:
        with open(fresh_path) as f:
            fresh = json.load(f)
    except FileNotFoundError:
        print(f"::warning::bench guard: {fresh_path} missing — bench did not run?")
        return 0

    tol = float(baseline.get("tolerance", 0.5))
    base_values = baseline.get("values", {})
    fresh_values = fresh.get("values", {})
    if fresh.get("quick"):
        print("note: fresh run is SLEC_BENCH_QUICK — numbers are smoke-grade")

    unblessed, regressed, ok = [], [], []
    for key, base in sorted(base_values.items()):
        if base is None:
            unblessed.append(key)
            continue
        cur = fresh_values.get(key)
        if cur is None:
            print(f"::warning::bench guard: metric '{key}' absent from {fresh_path}")
            continue
        floor = base * (1.0 - tol)
        if cur < floor:
            regressed.append(key)
            print(
                f"::warning::perf regression: {key} = {cur:.3g} "
                f"< {floor:.3g} (baseline {base:.3g}, tolerance {tol:.0%})"
            )
        else:
            ok.append(key)
            print(f"ok: {key} = {cur:.3g} (baseline {base:.3g})")

    if unblessed:
        print(f"unblessed (skipped): {', '.join(unblessed)}")
    print(
        f"bench guard: {len(ok)} ok, {len(regressed)} regressed, "
        f"{len(unblessed)} unblessed"
    )
    return 0  # warn-only by design


if __name__ == "__main__":
    sys.exit(main())

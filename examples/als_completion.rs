//! Fig-12 application: ALS matrix completion (Algorithm 2) with coded
//! matmuls for the user/item steps — factorizes a synthetic ratings
//! matrix and reports the loss curve and per-iteration virtual times.
//!
//!     cargo run --release --example als_completion

use slec::apps::als::{als, synthetic_ratings, AlsConfig};
use slec::codes::Scheme;
use slec::util::rng::Pcg64;
use slec::util::stats::render_table;

fn main() -> anyhow::Result<()> {
    // BLAS-3 calibration (see EXPERIMENTS.md §fig12).
    let mut cfg = slec::config::Config::default();
    cfg.set("platform.flops_per_s", "6e9")?;
    let (env, _rt) = cfg.build_env()?;
    let mut rng = Pcg64::new(5);
    let ratings = synthetic_ratings(200, 200, &mut rng);

    let mut run = |label: &str, scheme: Scheme| -> anyhow::Result<Vec<(f64, f64)>> {
        let mut rng = Pcg64::new(17);
        let cfg = AlsConfig {
            factors: 20,
            iters: 7, // the paper's Fig-12 run length
            s_rows: 50,
            s_factors: 10,
            scheme,
            virtual_dims: Some((102_400, 102_400, 20_480)), // paper scale
            ..Default::default()
        };
        let res = als(&env, &ratings, &cfg, &mut rng)?;
        println!(
            "{label}: total {:.1}s over {} iterations",
            res.total_secs(),
            res.iterations.len()
        );
        Ok(res
            .iterations
            .iter()
            .map(|i| (i.virtual_secs, i.loss))
            .collect())
    };

    let coded = run("coded (local product)", Scheme::LocalProduct { l_a: 10, l_b: 10 })?;
    let spec = run("speculative", Scheme::Speculative { wait_frac: 0.9 })?;

    let mut rows = Vec::new();
    for i in 0..coded.len() {
        rows.push(vec![
            format!("{}", i + 1),
            format!("{:.1}", coded[i].0),
            format!("{:.1}", spec[i].0),
            format!("{:.4e}", coded[i].1),
        ]);
    }
    println!(
        "{}",
        render_table(
            &["iter", "coded (s)", "speculative (s)", "‖R−HW‖²_F"],
            &rows
        )
    );
    let ct: f64 = coded.iter().map(|x| x.0).sum();
    let st: f64 = spec.iter().map(|x| x.0).sum();
    println!(
        "savings {:.1}% (paper: 20%); loss fell {:.2e} → {:.2e}",
        (1.0 - ct / st) * 100.0,
        coded.first().unwrap().1,
        coded.last().unwrap().1
    );
    Ok(())
}

//! End-to-end driver (the EXPERIMENTS.md headline run): the full system —
//! PJRT artifacts (L1 Pallas kernels lowered through L2 JAX), the Rust
//! coordinator, the serverless platform simulator and the object store —
//! composed on a real workload: all five schemes multiplying matrices at
//! the paper's Fig-5 design point, with the paper's headline metric
//! (end-to-end latency; local product code ≥25% over speculative).
//!
//! Requires `make artifacts`. Run with:
//!
//!     cargo run --release --example end_to_end

use std::sync::Arc;

use slec::codes::Scheme;
use slec::coordinator::matmul::{run_matmul, Env, MatmulJob};
use slec::coordinator::REPORT_HEADERS;
use slec::linalg::Matrix;
use slec::runtime::{ComputeBackend, PjrtBackend, PjrtRuntime};
use slec::util::rng::Pcg64;
use slec::util::stats::render_table;

fn main() -> anyhow::Result<()> {
    // Layer 3 ← Layer 2/1: start the PJRT engine on the AOT artifacts.
    let dir = PjrtRuntime::default_dir();
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let rt = PjrtRuntime::start(&dir)?;
    let backend = Arc::new(PjrtBackend::new(rt.handle()));
    let backend_ref = Arc::clone(&backend);
    let env = Env::with_backend(backend);

    // Numeric shapes match the compiled artifact set (64×256 blocks), so
    // the hot path runs through the Pallas-lowered kernels.
    let mut rng = Pcg64::new(1);
    let a = Matrix::randn(1280, 256, &mut rng, 0.0, 1.0);
    let b = Matrix::randn(1280, 256, &mut rng, 0.0, 1.0);
    println!(
        "inputs: 1280×256 (20 row-blocks/side), virtual scale 20000² — backend: {}",
        env.backend.name()
    );

    let schemes = [
        ("local-product (paper)", Scheme::LocalProduct { l_a: 10, l_b: 10 }),
        ("speculative (baseline)", Scheme::Speculative { wait_frac: 0.79 }),
        ("uncoded", Scheme::Uncoded),
        ("product [16]", Scheme::Product { t_a: 2, t_b: 2 }),
        ("polynomial [18]", Scheme::Polynomial { redundancy: 0.21 }),
    ];
    let mut rows = Vec::new();
    let mut totals = std::collections::BTreeMap::new();
    for (label, scheme) in schemes {
        let job = MatmulJob {
            s_a: 20,
            s_b: 20,
            scheme,
            decode_workers: 5,
            verify: true,
            seed: 99,
            job_id: format!("e2e-{}", scheme.name()),
            virtual_dims: Some((20_000, 20_000, 20_000)),
            encode_workers: 0,
        };
        let (_, report) = run_matmul(&env, &a, &b, &job)?;
        totals.insert(scheme.name().to_string(), report.total_secs());
        let mut row = report.row();
        row[0] = label.to_string();
        if !report.numerics_ok {
            row[5] = "infeasible".into();
        }
        rows.push(row);
    }
    println!("{}", render_table(&REPORT_HEADERS, &rows));

    let lp = totals["local-product"];
    let sp = totals["speculative"];
    println!(
        "headline: local product code {:.1}s vs speculative {:.1}s → {:.1}% end-to-end savings (paper: ≥25%)",
        lp,
        sp,
        (1.0 - lp / sp) * 100.0
    );
    let (pjrt_ops, fallbacks) = backend_ref.counts();
    println!("compute ops through PJRT artifacts: {pjrt_ops}; host fallbacks: {fallbacks}");
    let stats = rt.handle().stats();
    println!(
        "PJRT engine: {} executions, {} compilations (cached), {} errors",
        stats.executions, stats.compiles, stats.errors
    );
    Ok(())
}

//! Quickstart: one straggler-resilient coded matrix multiplication.
//!
//! Runs `C = A·Bᵀ` through the full Fig-2 pipeline (parallel encode →
//! compute with earliest-decodable termination → parallel peeling decode)
//! on the simulated serverless platform, verifies the result against the
//! direct product, and prints the `T_enc / T_comp / T_dec` report.
//!
//!     cargo run --release --example quickstart

use slec::codes::Scheme;
use slec::coordinator::matmul::{run_matmul, Env, MatmulJob};
use slec::coordinator::REPORT_HEADERS;
use slec::linalg::Matrix;
use slec::util::rng::Pcg64;
use slec::util::stats::render_table;

fn main() -> anyhow::Result<()> {
    // Lab-scale inputs; the virtual clock simulates the paper's scale.
    let mut rng = Pcg64::new(7);
    let a = Matrix::randn(640, 256, &mut rng, 0.0, 1.0);
    let b = Matrix::randn(640, 256, &mut rng, 0.0, 1.0);

    let env = Env::host();
    let mut rows = Vec::new();
    for scheme in [
        Scheme::LocalProduct { l_a: 10, l_b: 10 }, // the paper's scheme
        Scheme::Speculative { wait_frac: 0.79 },   // the baseline it beats
    ] {
        let job = MatmulJob {
            s_a: 10,
            s_b: 10,
            scheme,
            decode_workers: 5,
            verify: true,
            seed: 42,
            job_id: format!("quickstart-{}", scheme.name()),
            virtual_dims: Some((20_000, 20_000, 20_000)), // paper-scale clock
            encode_workers: 0,
        };
        let (c, report) = run_matmul(&env, &a, &b, &job)?;
        assert!(c.is_finite());
        assert!(
            report.rel_err < 1e-4,
            "decode must reproduce A·Bᵀ exactly (rel_err = {})",
            report.rel_err
        );
        rows.push(report.row());
    }
    println!("{}", render_table(&REPORT_HEADERS, &rows));
    println!("The coded pipeline recovered every straggled block from parities —");
    println!("the output is bit-for-bit the uncoded product, but finished earlier.");
    Ok(())
}

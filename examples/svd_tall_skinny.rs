//! §IV-C application: tall-skinny SVD via two coded matmuls (`AᵀA`, then
//! `U = A·VΣ⁻¹`) with a local eigendecomposition between — reports the
//! phase breakdown and verifies the factorization.
//!
//!     cargo run --release --example svd_tall_skinny

use slec::apps::svd::{reconstruction_error, tall_skinny_svd, SvdConfig};
use slec::codes::Scheme;
use slec::linalg::Matrix;
use slec::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    // BLAS-3 calibration (see EXPERIMENTS.md §svd).
    let mut cfg = slec::config::Config::default();
    cfg.set("platform.flops_per_s", "6e9")?;
    let (env, _rt) = cfg.build_env()?;
    let mut rng = Pcg64::new(9);
    let a = Matrix::randn(600, 60, &mut rng, 0.0, 1.0);

    for (label, scheme) in [
        ("coded (local product)", Scheme::LocalProduct { l_a: 10, l_b: 10 }),
        ("speculative", Scheme::Speculative { wait_frac: 0.79 }),
    ] {
        let mut rng = Pcg64::new(27);
        let res = tall_skinny_svd(
            &env,
            &a,
            &SvdConfig {
                s_blocks: 20, // 400 computation workers (paper's setup)
                scheme,
                virtual_dims: Some((300_000, 30_000)), // paper scale
                ..Default::default()
            },
            &mut rng,
        )?;
        let err = reconstruction_error(&a, &res);
        println!(
            "{label}: gram {:.1}s + eigen {:.1}s + U {:.1}s = {:.1}s total; ‖A−UΣVᵀ‖/‖A‖ = {err:.2e}",
            res.gram_report.total_secs(),
            res.eigen_secs,
            res.u_report.total_secs(),
            res.total_secs()
        );
        println!(
            "  σ₁..σ₅ = {:?}",
            res.sigma[..5].iter().map(|s| (s * 100.0).round() / 100.0).collect::<Vec<_>>()
        );
        anyhow::ensure!(err < 1e-2, "SVD must reconstruct A");
    }
    println!("(paper: coded 270.9s vs speculative 368.75s → 26.5% reduction)");
    Ok(())
}

//! Fig-3 application: power iteration with coded matvec vs speculative
//! execution — prints per-iteration virtual times and the eigenvalue
//! trajectory (PageRank/PCA's inner loop).
//!
//!     cargo run --release --example power_iteration

use slec::apps::power_iteration::{planted_matrix, power_iteration};
use slec::codes::Scheme;
use slec::coordinator::Env;
use slec::util::rng::Pcg64;
use slec::util::stats::render_table;

fn main() -> anyhow::Result<()> {
    let env = Env::host();
    let mut rng = Pcg64::new(3);
    let a = planted_matrix(512, 80.0, &mut rng);
    let iters = 12;

    let mut rng1 = Pcg64::new(10);
    let coded = power_iteration(
        &env,
        &a,
        8, // 8 = 2 grids of 2×2 (2-D product code, §IV-A)
        Scheme::LocalProduct { l_a: 2, l_b: 2 },
        iters,
        &mut rng1,
    )?;
    let mut rng2 = Pcg64::new(11);
    let spec = power_iteration(
        &env,
        &a,
        8,
        Scheme::Speculative { wait_frac: 0.9 },
        iters,
        &mut rng2,
    )?;

    let mut rows = Vec::new();
    for i in 0..iters {
        rows.push(vec![
            format!("{}", i + 1),
            format!("{:.2}", coded.iteration_secs[i]),
            format!("{:.2}", spec.iteration_secs[i]),
            format!("{:.4}", coded.eigenvalues[i]),
        ]);
    }
    println!(
        "{}",
        render_table(&["iter", "coded (s)", "speculative (s)", "λ estimate"], &rows)
    );
    println!(
        "dominant eigenvalue: coded {:.4} vs speculative {:.4} (identical math — coding is transparent)",
        coded.eigenvalues.last().unwrap(),
        spec.eigenvalues.last().unwrap()
    );
    println!(
        "totals: coded {:.1}s (encode {:.1}s, amortized) vs speculative {:.1}s",
        coded.total_secs(),
        coded.encode_secs,
        spec.total_secs()
    );
    Ok(())
}

//! Straggler playground: explore the platform model and the theory
//! interactively — sweeps the straggle probability `p` and the code
//! parameter `L`, showing how end-to-end latency, Theorem-2 undecodability
//! and decode reads respond. The ablation companion to Figs 6 and 9.
//!
//!     cargo run --release --example straggler_playground

use slec::codes::{montecarlo, theory, Scheme};
use slec::coordinator::matmul::{run_matmul, Env, MatmulJob};
use slec::linalg::Matrix;
use slec::util::rng::Pcg64;
use slec::util::stats::render_table;

fn main() -> anyhow::Result<()> {
    // --- Sweep p: how fragile is each scheme as the platform degrades?
    println!("== end-to-end latency vs straggle probability (virtual 20000², 20 blocks/side) ==");
    let mut rng = Pcg64::new(2);
    let a = Matrix::randn(640, 128, &mut rng, 0.0, 1.0);
    let b = Matrix::randn(640, 128, &mut rng, 0.0, 1.0);
    let mut rows = Vec::new();
    for p in [0.0, 0.01, 0.02, 0.05, 0.10] {
        let mut cfg = slec::config::Config::default();
        cfg.set("platform.p", &p.to_string())?;
        let (env, _rt): (Env, _) = cfg.build_env()?;
        let mut cells = vec![format!("{p:.2}")];
        for scheme in [
            Scheme::LocalProduct { l_a: 10, l_b: 10 },
            Scheme::Speculative { wait_frac: 0.79 },
        ] {
            let mut total = 0.0;
            let trials = 3;
            for t in 0..trials {
                let job = MatmulJob {
                    s_a: 20,
                    s_b: 20,
                    scheme,
                    verify: false,
                    seed: 1000 + t,
                    job_id: format!("pg-{}-{p}-{t}", scheme.name()),
                    virtual_dims: Some((20_000, 20_000, 20_000)),
                    ..Default::default()
                };
                let (_, report) = run_matmul(&env, &a, &b, &job)?;
                total += report.total_secs();
            }
            cells.push(format!("{:.1}", total / trials as f64));
        }
        rows.push(cells);
    }
    println!(
        "{}",
        render_table(&["p", "local-product (s)", "speculative (s)"], &rows)
    );

    // --- Sweep L: redundancy vs undecodability (the Fig-9 trade-off).
    println!("== code parameter L: redundancy vs Pr(undecodable), p = 0.02 ==");
    let mut rows = Vec::new();
    for l in [2usize, 5, 10, 15, 20] {
        let red = slec::codes::layout::product_redundancy(l, l);
        let bound = theory::thm2_bound(l, l, 0.02);
        let mc = montecarlo::simulate(l, l, 0.02, 20_000, 5 + l as u64);
        rows.push(vec![
            format!("{l}"),
            format!("{:.0}%", red * 100.0),
            format!("{bound:.2e}"),
            format!("{:.2e}", mc.pr_undecodable),
            format!("{:.1}", mc.mean_reads()),
        ]);
    }
    println!(
        "{}",
        render_table(
            &["L", "redundancy", "Thm-2 bound", "MC Pr(undec.)", "mean decode reads"],
            &rows
        )
    );
    println!("sweet spot at L ≈ 10 (n = 121): low redundancy, negligible undecodability — the paper's choice.");
    Ok(())
}

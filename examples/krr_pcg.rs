//! Figs 10–11 application: Kernel Ridge Regression with preconditioned CG
//! (Algorithm 1), coded matvecs for steps 4 and 6 — trains a real kernel
//! classifier on a synthetic nonlinear task and reports residuals, test
//! error and per-iteration virtual times.
//!
//!     cargo run --release --example krr_pcg

use slec::apps::krr::{krr_pcg, synthetic_dataset, KrrConfig};
use slec::codes::Scheme;
use slec::coordinator::Env;
use slec::util::rng::Pcg64;
use slec::util::stats::render_table;

fn main() -> anyhow::Result<()> {
    let env = Env::host();
    let mut rng = Pcg64::new(21);
    let data = synthetic_dataset(512, 256, 10, &mut rng);

    let mut results = Vec::new();
    for (label, scheme) in [
        ("coded (2-D product)", Scheme::LocalProduct { l_a: 4, l_b: 4 }),
        ("speculative", Scheme::Speculative { wait_frac: 0.9 }),
    ] {
        let mut rng = Pcg64::new(33);
        let cfg = KrrConfig {
            s_blocks: 64,
            scheme,
            virtual_n: Some(32_000), // the paper's ADULT kernel scale
            ..Default::default()
        };
        let res = krr_pcg(&env, &data, &cfg, &mut rng)?;
        println!(
            "{label}: converged={} in {} iterations, test error {:.1}%, total {:.1}s (encode {:.1}s)",
            res.converged,
            res.iterations.len(),
            res.test_error * 100.0,
            res.total_secs(),
            res.encode_secs
        );
        results.push((label, res));
    }

    // Residual trajectory side by side (Algorithm 1's stopping rule).
    let iters = results.iter().map(|(_, r)| r.iterations.len()).max().unwrap();
    let mut rows = Vec::new();
    for i in 0..iters {
        let cell = |idx: usize| -> (String, String) {
            results[idx]
                .1
                .iterations
                .get(i)
                .map(|it| (format!("{:.1}", it.virtual_secs), format!("{:.1e}", it.residual)))
                .unwrap_or_default()
        };
        let (ct, cr) = cell(0);
        let (st, _) = cell(1);
        rows.push(vec![format!("{}", i + 1), ct, st, cr]);
    }
    println!(
        "{}",
        render_table(&["iter", "coded (s)", "spec (s)", "residual"], &rows)
    );
    let savings = 1.0 - results[0].1.total_secs() / results[1].1.total_secs();
    println!("savings: {:.1}% (paper Fig 10: 42.1%)", savings * 100.0);
    Ok(())
}

# Convenience targets. The Rust workspace is fully usable without make;
# `make artifacts` regenerates every machine-produced artifact the repo
# tracks: AOT HLO kernels (PJRT path), quick-mode bench JSON (the perf
# trajectory seeded by CI's bench-smoke job), and freshly blessed
# scenario / scheme-conformance goldens.

ARTIFACTS_DIR ?= artifacts

# Derived from the bench sources (same enumeration as CI's bench-smoke
# job), so a new bench binary is covered with no Makefile edit.
BENCHES := $(basename $(notdir $(wildcard rust/benches/bench_*.rs)))

.PHONY: all build test bench artifacts aot-artifacts bench-artifacts golden-artifacts clean-artifacts

all: build

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

artifacts: aot-artifacts bench-artifacts golden-artifacts

# AOT-lower the L1/L2 kernels to HLO-text artifacts + manifest.json.
# Needs a Python with JAX (the aot module imports `compile.model`, so run
# from python/). No-op for the default (HostBackend) build and tests.
aot-artifacts:
	cd python && python3 -m compile.aot --out-dir $(abspath $(ARTIFACTS_DIR))

# Quick-mode run of every bench binary, dropping BENCH_<name>.json into
# the artifacts dir (same pipeline as CI's bench-smoke job).
bench-artifacts:
	mkdir -p $(ARTIFACTS_DIR)
	@for b in $(BENCHES); do \
		echo "== $$b"; \
		SLEC_BENCH_QUICK=1 SLEC_BENCH_DIR=$(abspath $(ARTIFACTS_DIR)) \
			cargo bench --bench $$b || exit 1; \
	done

# Re-bless the scenario + scheme-conformance goldens in place (pins the
# timing fields that stay null until blessed on a machine with a
# toolchain); review the diff before committing.
golden-artifacts:
	SLEC_BLESS=1 cargo test --test scenarios_golden --test scheme_conformance -q

clean-artifacts:
	rm -rf $(ARTIFACTS_DIR)

# Convenience targets. The Rust workspace is fully usable without make;
# `artifacts` is only needed for the PJRT path (see README feature matrix).

ARTIFACTS_DIR ?= artifacts

.PHONY: all build test bench artifacts clean-artifacts

all: build

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

# AOT-lower the L1/L2 kernels to HLO-text artifacts + manifest.json.
# Needs a Python with JAX (the aot module imports `compile.model`, so run
# from python/). No-op for the default (HostBackend) build and tests.
artifacts:
	cd python && python3 -m compile.aot --out-dir $(abspath $(ARTIFACTS_DIR))

clean-artifacts:
	rm -rf $(ARTIFACTS_DIR)

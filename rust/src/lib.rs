//! # slec — Serverless Straggler Mitigation using Local Error-Correcting Codes
//!
//! A complete reproduction of Gupta et al., *"Serverless Straggler
//! Mitigation using Local Error-Correcting Codes"* (2020) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! - **Layer 1 (Pallas)**: tiled matmul / parity kernels, AOT-lowered,
//! - **Layer 2 (JAX)**: block-product compute graphs → HLO-text artifacts,
//! - **Layer 3 (this crate)**: the serverless coordinator — coded encode /
//!   compute / decode phases over a simulated serverless platform + object
//!   store, with local product codes, peeling decoding and all baselines.
//!
//! The default build is hermetic and offline: all numerics run on the
//! pure-Rust [`runtime::HostBackend`]. The PJRT path (layers 1–2 on the
//! hot path) is behind the `pjrt` cargo feature and needs `make
//! artifacts` first.
//!
//! See `DESIGN.md` (repo root) for the system inventory and
//! `EXPERIMENTS.md` for how each paper figure is regenerated.

// Style lints that dense numeric/index code trips by design: indexed
// loops mirror the paper's subscript notation, and the decode paths
// return structured tuples rather than one-off structs.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::type_complexity)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::if_same_then_else)]

pub mod apps;
pub mod codes;
pub mod config;
pub mod figures;
pub mod coordinator;
pub mod linalg;
pub mod platform;
pub mod runtime;
pub mod storage;
pub mod util;

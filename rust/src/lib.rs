//! # slec — Serverless Straggler Mitigation using Local Error-Correcting Codes
//!
//! A complete reproduction of Gupta et al., *"Serverless Straggler
//! Mitigation using Local Error-Correcting Codes"* (2020) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! - **Layer 1 (Pallas)**: tiled matmul / parity kernels, AOT-lowered,
//! - **Layer 2 (JAX)**: block-product compute graphs → HLO-text artifacts,
//! - **Layer 3 (this crate)**: the serverless coordinator — coded encode /
//!   compute / decode phases over a simulated serverless platform + object
//!   store, with local product codes, peeling decoding and all baselines.
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.
pub mod apps;
pub mod codes;
pub mod config;
pub mod figures;
pub mod coordinator;
pub mod linalg;
pub mod platform;
pub mod runtime;
pub mod storage;
pub mod util;

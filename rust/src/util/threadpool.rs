//! Fixed-size thread pool and parallel iteration helpers.
//!
//! `tokio`/`rayon` are unavailable offline; the coordinator's real-compute
//! path (PJRT block products, host GEMM) and the platform simulator's
//! worker execution run on this pool instead.
//!
//! Design: a simple shared-queue pool with scoped `parallel_for` built on
//! `std::thread::scope`, which lets closures borrow from the caller's stack
//! without `'static` bounds — the dominant use-case in the coordinator.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size pool executing `'static` jobs; results flow back over
/// channels owned by the submitter.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(size);
        for i in 0..size {
            let rx = Arc::clone(&rx);
            handles.push(
                thread::Builder::new()
                    .name(format!("slec-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn pool thread"),
            );
        }
        ThreadPool {
            tx: Some(tx),
            handles,
            size,
        }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a job; returns immediately.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("pool worker died");
    }

    /// Submit a job returning a value; the result is received via the
    /// returned handle.
    pub fn submit<T: Send + 'static>(
        &self,
        job: impl FnOnce() -> T + Send + 'static,
    ) -> JobHandle<T> {
        let (tx, rx) = mpsc::channel();
        self.execute(move || {
            let _ = tx.send(job());
        });
        JobHandle { rx }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Handle to a submitted job's result.
pub struct JobHandle<T> {
    rx: mpsc::Receiver<T>,
}

impl<T> JobHandle<T> {
    /// Block until the job finishes.
    pub fn join(self) -> T {
        self.rx.recv().expect("job panicked")
    }
}

/// Number of hardware threads (≥1).
pub fn num_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Run `f(i)` for every `i in 0..n` across up to `threads` scoped workers.
///
/// Work distribution is dynamic (atomic counter), so uneven task costs —
/// e.g. a straggling PJRT block product — don't idle the other workers.
pub fn parallel_for(threads: usize, n: usize, f: impl Fn(usize) + Sync) {
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Map `f` over `0..n` in parallel, collecting results in index order.
/// Like [`parallel_for`], degenerate fan-outs (one thread or ≤1 item)
/// run inline — no thread spawn, no mutex.
pub fn parallel_map<T: Send>(threads: usize, n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots = std::sync::Mutex::new(&mut out);
        // Use chunk-free dynamic scheduling; writes go through disjoint
        // indices so a striped approach is fine. We avoid unsafe by using a
        // per-index mutex-free trick: collect (i, T) pairs per thread.
        let counter = AtomicUsize::new(0);
        thread::scope(|s| {
            let mut handles = Vec::new();
            for _ in 0..threads {
                handles.push(s.spawn(|| {
                    let mut local: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = counter.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                }));
            }
            for h in handles {
                let local = h.join().expect("parallel_map worker panicked");
                let mut guard = slots.lock().unwrap();
                for (i, v) in local {
                    guard[i] = Some(v);
                }
            }
        });
    }
    out.into_iter().map(|o| o.expect("slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = (0..100)
            .map(|_| {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                    1u64
                })
            })
            .collect();
        let total: u64 = handles.into_iter().map(|h| h.join()).sum();
        assert_eq!(total, 100);
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_drop_joins() {
        let pool = ThreadPool::new(2);
        let h = pool.submit(|| 42);
        drop(pool);
        assert_eq!(h.join(), 42);
    }

    #[test]
    fn parallel_for_covers_all() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(8, 1000, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_for_zero_and_one() {
        parallel_for(4, 0, |_| panic!("should not run"));
        let hit = AtomicUsize::new(0);
        parallel_for(4, 1, |i| {
            assert_eq!(i, 0);
            hit.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn parallel_map_order() {
        let v = parallel_map(6, 257, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn parallel_map_uneven_work() {
        // Tasks with wildly different costs still land in the right slots.
        let v = parallel_map(4, 64, |i| {
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i
        });
        assert_eq!(v, (0..64).collect::<Vec<_>>());
    }
}

//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Each `cargo bench` target is a `harness = false` binary that uses this
//! module: warmup, timed iterations, robust stats, and an aligned report.
//! Figures-style end-to-end benches also use `run_once` for single-shot
//! wall-clock + simulated-time reporting.
//!
//! Two environment variables drive the CI perf-artifact pipeline:
//! - `SLEC_BENCH_QUICK=1` shrinks every [`Bencher`]'s warmup/iteration
//!   budget so the whole bench set finishes in CI time.
//! - `SLEC_BENCH_DIR=<dir>` makes [`BenchReport::write`] drop a
//!   machine-readable `BENCH_<name>.json` per bench binary — the files
//!   the `bench-smoke` CI job uploads as the perf trajectory.

use std::time::{Duration, Instant};

use crate::util::json::{obj, Json};
use crate::util::stats::Summary;

/// Result of a timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time in seconds.
    pub summary: Summary,
    pub iters: usize,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} iters={:<4} mean={:>12} p50={:>12} p99={:>12}",
            self.name,
            self.iters,
            fmt_duration(self.summary.mean),
            fmt_duration(self.summary.p50),
            fmt_duration(self.summary.p99),
        )
    }
}

/// Format a duration given in seconds with adaptive units.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

/// Benchmark runner: fixed warmup iterations, then timed iterations until
/// either `max_iters` or `max_total` wall time is reached (≥ min_iters).
pub struct Bencher {
    pub warmup: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub max_total: Duration,
}

/// Is the quick/CI mode requested? (`SLEC_BENCH_QUICK=1`.)
pub fn quick_mode() -> bool {
    std::env::var_os("SLEC_BENCH_QUICK").is_some_and(|v| !v.is_empty() && v != "0")
}

impl Default for Bencher {
    fn default() -> Self {
        if quick_mode() {
            return Bencher {
                warmup: 0,
                min_iters: 2,
                max_iters: 3,
                max_total: Duration::from_secs(2),
            };
        }
        Bencher {
            warmup: 2,
            min_iters: 5,
            max_iters: 50,
            max_total: Duration::from_secs(10),
        }
    }
}

impl Bencher {
    /// Fast settings for heavyweight end-to-end benches.
    pub fn end_to_end() -> Self {
        if quick_mode() {
            return Bencher {
                warmup: 0,
                min_iters: 1,
                max_iters: 2,
                max_total: Duration::from_secs(5),
            };
        }
        Bencher {
            warmup: 1,
            min_iters: 3,
            max_iters: 10,
            max_total: Duration::from_secs(30),
        }
    }

    /// Time `f`, returning per-iteration statistics.
    pub fn bench<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup {
            let _ = black_box(f());
        }
        let mut times = Vec::new();
        let start = Instant::now();
        while times.len() < self.max_iters
            && (times.len() < self.min_iters || start.elapsed() < self.max_total)
        {
            let t0 = Instant::now();
            let _ = black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        BenchResult {
            name: name.to_string(),
            summary: Summary::of(&times),
            iters: times.len(),
        }
    }
}

/// Run once and report wall time alongside the value.
pub fn run_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    let dt = t0.elapsed().as_secs_f64();
    eprintln!("[bench] {name}: {}", fmt_duration(dt));
    (v, dt)
}

/// Machine-readable bench report: collects [`BenchResult`]s plus named
/// scalar values (savings %, GFLOP/s, …) and, when `SLEC_BENCH_DIR` is
/// set, writes them as `<dir>/BENCH_<name>.json` — the perf-trajectory
/// artifact CI uploads per bench binary.
pub struct BenchReport {
    name: String,
    results: Vec<Json>,
    values: Vec<(String, f64)>,
}

impl BenchReport {
    pub fn new(name: &str) -> BenchReport {
        BenchReport {
            name: name.to_string(),
            results: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Record a timed result (keeps the human-readable line printing at
    /// the call site).
    pub fn push(&mut self, r: &BenchResult) {
        self.results.push(
            obj()
                .field("name", r.name.as_str())
                .field("iters", r.iters)
                .field("mean_s", r.summary.mean)
                .field("p50_s", r.summary.p50)
                .field("p99_s", r.summary.p99)
                .build(),
        );
    }

    /// Record a named scalar (figure outputs, derived throughputs).
    pub fn value(&mut self, key: &str, v: f64) {
        self.values.push((key.to_string(), v));
    }

    pub fn to_json(&self) -> Json {
        let mut values = obj();
        for (k, v) in &self.values {
            values = values.field(k, *v);
        }
        obj()
            .field("bench", self.name.as_str())
            .field("quick", quick_mode())
            .field("results", Json::Arr(self.results.clone()))
            .field("values", values.build())
            .build()
    }

    /// Write `BENCH_<name>.json` under `$SLEC_BENCH_DIR`; no-op (returns
    /// `None`) when the variable is unset. I/O failures panic: in CI a
    /// missing artifact must fail the job, not vanish silently.
    pub fn write(&self) -> Option<std::path::PathBuf> {
        let dir = std::path::PathBuf::from(std::env::var_os("SLEC_BENCH_DIR")?);
        std::fs::create_dir_all(&dir).expect("create SLEC_BENCH_DIR");
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json().to_string_pretty()).expect("write bench report");
        println!("[bench] wrote {}", path.display());
        Some(path)
    }
}

/// Identity function that defeats the optimizer (std::hint::black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Pretty header for a bench binary.
pub fn banner(title: &str) {
    println!("{}", "=".repeat(78));
    println!("{title}");
    println!("{}", "=".repeat(78));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bencher {
            warmup: 1,
            min_iters: 3,
            max_iters: 5,
            max_total: Duration::from_secs(1),
        };
        let r = b.bench("noop", || 1 + 1);
        assert!(r.iters >= 3 && r.iters <= 5);
        assert!(r.summary.mean >= 0.0);
        assert!(r.line().contains("noop"));
    }

    #[test]
    fn bench_respects_time_budget() {
        let b = Bencher {
            warmup: 0,
            min_iters: 2,
            max_iters: 1000,
            max_total: Duration::from_millis(50),
        };
        let r = b.bench("sleepy", || std::thread::sleep(Duration::from_millis(10)));
        assert!(r.iters < 1000);
    }

    #[test]
    fn report_serializes_results_and_values() {
        let b = Bencher {
            warmup: 0,
            min_iters: 2,
            max_iters: 2,
            max_total: Duration::from_secs(1),
        };
        let mut report = BenchReport::new("unit");
        let r = b.bench("noop", || 1 + 1);
        report.push(&r);
        report.value("speedup", 2.5);
        let j = report.to_json();
        assert_eq!(j.get("bench").unwrap().as_str(), Some("unit"));
        let results = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("iters").unwrap().as_usize(), Some(2));
        assert!(results[0].get("p50_s").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(
            j.get("values").unwrap().get("speedup").unwrap().as_f64(),
            Some(2.5)
        );
        // Without SLEC_BENCH_DIR nothing is written.
        if std::env::var_os("SLEC_BENCH_DIR").is_none() {
            assert!(report.write().is_none());
        }
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(2e-9).ends_with("ns"));
        assert!(fmt_duration(2e-6).ends_with("us"));
        assert!(fmt_duration(2e-3).ends_with("ms"));
        assert!(fmt_duration(2.0).ends_with('s'));
    }
}

//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Each `cargo bench` target is a `harness = false` binary that uses this
//! module: warmup, timed iterations, robust stats, and an aligned report.
//! Figures-style end-to-end benches also use `run_once` for single-shot
//! wall-clock + simulated-time reporting.

use std::time::{Duration, Instant};

use crate::util::stats::Summary;

/// Result of a timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time in seconds.
    pub summary: Summary,
    pub iters: usize,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<44} iters={:<4} mean={:>12} p50={:>12} p99={:>12}",
            self.name,
            self.iters,
            fmt_duration(self.summary.mean),
            fmt_duration(self.summary.p50),
            fmt_duration(self.summary.p99),
        )
    }
}

/// Format a duration given in seconds with adaptive units.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

/// Benchmark runner: fixed warmup iterations, then timed iterations until
/// either `max_iters` or `max_total` wall time is reached (≥ min_iters).
pub struct Bencher {
    pub warmup: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub max_total: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: 2,
            min_iters: 5,
            max_iters: 50,
            max_total: Duration::from_secs(10),
        }
    }
}

impl Bencher {
    /// Fast settings for heavyweight end-to-end benches.
    pub fn end_to_end() -> Self {
        Bencher {
            warmup: 1,
            min_iters: 3,
            max_iters: 10,
            max_total: Duration::from_secs(30),
        }
    }

    /// Time `f`, returning per-iteration statistics.
    pub fn bench<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup {
            let _ = black_box(f());
        }
        let mut times = Vec::new();
        let start = Instant::now();
        while times.len() < self.max_iters
            && (times.len() < self.min_iters || start.elapsed() < self.max_total)
        {
            let t0 = Instant::now();
            let _ = black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        BenchResult {
            name: name.to_string(),
            summary: Summary::of(&times),
            iters: times.len(),
        }
    }
}

/// Run once and report wall time alongside the value.
pub fn run_once<T>(name: &str, f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let v = f();
    let dt = t0.elapsed().as_secs_f64();
    eprintln!("[bench] {name}: {}", fmt_duration(dt));
    (v, dt)
}

/// Identity function that defeats the optimizer (std::hint::black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Pretty header for a bench binary.
pub fn banner(title: &str) {
    println!("{}", "=".repeat(78));
    println!("{title}");
    println!("{}", "=".repeat(78));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bencher {
            warmup: 1,
            min_iters: 3,
            max_iters: 5,
            max_total: Duration::from_secs(1),
        };
        let r = b.bench("noop", || 1 + 1);
        assert!(r.iters >= 3 && r.iters <= 5);
        assert!(r.summary.mean >= 0.0);
        assert!(r.line().contains("noop"));
    }

    #[test]
    fn bench_respects_time_budget() {
        let b = Bencher {
            warmup: 0,
            min_iters: 2,
            max_iters: 1000,
            max_total: Duration::from_millis(50),
        };
        let r = b.bench("sleepy", || std::thread::sleep(Duration::from_millis(10)));
        assert!(r.iters < 1000);
    }

    #[test]
    fn fmt_duration_units() {
        assert!(fmt_duration(2e-9).ends_with("ns"));
        assert!(fmt_duration(2e-6).ends_with("us"));
        assert!(fmt_duration(2e-3).ends_with("ms"));
        assert!(fmt_duration(2.0).ends_with('s'));
    }
}

//! Summary statistics, percentiles and text histograms.
//!
//! Used by the Fig-1 reproduction (job-time distribution), by the bench
//! harness, and by every figure module to summarize virtual-time samples.

/// Summary of a sample of f64 values.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p25: f64,
    pub p50: f64,
    pub p75: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute the summary of a sample (not required to be sorted).
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of empty sample");
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p25: percentile_sorted(&sorted, 0.25),
            p50: percentile_sorted(&sorted, 0.50),
            p75: percentile_sorted(&sorted, 0.75),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
            max: sorted[n - 1],
        }
    }

    /// One-line human-readable rendering.
    pub fn line(&self) -> String {
        format!(
            "n={} mean={:.3} std={:.3} min={:.3} p50={:.3} p90={:.3} p99={:.3} max={:.3}",
            self.n, self.mean, self.std, self.min, self.p50, self.p90, self.p99, self.max
        )
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::obj()
            .field("n", self.n)
            .field("mean", self.mean)
            .field("std", self.std)
            .field("min", self.min)
            .field("p25", self.p25)
            .field("p50", self.p50)
            .field("p75", self.p75)
            .field("p90", self.p90)
            .field("p99", self.p99)
            .field("max", self.max)
            .build()
    }
}

/// Linear-interpolated percentile of a sorted sample, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Percentile of an unsorted sample.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, q)
}

/// A fixed-bin histogram over [lo, hi); values outside are clamped into the
/// first/last bin (the Fig-1 tail must not be silently dropped).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Histogram {
        assert!(hi > lo && bins > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; bins],
            total: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64) as isize).clamp(0, bins as isize - 1) as usize;
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn add_all(&mut self, xs: &[f64]) {
        for &x in xs {
            self.add(x);
        }
    }

    /// Bin center for bin `i`.
    pub fn center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        self.lo + w * (i as f64 + 0.5)
    }

    /// Fraction of mass in bin `i`.
    pub fn frac(&self, i: usize) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.counts[i] as f64 / self.total as f64
        }
    }

    /// ASCII rendering (one row per bin) — the terminal version of Fig 1.
    pub fn render(&self, width: usize) -> String {
        let maxc = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let bar = (c as f64 / maxc as f64 * width as f64).round() as usize;
            out.push_str(&format!(
                "{:>10.1} | {:<width$} {}\n",
                self.center(i),
                "#".repeat(bar),
                c,
                width = width
            ));
        }
        out
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        obj()
            .field("lo", self.lo)
            .field("hi", self.hi)
            .field("total", self.total)
            .field(
                "counts",
                Json::Arr(self.counts.iter().map(|&c| Json::from(c)).collect()),
            )
            .build()
    }
}

/// Render an aligned text table. `rows` are formatted cells; column widths
/// auto-fit. Used by every figure harness for paper-style output.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let sep = |out: &mut String| {
        for (i, w) in widths.iter().enumerate() {
            out.push_str(if i == 0 { "+" } else { "+" });
            out.push_str(&"-".repeat(w + 2));
        }
        out.push_str("+\n");
    };
    sep(&mut out);
    for (i, h) in headers.iter().enumerate() {
        out.push_str(&format!("| {:<width$} ", h, width = widths[i]));
    }
    out.push_str("|\n");
    sep(&mut out);
    for row in rows {
        for i in 0..ncol {
            let cell = row.get(i).map(String::as_str).unwrap_or("");
            out.push_str(&format!("| {:<width$} ", cell, width = widths[i]));
        }
        out.push_str("|\n");
    }
    sep(&mut out);
    out
}

/// Format seconds compactly (e.g. "135.2s", "2.1m").
pub fn fmt_secs(s: f64) -> String {
    if s < 120.0 {
        format!("{s:.1}s")
    } else {
        format!("{:.1}m", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p99 - 99.01).abs() < 0.01);
    }

    #[test]
    fn percentile_interp() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 10.0);
    }

    #[test]
    fn histogram_counts_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.add_all(&[-5.0, 0.5, 5.5, 9.9, 42.0]);
        assert_eq!(h.total, 5);
        assert_eq!(h.counts[0], 2); // -5 clamped + 0.5
        assert_eq!(h.counts[5], 1);
        assert_eq!(h.counts[9], 2); // 9.9 + clamped 42
        assert!((h.frac(0) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn histogram_render_nonempty() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.add_all(&[0.1, 0.1, 0.9]);
        let r = h.render(20);
        assert!(r.lines().count() == 4);
        assert!(r.contains('#'));
    }

    #[test]
    fn table_alignment() {
        let t = render_table(
            &["scheme", "time"],
            &[
                vec!["local-product".into(), "1.0".into()],
                vec!["spec".into(), "2.0".into()],
            ],
        );
        assert!(t.contains("| local-product | 1.0  |"));
    }

    #[test]
    fn fmt_secs_units() {
        assert_eq!(fmt_secs(5.0), "5.0s");
        assert_eq!(fmt_secs(300.0), "5.0m");
    }
}

//! Minimal JSON model, parser and writer.
//!
//! `serde`/`serde_json` are unavailable in the offline image, so this module
//! is the substrate used for (a) the config system, (b) the artifact
//! manifest produced by `python/compile/aot.py`, and (c) machine-readable
//! experiment results written to `results/`.
//!
//! It supports the full JSON grammar (objects, arrays, strings with
//! escapes/`\uXXXX`, numbers, booleans, null) and pretty or compact
//! serialization. Object key order is preserved (insertion order), which
//! keeps emitted result files diffable.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object: insertion-ordered key/value pairs.
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as u64)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Nested lookup by dotted path, e.g. `"platform.straggler.p"`.
    pub fn get_path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    /// Set/replace a field on an object (panics if not an object).
    pub fn set(&mut self, key: &str, val: Json) {
        match self {
            Json::Obj(o) => {
                if let Some(slot) = o.iter_mut().find(|(k, _)| k == key) {
                    slot.1 = val;
                } else {
                    o.push((key.to_string(), val));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    // JSON has no NaN/Inf; emit null like most writers.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors.
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i32> for Json {
    fn from(x: i32) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Self {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

/// Builder for JSON objects: `obj().field("a", 1.0).field("b", "x").build()`.
pub struct ObjBuilder {
    fields: Vec<(String, Json)>,
}

pub fn obj() -> ObjBuilder {
    ObjBuilder { fields: Vec::new() }
}

impl ObjBuilder {
    pub fn field(mut self, key: &str, val: impl Into<Json>) -> Self {
        self.fields.push((key.to_string(), val.into()));
        self
    }

    pub fn build(self) -> Json {
        Json::Obj(self.fields)
    }
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document. Trailing whitespace is allowed; trailing garbage is
/// an error.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => {
                            s.push('"');
                            self.pos += 1;
                        }
                        Some(b'\\') => {
                            s.push('\\');
                            self.pos += 1;
                        }
                        Some(b'/') => {
                            s.push('/');
                            self.pos += 1;
                        }
                        Some(b'n') => {
                            s.push('\n');
                            self.pos += 1;
                        }
                        Some(b't') => {
                            s.push('\t');
                            self.pos += 1;
                        }
                        Some(b'r') => {
                            s.push('\r');
                            self.pos += 1;
                        }
                        Some(b'b') => {
                            s.push('\u{8}');
                            self.pos += 1;
                        }
                        Some(b'f') => {
                            s.push('\u{c}');
                            self.pos += 1;
                        }
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo.wrapping_sub(0xDC00));
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("bad unicode escape"))?);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("short unicode escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Load and parse a JSON file.
pub fn load_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

/// Is `b` within 1e-6 (absolute or relative) of golden value `a`?
fn num_close(a: f64, b: f64) -> bool {
    let tol = 1e-6_f64.max(1e-6 * a.abs().max(b.abs()));
    (a - b).abs() <= tol
}

/// Golden-vs-observed structural diff, shared by the golden regression
/// suites (`tests/scenarios_golden.rs`, `tests/scheme_conformance.rs`).
///
/// Semantics: a golden `null` is a wildcard (field not yet pinned);
/// golden objects are compared as *subsets* of the observed object
/// (extra observed keys are fine, missing ones are a failure); numbers
/// compare with 1e-6 absolute/relative tolerance so goldens can be
/// hand-written or machine-blessed. One line per divergent field is
/// appended to `out`.
///
/// `schema_version` is structural, not a measurement: when a golden pins
/// it, the observed value must match *exactly* — no numeric tolerance,
/// which would let a version drift slide through as "close enough".
pub fn golden_diff(golden: &Json, got: &Json, path: &str, out: &mut Vec<String>) {
    match golden {
        Json::Null => {}
        Json::Obj(fields) => {
            if !matches!(got, Json::Obj(_)) {
                out.push(format!(
                    "{path}: expected an object, observed {}",
                    got.to_string_compact()
                ));
                return;
            }
            for (k, v) in fields {
                let sub = if path.is_empty() {
                    k.clone()
                } else {
                    format!("{path}.{k}")
                };
                match got.get(k) {
                    Some(g) if k == "schema_version" => {
                        if v != g {
                            out.push(format!(
                                "{sub}: golden schema_version {} vs observed {}",
                                v.to_string_compact(),
                                g.to_string_compact()
                            ));
                        }
                    }
                    Some(g) => golden_diff(v, g, &sub, out),
                    None => out.push(format!("{sub}: missing in observed output")),
                }
            }
        }
        Json::Arr(items) => match got.as_arr() {
            None => out.push(format!(
                "{path}: expected an array, observed {}",
                got.to_string_compact()
            )),
            Some(gs) => {
                if gs.len() != items.len() {
                    out.push(format!(
                        "{path}: golden has {} items, observed {}",
                        items.len(),
                        gs.len()
                    ));
                    return;
                }
                for (i, (v, g)) in items.iter().zip(gs).enumerate() {
                    golden_diff(v, g, &format!("{path}[{i}]"), out);
                }
            }
        },
        Json::Num(a) => match got.as_f64() {
            Some(b) if num_close(*a, b) => {}
            _ => out.push(format!(
                "{path}: golden {} vs observed {}",
                golden.to_string_compact(),
                got.to_string_compact()
            )),
        },
        other => {
            if other != got {
                out.push(format!(
                    "{path}: golden {} vs observed {}",
                    other.to_string_compact(),
                    got.to_string_compact()
                ));
            }
        }
    }
}

/// Flatten an object into dotted-path/value pairs (for diffing configs).
pub fn flatten(v: &Json) -> BTreeMap<String, String> {
    let mut out = BTreeMap::new();
    fn rec(prefix: &str, v: &Json, out: &mut BTreeMap<String, String>) {
        match v {
            Json::Obj(fields) => {
                for (k, val) in fields {
                    let p = if prefix.is_empty() {
                        k.clone()
                    } else {
                        format!("{prefix}.{k}")
                    };
                    rec(&p, val, out);
                }
            }
            other => {
                out.insert(prefix.to_string(), other.to_string_compact());
            }
        }
    }
    rec("", v, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": -2.5e3}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get_path("c.d").unwrap().as_f64(), Some(-2500.0));
        let re = parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
        let re2 = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{} extra").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""aA\\\"\n\t""#).unwrap();
        assert_eq!(v.as_str(), Some("aA\\\"\n\t"));
    }

    #[test]
    fn surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn numbers() {
        for (s, x) in [
            ("0", 0.0),
            ("-0.5", -0.5),
            ("1e3", 1000.0),
            ("2.5E-2", 0.025),
            ("123456789", 123456789.0),
        ] {
            assert_eq!(parse(s).unwrap().as_f64(), Some(x), "{s}");
        }
    }

    #[test]
    fn builder_and_set() {
        let mut v = obj().field("x", 1.0).field("name", "n").build();
        v.set("x", Json::from(2.0));
        v.set("new", Json::from(true));
        assert_eq!(v.get("x").unwrap().as_f64(), Some(2.0));
        assert_eq!(v.get("new").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<&str> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn flatten_paths() {
        let v = parse(r#"{"a": {"b": 1, "c": [2]}, "d": "x"}"#).unwrap();
        let f = flatten(&v);
        assert_eq!(f.get("a.b").map(String::as_str), Some("1"));
        assert_eq!(f.get("d").map(String::as_str), Some("\"x\""));
    }

    #[test]
    fn non_finite_serializes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }

    #[test]
    fn golden_diff_schema_version_is_exact_while_numbers_stay_tolerant() {
        let golden = parse(r#"{"latency": 1.0, "schema_version": 1}"#).unwrap();
        // Within tolerance on a measurement, exact on the version: clean.
        let ok = parse(r#"{"latency": 1.0000001, "schema_version": 1}"#).unwrap();
        let mut out = Vec::new();
        golden_diff(&golden, &ok, "", &mut out);
        assert!(out.is_empty(), "{out:?}");
        // A "close" schema_version is still a hard mismatch.
        let drifted = parse(r#"{"latency": 1.0, "schema_version": 1.0000001}"#).unwrap();
        let mut out = Vec::new();
        golden_diff(&golden, &drifted, "", &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].contains("schema_version"), "{out:?}");
        // Subset semantics still hold: goldens that never pinned the
        // version don't start failing when outputs grow one.
        let unpinned = parse(r#"{"latency": 1.0}"#).unwrap();
        let mut out = Vec::new();
        golden_diff(&unpinned, &ok, "", &mut out);
        assert!(out.is_empty(), "{out:?}");
    }
}

//! Deterministic pseudo-random number generation and distributions.
//!
//! The offline build has no access to the `rand` crate, so this module is a
//! self-contained substrate: a PCG-XSH-RR 64/32-based 64-bit generator
//! (`Pcg64`) plus the distributions the simulator needs (uniform, normal,
//! lognormal, exponential, Bernoulli, categorical, permutation sampling).
//!
//! Everything is seedable and fully deterministic so that every experiment
//! in `EXPERIMENTS.md` can be reproduced bit-for-bit.

/// A 64-bit permuted congruential generator (PCG-RXS-M-XS variant, two
/// independent 64-bit streams combined for 64-bit output).
///
/// Passes practical statistical needs for simulation workloads; NOT a
/// cryptographic RNG.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed. Distinct seeds yield
    /// independent-looking streams.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into 128-bit state + stream.
        let mut sm = SplitMix64::new(seed);
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let inc = (((sm.next_u64() as u128) << 64) | sm.next_u64() as u128) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = state.wrapping_add(rng.inc);
        rng.next_u64();
        rng
    }

    /// Derive a child generator; children with distinct `stream` values are
    /// statistically independent of each other and of the parent.
    pub fn fork(&mut self, stream: u64) -> Pcg64 {
        let s = self.next_u64() ^ (stream.wrapping_mul(0x9e3779b97f4a7c15));
        Pcg64::new(s)
    }

    /// Next raw 64 random bits (PCG-XSL-RR output function).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1). 53-bit mantissa precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection to
    /// avoid modulo bias.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0) is undefined");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli(p): true with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached second variate dropped for
    /// simplicity; throughput is not a bottleneck for the simulator).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        // Avoid ln(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        mean + std * r * theta.cos()
    }

    /// LogNormal with the *underlying* normal's mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Pareto with scale `xm` and shape `alpha` (heavy-tailed; used for
    /// straggler factor ablations).
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        xm / u.powf(1.0 / alpha)
    }

    /// Sample an index from unnormalized nonnegative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical needs positive total weight");
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k ≤ n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Partial Fisher–Yates over an index vector; O(n) setup is fine at
        // simulator scales.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Fill a slice with standard-normal f32 values (for synthetic matrices).
    pub fn fill_normal_f32(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal(mean as f64, std as f64) as f32;
        }
    }

    /// Fill a slice with uniform [lo, hi) f32 values.
    pub fn fill_uniform_f32(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.uniform(lo as f64, hi as f64) as f32;
        }
    }
}

/// SplitMix64 — used only to expand seeds into PCG state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Pcg64::new(3);
        let n = 10u64;
        let mut counts = [0usize; 10];
        let trials = 100_000;
        for _ in 0..trials {
            counts[r.below(n) as usize] += 1;
        }
        let expect = trials as f64 / n as f64;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < 0.05 * expect, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let x = r.normal(3.0, 2.0);
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!((mean - 3.0).abs() < 0.02, "mean={mean}");
        assert!((var - 4.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Pcg64::new(5);
        let n = 200_000;
        let hits = (0..n).filter(|_| r.bernoulli(0.02)).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.02).abs() < 0.002, "rate={rate}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg64::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(0.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Pcg64::new(13);
        for _ in 0..1000 {
            assert!(r.lognormal(0.0, 1.0) > 0.0);
        }
    }

    #[test]
    fn pareto_at_least_scale() {
        let mut r = Pcg64::new(17);
        for _ in 0..1000 {
            assert!(r.pareto(2.0, 1.5) >= 2.0);
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::new(19);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(23);
        for _ in 0..100 {
            let s = r.sample_indices(20, 7);
            assert_eq!(s.len(), 7);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 7);
            assert!(sorted.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(29);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg64::new(31);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}

//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports: subcommands, `--flag`, `--key value`, `--key=value`, and
//! positional arguments, plus auto-generated usage text.

use std::collections::BTreeMap;

/// Declarative option spec used for usage rendering and validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments: options, flags and positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from raw args (excluding argv[0]) against a spec. Unknown
    /// options are an error so typos fail loudly.
    pub fn parse(raw: &[String], specs: &[OptSpec]) -> Result<Args, String> {
        let mut out = Args::default();
        // Seed defaults.
        for s in specs {
            if let Some(d) = s.default {
                out.options.insert(s.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown option --{name}"))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} requires a value"))?
                        }
                    };
                    out.options.insert(name, val);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("--{name} does not take a value"));
                    }
                    out.flags.push(name);
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| format!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<u64>()
                .map(Some)
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }
}

/// Render usage text for a subcommand.
pub fn usage(program: &str, sub: &str, about: &str, specs: &[OptSpec]) -> String {
    let mut s = format!("{about}\n\nUsage: {program} {sub} [options]\n\nOptions:\n");
    for spec in specs {
        let arg = if spec.takes_value {
            format!("--{} <v>", spec.name)
        } else {
            format!("--{}", spec.name)
        };
        let default = spec
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("  {arg:<24} {}{default}\n", spec.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<OptSpec> {
        vec![
            OptSpec {
                name: "dim",
                help: "matrix dimension",
                takes_value: true,
                default: Some("1024"),
            },
            OptSpec {
                name: "verbose",
                help: "chatty output",
                takes_value: false,
                default: None,
            },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_options_flags_positionals() {
        let a = Args::parse(&sv(&["--dim", "2048", "--verbose", "fig5"]), &specs()).unwrap();
        assert_eq!(a.get("dim"), Some("2048"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["fig5"]);
    }

    #[test]
    fn equals_syntax() {
        let a = Args::parse(&sv(&["--dim=4096"]), &specs()).unwrap();
        assert_eq!(a.get_usize("dim").unwrap(), Some(4096));
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&[]), &specs()).unwrap();
        assert_eq!(a.get("dim"), Some("1024"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(Args::parse(&sv(&["--nope"]), &specs()).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(Args::parse(&sv(&["--dim"]), &specs()).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(Args::parse(&sv(&["--verbose=yes"]), &specs()).is_err());
    }

    #[test]
    fn bad_number_reported() {
        let a = Args::parse(&sv(&["--dim", "abc"]), &specs()).unwrap();
        assert!(a.get_usize("dim").is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = usage("slec", "figures", "Reproduce figures", &specs());
        assert!(u.contains("--dim"));
        assert!(u.contains("default: 1024"));
    }
}

//! Shared substrates: deterministic RNG, JSON, threading, CLI parsing,
//! statistics, bench and property-test harnesses.
//!
//! These exist because the offline build image has no access to the usual
//! crates (`rand`, `serde`, `tokio`/`rayon`, `clap`, `criterion`,
//! `proptest`); each substitute is small, tested, and tailored to what the
//! reproduction needs. See DESIGN.md §Substitutions.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;

//! Mini property-based testing framework (proptest is unavailable offline).
//!
//! Usage:
//! ```ignore
//! proptest(200, 0xBEEF, |g| {
//!     let la = g.usize_in(1, 12);
//!     let lb = g.usize_in(1, 12);
//!     // ... build inputs from `g`, assert invariants ...
//! });
//! ```
//! On failure the panic message includes the case index and the seed so the
//! exact case replays deterministically. A lightweight "shrink" is provided
//! by re-running with the reported single-case seed.

use crate::util::rng::Pcg64;

/// Generator handed to property closures.
pub struct Gen {
    pub rng: Pcg64,
    /// Case index (0-based) for diagnostics.
    pub case: usize,
}

impl Gen {
    /// usize uniform in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.index(hi - lo + 1)
    }

    /// f64 uniform in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    /// Probability-ish value in (0, 0.5].
    pub fn prob(&mut self) -> f64 {
        self.rng.uniform(1e-4, 0.5)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bernoulli(0.5)
    }

    /// Random vector of f32 with entries in [-1, 1).
    pub fn vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.uniform(-1.0, 1.0) as f32).collect()
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }

    /// Random subset of size k from 0..n.
    pub fn subset(&mut self, n: usize, k: usize) -> Vec<usize> {
        self.rng.sample_indices(n, k)
    }
}

/// Run `prop` for `cases` random cases with a base `seed`.
///
/// Panics (failing the test) with replay info if the property panics.
pub fn proptest(cases: usize, seed: u64, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x9e3779b97f4a7c15)
            .wrapping_add(case as u64);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen {
                rng: Pcg64::new(case_seed),
                case,
            };
            prop(&mut g);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property failed at case {case}/{cases} (replay: run_case(seed=0x{case_seed:x})): {msg}"
            );
        }
    }
}

/// Replay a single failing case by its reported case seed.
pub fn run_case(case_seed: u64, prop: impl Fn(&mut Gen)) {
    let mut g = Gen {
        rng: Pcg64::new(case_seed),
        case: 0,
    };
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        proptest(100, 1, |g| {
            let a = g.usize_in(0, 10);
            let b = g.usize_in(0, 10);
            assert!(a + b <= 20);
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_case() {
        proptest(100, 2, |g| {
            let x = g.usize_in(0, 100);
            assert!(x < 95, "x too big: {x}");
        });
    }

    #[test]
    fn deterministic_cases() {
        let mut first = Vec::new();
        proptest(10, 42, |g| {
            if g.case == 3 {
                // capture some draws — compare across runs via a static
            }
            let _ = g.usize_in(0, 1000);
        });
        // Determinism: same seed ⇒ same draws.
        for _ in 0..2 {
            let mut draws = Vec::new();
            proptest(5, 7, |g| {
                // record first draw of each case through a thread_local
                DRAWS.with(|d| d.borrow_mut().push(g.usize_in(0, 1_000_000)));
            });
            DRAWS.with(|d| {
                draws = d.borrow().clone();
                d.borrow_mut().clear();
            });
            if first.is_empty() {
                first = draws;
            } else {
                assert_eq!(first, draws);
            }
        }
    }

    thread_local! {
        static DRAWS: std::cell::RefCell<Vec<usize>> = const { std::cell::RefCell::new(Vec::new()) };
    }

    #[test]
    fn subset_bounds() {
        proptest(50, 9, |g| {
            let n = g.usize_in(1, 30);
            let k = g.usize_in(0, n);
            let s = g.subset(n, k);
            assert_eq!(s.len(), k);
            assert!(s.iter().all(|&i| i < n));
        });
    }
}

//! Storage cost model: converts I/O volume into *virtual seconds*.
//!
//! The paper's core premise is that in serverless settings
//! "communication costs greatly outweigh computation costs" (§VI): every
//! S3 read/write pays a per-op latency plus bytes/bandwidth. The decode
//! phase's cost — the quantity Theorems 1–2 bound — is linear in blocks
//! read, which this model makes explicit.

/// S3-like cost parameters (per worker).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Per-operation latency in seconds (request round-trip).
    pub op_latency_s: f64,
    /// Sustained per-worker bandwidth, bytes/second.
    pub bandwidth_bps: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        // Calibrated to measured AWS Lambda↔S3 characteristics circa the
        // paper: ~60 ms request latency, ~100 MB/s per-worker throughput.
        CostModel {
            op_latency_s: 0.060,
            bandwidth_bps: 100e6,
        }
    }
}

impl CostModel {
    /// Virtual time to read `bytes` in one object.
    pub fn read_time(&self, bytes: u64) -> f64 {
        self.op_latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Virtual time to write `bytes` in one object.
    pub fn write_time(&self, bytes: u64) -> f64 {
        self.op_latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Time for `n_ops` reads totalling `bytes` (e.g. a decode worker
    /// fetching R blocks).
    pub fn read_many(&self, n_ops: u64, bytes: u64) -> f64 {
        n_ops as f64 * self.op_latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Like [`CostModel::read_many`] but with `parallelism` concurrent
    /// in-flight GETs — the long-lived master's async fetch path for
    /// small vector blocks.
    pub fn read_many_parallel(&self, n_ops: u64, bytes: u64, parallelism: u64) -> f64 {
        let rounds = n_ops.div_ceil(parallelism.max(1));
        rounds as f64 * self.op_latency_s + bytes as f64 / self.bandwidth_bps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_time_linear_in_bytes() {
        let c = CostModel {
            op_latency_s: 0.1,
            bandwidth_bps: 1e6,
        };
        assert!((c.read_time(0) - 0.1).abs() < 1e-12);
        assert!((c.read_time(2_000_000) - 2.1).abs() < 1e-12);
        assert!((c.write_time(500_000) - 0.6).abs() < 1e-12);
    }

    #[test]
    fn read_many_accumulates_latency() {
        let c = CostModel {
            op_latency_s: 0.05,
            bandwidth_bps: 1e6,
        };
        // 10 block reads of 100 KB each: 0.5 s latency + 1 s transfer.
        let t = c.read_many(10, 1_000_000);
        assert!((t - 1.5).abs() < 1e-12);
    }

    #[test]
    fn defaults_sane() {
        let c = CostModel::default();
        // A 64 MB block read should take ~0.7 s.
        let t = c.read_time(64 << 20);
        assert!(t > 0.5 && t < 1.0, "t={t}");
    }
}

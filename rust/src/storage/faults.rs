//! Storage fault injection and the typed failure surface of block reads.
//!
//! The rest of the stack historically trusted the object store
//! completely: `put` cannot fail and a `get` miss was a caller bug. Real
//! S3-class stores throttle (503 SlowDown), lose objects, and return
//! torn reads — and the paper's local parities can absorb a lost *block*
//! exactly as they absorb a straggling *task*. This module supplies the
//! three pieces that make the pipeline honest about that:
//!
//! - [`StorageError`] — the typed vocabulary of a failed read
//!   (`NotFound` / `Corrupt` / `Transient`), consumed by the driver's
//!   bounded-retry loop and its erasure-demotion path.
//! - An **integrity layer**: [`FaultyStore`] records an FNV-1a digest of
//!   every `put`/`put_block` payload and verifies it on read, so silent
//!   corruption is *detected* (a typed error) instead of propagated into
//!   the decoder as wrong numerics.
//! - [`FaultyStore`] itself — a deterministic fault-injecting
//!   [`ObjectStore`] wrapper driven by a [`StorageFaultSpec`]. Every
//!   fault class is draw-gated on its own probability, so an inert spec
//!   consumes **zero** RNG draws and wrapped runs are bit-identical to
//!   unwrapped ones (the PR 6 draw-gating contract).
//!
//! The fault plane covers the *block read* surface
//! ([`ObjectStore::try_get_block`]) — the one path the coded pipeline's
//! retry and erasure machinery can absorb. Byte-surface reads stay
//! fault-free but digest-verified (a detected mismatch reads as absent),
//! so manifest traffic cannot silently go wrong either.
//!
//! The scenario runner mirrors these semantics in timing-land without a
//! real store (see `platform::scenario`); both sides fork their streams
//! from [`STORAGE_FAULT_SALT`] so storage-fault draws can never perturb
//! straggler or worker-death draws.

use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, Mutex};

use crate::linalg::matrix::BlockBuf;
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg64;

use super::{ObjectStore, StatsSnapshot};

/// Stream salt ("STORFALT" in ASCII) separating storage-fault draws from
/// every other consumer of a scenario seed. Both [`FaultyStore`] and the
/// scenario runner derive their fault streams as
/// `Pcg64::new(seed ^ STORAGE_FAULT_SALT)`, forked per job.
pub const STORAGE_FAULT_SALT: u64 = 0x53544F5246414C54;

/// Why a fallible read failed. The driver maps these onto its recovery
/// ladder: `Transient` and `Corrupt` are retryable (a re-read may
/// succeed), `NotFound` is permanent — the object is gone and the only
/// recovery left is coded (treat the block as an erasure).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StorageError {
    /// The key holds no object (never stored, deleted, or lost).
    NotFound { key: String },
    /// The payload arrived but its content digest does not match what
    /// was staged (bit rot, torn read, or tampering).
    Corrupt { key: String },
    /// The store refused the operation this time (throttle / SlowDown);
    /// a retry after backoff may succeed.
    Transient { key: String },
}

impl StorageError {
    /// The key the failed operation addressed.
    pub fn key(&self) -> &str {
        match self {
            StorageError::NotFound { key }
            | StorageError::Corrupt { key }
            | StorageError::Transient { key } => key,
        }
    }

    /// Whether a bounded retry is worth attempting. `NotFound` is
    /// permanent by definition; `Corrupt` and `Transient` model per-read
    /// conditions that an independent re-read can clear.
    pub fn retryable(&self) -> bool {
        !matches!(self, StorageError::NotFound { .. })
    }
}

impl fmt::Display for StorageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StorageError::NotFound { key } => write!(f, "object not found: {key}"),
            StorageError::Corrupt { key } => write!(f, "object failed integrity check: {key}"),
            StorageError::Transient { key } => write!(f, "transient storage error reading {key}"),
        }
    }
}

impl std::error::Error for StorageError {}

/// The `"storage_faults"` scenario section: per-read fault probabilities
/// plus the retry contract. All probabilities default to zero — an
/// absent or all-zero section is *inert* and must consume no RNG draws.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StorageFaultSpec {
    /// Per-read probability of a transient (retryable) error.
    pub transient_p: f64,
    /// Virtual seconds one retry costs on the scenario timing path (the
    /// store's advertised retry-after delay, folded into task I/O time).
    pub throttle_s: f64,
    /// Probability an object is permanently lost (per coded input block
    /// on the scenario path; per read on the [`FaultyStore`] path, where
    /// the draw deletes the underlying object).
    pub loss_p: f64,
    /// Per-read probability of silent corruption (a single bit flip in
    /// the wire image, caught by the integrity digest).
    pub corrupt_p: f64,
    /// Bounded retries per read before the block is demoted to an
    /// erasure.
    pub max_retries: u32,
    /// Base of the deterministic exponential backoff (virtual seconds).
    pub backoff_s: f64,
}

impl Default for StorageFaultSpec {
    fn default() -> Self {
        StorageFaultSpec {
            transient_p: 0.0,
            throttle_s: 0.0,
            loss_p: 0.0,
            corrupt_p: 0.0,
            max_retries: 3,
            backoff_s: 1.0,
        }
    }
}

impl StorageFaultSpec {
    /// Whether the spec can inject anything. An inert spec must behave
    /// exactly like no spec at all: zero draws, zero report keys.
    pub fn any(&self) -> bool {
        self.transient_p > 0.0 || self.loss_p > 0.0 || self.corrupt_p > 0.0
    }

    /// The retry contract this spec implies.
    pub fn retry(&self) -> RetryPolicy {
        RetryPolicy {
            max_retries: self.max_retries,
            backoff_s: self.backoff_s,
        }
    }
}

/// Bounded retry with deterministic exponential backoff — the storage
/// analogue of `FailureModel`'s re-dispatch backoff: virtual-clock time,
/// no jitter, so simulated runs stay bit-reproducible.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Retries after the first attempt (0 = fail fast).
    pub max_retries: u32,
    /// Backoff before retry `k` (1-based) is `backoff_s · 2^(k-1)`.
    pub backoff_s: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            backoff_s: 1.0,
        }
    }
}

impl RetryPolicy {
    /// Virtual seconds to wait before retry `attempt` (1-based). The
    /// exponent is capped so a pathological retry budget cannot push the
    /// virtual clock to infinity.
    pub fn backoff(&self, attempt: u32) -> f64 {
        let exp = attempt.saturating_sub(1).min(52) as i32;
        self.backoff_s * 2f64.powi(exp)
    }
}

/// Storage-fault counters surfaced in `JobReport` (key appended only
/// when at least one counter is nonzero) and rolled up through the
/// service summary and the daemon's `/metrics`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StorageFaultMetrics {
    /// Transient errors observed (each costs one retry).
    pub transients: u64,
    /// Re-read attempts performed.
    pub retries: u64,
    /// Blocks permanently lost (demoted to erasures).
    pub lost: u64,
    /// Corruptions detected by the integrity digest.
    pub corrupt: u64,
    /// Lost blocks reconstructed by the code's parity slack.
    pub recovered_via_parity: u64,
}

impl StorageFaultMetrics {
    /// Whether anything happened (all-zero metrics are not reported).
    pub fn any(&self) -> bool {
        *self != StorageFaultMetrics::default()
    }

    /// Fold another job's counters into a rollup.
    pub fn add(&mut self, o: &StorageFaultMetrics) {
        self.transients += o.transients;
        self.retries += o.retries;
        self.lost += o.lost;
        self.corrupt += o.corrupt;
        self.recovered_via_parity += o.recovered_via_parity;
    }

    pub fn to_json(&self) -> Json {
        obj()
            .field("transients", self.transients)
            .field("retries", self.retries)
            .field("lost", self.lost)
            .field("corrupt", self.corrupt)
            .field("recovered_via_parity", self.recovered_via_parity)
            .build()
    }
}

/// FNV-1a over arbitrary bytes — the store's one hash family (the same
/// constants as [`super::shard_of`]), reused as the content digest of
/// the integrity layer.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

struct FaultState {
    rng: Pcg64,
    /// Content digest of every payload staged through this wrapper,
    /// keyed by object key. A sidecar map — the wire image and all byte
    /// accounting are unchanged, so traffic numbers stay comparable with
    /// unwrapped runs.
    digests: HashMap<String, u64>,
    metrics: StorageFaultMetrics,
}

/// Deterministic fault-injecting wrapper over any [`ObjectStore`].
///
/// Reads through [`ObjectStore::try_get_block`] pass a three-stage fault
/// plane — permanent loss (the underlying object is deleted), transient
/// refusal, and a single-bit corruption of the wire image — each
/// draw-gated on its probability from a dedicated
/// [`STORAGE_FAULT_SALT`]-derived stream. Every staged payload is
/// digest-framed; reads verify the digest, so an injected (or external)
/// flip surfaces as [`StorageError::Corrupt`], never as silently wrong
/// numerics.
pub struct FaultyStore {
    inner: Arc<dyn ObjectStore>,
    spec: StorageFaultSpec,
    state: Mutex<FaultState>,
}

impl FaultyStore {
    /// Wrap `inner`. The fault stream is `Pcg64::new(seed ^
    /// STORAGE_FAULT_SALT)` — derive `seed` from the job seed so
    /// concurrent jobs with distinct seeds draw independently.
    pub fn new(inner: Arc<dyn ObjectStore>, spec: StorageFaultSpec, seed: u64) -> FaultyStore {
        FaultyStore {
            inner,
            spec,
            state: Mutex::new(FaultState {
                rng: Pcg64::new(seed ^ STORAGE_FAULT_SALT),
                digests: HashMap::new(),
                metrics: StorageFaultMetrics::default(),
            }),
        }
    }

    /// Injection counters so far (what the wrapper *did*; the driver
    /// separately reports what it *observed* and recovered).
    pub fn metrics(&self) -> StorageFaultMetrics {
        self.state.lock().unwrap().metrics
    }

    /// The wrapped store.
    pub fn inner(&self) -> &Arc<dyn ObjectStore> {
        &self.inner
    }
}

impl ObjectStore for FaultyStore {
    fn put(&self, key: &str, value: Vec<u8>) {
        self.state
            .lock()
            .unwrap()
            .digests
            .insert(key.to_string(), fnv64(&value));
        self.inner.put(key, value);
    }

    fn get(&self, key: &str) -> Option<Arc<Vec<u8>>> {
        let bytes = self.inner.get(key)?;
        // Byte-surface reads are fault-free but still integrity-checked:
        // a digest mismatch reads as absent rather than handing back a
        // payload the writer never staged.
        if let Some(&want) = self.state.lock().unwrap().digests.get(key) {
            if fnv64(&bytes) != want {
                return None;
            }
        }
        Some(bytes)
    }

    fn exists(&self, key: &str) -> bool {
        self.inner.exists(key)
    }

    fn delete(&self, key: &str) -> bool {
        self.state.lock().unwrap().digests.remove(key);
        self.inner.delete(key)
    }

    fn list(&self, prefix: &str) -> Vec<String> {
        self.inner.list(prefix)
    }

    fn stats(&self) -> StatsSnapshot {
        self.inner.stats()
    }

    fn put_block(&self, key: &str, block: BlockBuf) {
        // Digest the logical wire image (the zero-copy handle itself is
        // what moves into the store, unchanged).
        self.state
            .lock()
            .unwrap()
            .digests
            .insert(key.to_string(), fnv64(&block.to_wire()));
        self.inner.put_block(key, block);
    }

    fn get_block(&self, key: &str) -> Option<BlockBuf> {
        self.try_get_block(key).ok()
    }

    fn try_get_block(&self, key: &str) -> Result<BlockBuf, StorageError> {
        let nf = || StorageError::NotFound {
            key: key.to_string(),
        };
        let mut st = self.state.lock().unwrap();
        // Draw order per read: loss, transient, corrupt — each gated on
        // its own probability (inert spec ⇒ zero draws).
        if self.spec.loss_p > 0.0 && st.rng.bernoulli(self.spec.loss_p) {
            st.metrics.lost += 1;
            st.digests.remove(key);
            self.inner.delete(key);
            return Err(nf());
        }
        if self.spec.transient_p > 0.0 && st.rng.bernoulli(self.spec.transient_p) {
            st.metrics.transients += 1;
            return Err(StorageError::Transient {
                key: key.to_string(),
            });
        }
        let block = self.inner.get_block(key).ok_or_else(nf)?;
        let mut wire: Option<Vec<u8>> = None;
        if self.spec.corrupt_p > 0.0 && st.rng.bernoulli(self.spec.corrupt_p) {
            st.metrics.corrupt += 1;
            let mut w = block.to_wire();
            let bit = st.rng.below(w.len() as u64 * 8);
            w[(bit / 8) as usize] ^= 1 << (bit % 8);
            wire = Some(w);
        }
        if let Some(&want) = st.digests.get(key) {
            let got = match &wire {
                Some(w) => fnv64(w),
                None => fnv64(&block.to_wire()),
            };
            if got != want {
                return Err(StorageError::Corrupt {
                    key: key.to_string(),
                });
            }
        }
        match wire {
            // No digest on record (key staged outside this wrapper): a
            // flip that still parses would go through undetected — the
            // exact hazard the integrity layer exists to close, kept
            // observable here for tests.
            Some(w) => BlockBuf::from_wire(&w).map_err(|_| StorageError::Corrupt {
                key: key.to_string(),
            }),
            None => Ok(block),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::storage::MemStore;

    fn block(seed: u64) -> BlockBuf {
        let mut rng = Pcg64::new(seed);
        BlockBuf::new(Matrix::randn(6, 5, &mut rng, 0.0, 1.0))
    }

    fn wrapped(spec: StorageFaultSpec, seed: u64) -> (Arc<MemStore>, FaultyStore) {
        let inner = Arc::new(MemStore::new());
        let fs = FaultyStore::new(Arc::clone(&inner) as Arc<dyn ObjectStore>, spec, seed);
        (inner, fs)
    }

    #[test]
    fn fnv64_pinned() {
        // Offset basis for the empty input; one known vector so the
        // digest family can never silently change.
        assert_eq!(fnv64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv64(b"a"), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn inert_spec_is_a_pure_passthrough() {
        let spec = StorageFaultSpec::default();
        assert!(!spec.any());
        let (_inner, fs) = wrapped(spec, 7);
        let blk = block(1);
        fs.put_block("k", blk.clone());
        let back = fs.try_get_block("k").expect("clean read");
        assert!(BlockBuf::ptr_eq(&blk, &back));
        assert_eq!(fs.metrics(), StorageFaultMetrics::default());
        assert!(!fs.metrics().any());
        assert!(matches!(
            fs.try_get_block("absent"),
            Err(StorageError::NotFound { .. })
        ));
    }

    #[test]
    fn loss_deletes_the_underlying_object() {
        let spec = StorageFaultSpec {
            loss_p: 1.0,
            ..StorageFaultSpec::default()
        };
        let (inner, fs) = wrapped(spec, 3);
        fs.put_block("k", block(2));
        let err = fs.try_get_block("k").unwrap_err();
        assert!(matches!(err, StorageError::NotFound { .. }));
        assert!(!err.retryable());
        assert!(!inner.exists("k"));
        assert_eq!(fs.metrics().lost, 1);
        // Still gone on the next read — loss is permanent.
        assert!(fs.try_get_block("k").is_err());
    }

    #[test]
    fn transient_errors_are_retryable_and_counted() {
        let spec = StorageFaultSpec {
            transient_p: 1.0,
            ..StorageFaultSpec::default()
        };
        let (_inner, fs) = wrapped(spec, 4);
        fs.put_block("k", block(3));
        for _ in 0..3 {
            let err = fs.try_get_block("k").unwrap_err();
            assert!(matches!(err, StorageError::Transient { .. }), "{err}");
            assert!(err.retryable());
            assert_eq!(err.key(), "k");
        }
        assert_eq!(fs.metrics().transients, 3);
        // The object itself is intact.
        assert!(fs.exists("k"));
    }

    #[test]
    fn injected_corruption_is_caught_by_the_digest() {
        let spec = StorageFaultSpec {
            corrupt_p: 1.0,
            ..StorageFaultSpec::default()
        };
        let (_inner, fs) = wrapped(spec, 5);
        fs.put_block("k", block(4));
        let err = fs.try_get_block("k").unwrap_err();
        assert!(matches!(err, StorageError::Corrupt { .. }), "{err}");
        assert!(err.retryable());
        assert_eq!(fs.metrics().corrupt, 1);
        // The Option surface maps the same failure to a miss.
        assert!(fs.get_block("k").is_none());
    }

    #[test]
    fn external_tampering_is_caught_even_with_an_inert_spec() {
        let (inner, fs) = wrapped(StorageFaultSpec::default(), 6);
        let blk = block(5);
        fs.put_block("k", blk.clone());
        // Tamper behind the wrapper's back: rewrite the key through the
        // inner store with one payload bit flipped.
        let mut wire = blk.to_wire();
        let last = wire.len() - 1;
        wire[last] ^= 0x40;
        inner.put("k", wire);
        assert!(matches!(
            fs.try_get_block("k"),
            Err(StorageError::Corrupt { .. })
        ));
        // Byte-surface reads also refuse the tampered payload.
        assert!(fs.get("k").is_none());
    }

    #[test]
    fn fault_draws_are_deterministic_per_seed() {
        let spec = StorageFaultSpec {
            transient_p: 0.3,
            loss_p: 0.1,
            corrupt_p: 0.2,
            ..StorageFaultSpec::default()
        };
        let run = |seed: u64| {
            let (_inner, fs) = wrapped(spec, seed);
            let mut outcomes = Vec::new();
            for i in 0..32 {
                let key = format!("k{i}");
                fs.put_block(&key, block(i));
                outcomes.push(match fs.try_get_block(&key) {
                    Ok(_) => "ok",
                    Err(StorageError::NotFound { .. }) => "lost",
                    Err(StorageError::Corrupt { .. }) => "corrupt",
                    Err(StorageError::Transient { .. }) => "transient",
                });
            }
            (outcomes, fs.metrics())
        };
        let (a, ma) = run(11);
        let (b, mb) = run(11);
        assert_eq!(a, b);
        assert_eq!(ma, mb);
        // A different seed draws a different fault pattern.
        let (c, _) = run(12);
        assert_ne!(a, c);
    }

    #[test]
    fn retry_policy_backoff_doubles() {
        let p = RetryPolicy {
            max_retries: 3,
            backoff_s: 0.5,
        };
        assert_eq!(p.backoff(1), 0.5);
        assert_eq!(p.backoff(2), 1.0);
        assert_eq!(p.backoff(3), 2.0);
        let d = RetryPolicy::default();
        assert_eq!(d.max_retries, 3);
        assert_eq!(d.backoff_s, 1.0);
    }

    #[test]
    fn metrics_fold_and_serialize() {
        let mut a = StorageFaultMetrics {
            transients: 1,
            retries: 2,
            lost: 1,
            corrupt: 0,
            recovered_via_parity: 1,
        };
        let b = StorageFaultMetrics {
            transients: 2,
            retries: 1,
            lost: 0,
            corrupt: 3,
            recovered_via_parity: 0,
        };
        a.add(&b);
        assert_eq!(a.transients, 3);
        assert_eq!(a.retries, 3);
        assert_eq!(a.lost, 1);
        assert_eq!(a.corrupt, 3);
        assert_eq!(a.recovered_via_parity, 1);
        let j = a.to_json();
        assert_eq!(j.get("transients").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("recovered_via_parity").unwrap().as_u64(), Some(1));
    }
}

//! Simulated cloud object storage (the S3 substitute).
//!
//! Serverless workers are stateless: all inputs, coded blocks, task
//! results and decoded outputs flow through this store, exactly as the
//! paper's workflow (Fig 2) routes everything through S3. The default
//! backend is [`MemStore`]: a sharded in-memory blob store with chunked
//! put/get, hit/miss + bytes-moved accounting, and per-shard load
//! counters so hot-spotting is observable. An optional LRU read-through
//! cache ([`cache::CachedStore`]) sits in front of it, and
//! [`transfer::TransferModel`] converts object movement into virtual
//! seconds with the single-stream caps the figure harnesses calibrate.
//!
//! Submodules:
//! - [`cache`] — LRU read-through block cache over any [`ObjectStore`].
//! - [`faults`] — deterministic fault injection ([`faults::FaultyStore`])
//!   and the typed error surface ([`faults::StorageError`]) of fallible
//!   block reads, plus the FNV-1a integrity digest.
//! - [`transfer`] — per-object latency/bandwidth timing with
//!   single-stream caps (fig3/fig10–11 S3 calibrations).
//! - [`cost`] — the original aggregate I/O → virtual-seconds model used
//!   by the straggler sampler (kept as the per-worker baseline).

pub mod cache;
pub mod cost;
pub mod faults;
pub mod transfer;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::linalg::matrix::BlockBuf;

/// Operation counters exposed by every store.
#[derive(Debug, Default)]
pub struct StoreStats {
    pub puts: AtomicU64,
    pub gets: AtomicU64,
    pub deletes: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
    /// Gets that found the key.
    pub hits: AtomicU64,
    /// Gets that found nothing.
    pub misses: AtomicU64,
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub puts: u64,
    pub gets: u64,
    pub deletes: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub hits: u64,
    pub misses: u64,
}

impl StoreStats {
    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            puts: self.puts.load(Ordering::Relaxed),
            gets: self.gets.load(Ordering::Relaxed),
            deletes: self.deletes.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
        }
    }
}

/// Blob store abstraction. Payloads are shared (`Arc<Vec<u8>>`) so
/// many simulated workers can read the same block without copying.
///
/// Matrix blocks additionally move through the **zero-copy block
/// surface** ([`ObjectStore::put_block`] / [`ObjectStore::get_block`]):
/// a [`BlockBuf`]'s shared payload is handed to and from the store as a
/// refcount bump, while `puts`/`gets`/`bytes_in`/`bytes_out` keep
/// reporting the *logical* wire size ([`BlockBuf::wire_len`]) so traffic
/// accounting is representation-independent. The default methods fall
/// back to serialize/parse through the byte surface, so third-party
/// stores stay correct without opting in; [`MemStore`] overrides both
/// with genuinely shared storage, and byte-oriented `get`s of a
/// block-staged key materialize the wire format on demand.
pub trait ObjectStore: Send + Sync {
    fn put(&self, key: &str, value: Vec<u8>);
    fn get(&self, key: &str) -> Option<Arc<Vec<u8>>>;
    fn exists(&self, key: &str) -> bool;
    fn delete(&self, key: &str) -> bool;
    /// Keys with the given prefix, sorted.
    fn list(&self, prefix: &str) -> Vec<String>;
    fn stats(&self) -> StatsSnapshot;

    /// Stage a matrix block. Default: serialize through [`ObjectStore::put`].
    fn put_block(&self, key: &str, block: BlockBuf) {
        self.put(key, block.to_wire());
    }

    /// Fetch a matrix block. Default: parse through [`ObjectStore::get`]
    /// (a non-wire payload reads as absent).
    fn get_block(&self, key: &str) -> Option<BlockBuf> {
        self.get(key).and_then(|b| BlockBuf::from_wire(&b).ok())
    }

    /// Fallible block fetch — the surface the driver's retry and
    /// erasure-recovery machinery consumes. Plain stores never throttle
    /// or corrupt, so the default maps a miss to
    /// [`faults::StorageError::NotFound`] and everything else to `Ok`;
    /// [`faults::FaultyStore`] overrides this with the full typed
    /// vocabulary.
    fn try_get_block(&self, key: &str) -> Result<BlockBuf, faults::StorageError> {
        self.get_block(key).ok_or_else(|| faults::StorageError::NotFound {
            key: key.to_string(),
        })
    }
}

/// Default shard count of [`MemStore::new`].
pub const DEFAULT_SHARDS: usize = 16;

/// FNV-1a shard placement — the one routing rule shared by the real
/// [`MemStore`] and the scenario storage timing model
/// (`platform::scenario`), so simulated hot shards are the shards the
/// real store would actually hit.
pub fn shard_of(key: &str, shards: usize) -> usize {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in key.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    (h % shards.max(1) as u64) as usize
}

/// Separator of internal chunk keys. User keys are slash-delimited ASCII
/// paths (see [`keys`]), so a control byte can never collide.
const CHUNK_SEP: char = '\u{1}';

fn chunk_key(key: &str, i: usize) -> String {
    format!("{key}{CHUNK_SEP}{i:06}")
}

/// One stored record: a small object inline in its home shard, a large
/// object as a manifest plus chunks spread across shards, one such chunk
/// (internal key, invisible to `list`/`exists`), or a zero-copy matrix
/// block sharing its payload with the writer.
#[derive(Debug, Clone)]
enum Entry {
    Inline(Arc<Vec<u8>>),
    Manifest { len: usize, chunks: usize },
    Chunk(Arc<Vec<u8>>),
    Block(BlockBuf),
}

/// What [`MemStore::fetch`] found under a key: raw bytes or a shared
/// block handle.
enum Payload {
    Bytes(Arc<Vec<u8>>),
    Block(BlockBuf),
}

impl Payload {
    /// Logical byte size (wire size for blocks).
    fn len(&self) -> usize {
        match self {
            Payload::Bytes(b) => b.len(),
            Payload::Block(b) => b.wire_len(),
        }
    }
}

/// Per-shard traffic counters (reads + writes that touched the shard).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardLoad {
    pub ops: u64,
    pub bytes: u64,
}

/// Sharded in-memory object store.
///
/// - `shards` independent `RwLock`ed maps; a key's *home* shard is
///   [`shard_of`] of the key.
/// - With `chunk_bytes > 0`, objects larger than one chunk are split and
///   the chunks spread across shards by [`shard_of`] of the chunk key
///   (S3 multipart), so one large object's bandwidth is not served by a
///   single shard.
/// - Every operation updates global [`StoreStats`] and per-shard
///   [`ShardLoad`] counters; the latter is how the storage-contention
///   scenario observes hot-spotting.
pub struct MemStore {
    shards: Vec<RwLock<HashMap<String, Entry>>>,
    stats: StoreStats,
    loads: Vec<ShardLoadCells>,
    chunk_bytes: usize,
}

#[derive(Debug, Default)]
struct ShardLoadCells {
    ops: AtomicU64,
    bytes: AtomicU64,
}

/// The historical name of the default backend; kept so existing call
/// sites and docs keep compiling.
pub type InMemoryStore = MemStore;

impl Default for MemStore {
    fn default() -> Self {
        Self::new()
    }
}

impl MemStore {
    /// Default store: [`DEFAULT_SHARDS`] shards, no chunking.
    pub fn new() -> MemStore {
        MemStore::with_config(DEFAULT_SHARDS, 0)
    }

    /// `shards` shards (min 1); `chunk_bytes = 0` disables chunking.
    pub fn with_config(shards: usize, chunk_bytes: usize) -> MemStore {
        let shards = shards.max(1);
        MemStore {
            shards: (0..shards).map(|_| RwLock::new(HashMap::new())).collect(),
            stats: StoreStats::default(),
            loads: (0..shards).map(|_| ShardLoadCells::default()).collect(),
            chunk_bytes,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn chunk_bytes(&self) -> usize {
        self.chunk_bytes
    }

    /// Per-shard traffic so far (index = shard id).
    pub fn shard_loads(&self) -> Vec<ShardLoad> {
        self.loads
            .iter()
            .map(|c| ShardLoad {
                ops: c.ops.load(Ordering::Relaxed),
                bytes: c.bytes.load(Ordering::Relaxed),
            })
            .collect()
    }

    fn touch(&self, shard: usize, bytes: usize) {
        self.loads[shard].ops.fetch_add(1, Ordering::Relaxed);
        self.loads[shard]
            .bytes
            .fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Entry lookup with per-shard load accounting (no global counters):
    /// raw bytes for inline objects, the shared handle for zero-copy
    /// blocks, reassembled bytes for multipart objects (`None` on a torn
    /// overwrite in flight).
    fn fetch(&self, key: &str) -> Option<Payload> {
        let home = shard_of(key, self.n_shards());
        let entry = self.shards[home].read().unwrap().get(key).cloned();
        match entry {
            Some(Entry::Inline(b)) => {
                self.touch(home, b.len());
                Some(Payload::Bytes(b))
            }
            Some(Entry::Block(b)) => {
                self.touch(home, b.wire_len());
                Some(Payload::Block(b))
            }
            Some(Entry::Manifest { len, chunks }) => {
                let mut out = Vec::with_capacity(len);
                for i in 0..chunks {
                    let ck = chunk_key(key, i);
                    let s = shard_of(&ck, self.n_shards());
                    match self.shards[s].read().unwrap().get(&ck) {
                        Some(Entry::Chunk(part)) => {
                            self.touch(s, part.len());
                            out.extend_from_slice(part);
                        }
                        // Torn overwrite in flight: treat as absent.
                        _ => return None,
                    }
                }
                Some(Payload::Bytes(Arc::new(out)))
            }
            _ => None,
        }
    }

    /// Global get accounting shared by `get`/`get_block`: one `gets`
    /// tick, then a hit moving `len` logical bytes or a miss.
    fn count_get(&self, found_len: Option<usize>) {
        self.stats.gets.fetch_add(1, Ordering::Relaxed);
        match found_len {
            Some(len) => {
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                self.stats
                    .bytes_out
                    .fetch_add(len as u64, Ordering::Relaxed);
            }
            None => {
                self.stats.misses.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Remove `key` and any chunks it owned. Never holds two shard locks
    /// at once.
    fn remove_entry(&self, key: &str) -> bool {
        let home = shard_of(key, self.n_shards());
        let old = self.shards[home].write().unwrap().remove(key);
        match old {
            None => false,
            Some(Entry::Inline(_)) | Some(Entry::Chunk(_)) | Some(Entry::Block(_)) => true,
            Some(Entry::Manifest { chunks, .. }) => {
                for i in 0..chunks {
                    let ck = chunk_key(key, i);
                    let s = shard_of(&ck, self.n_shards());
                    self.shards[s].write().unwrap().remove(&ck);
                }
                true
            }
        }
    }
}

impl ObjectStore for MemStore {
    fn put(&self, key: &str, value: Vec<u8>) {
        debug_assert!(
            !key.contains(CHUNK_SEP),
            "user keys must not contain the internal chunk separator"
        );
        self.stats.puts.fetch_add(1, Ordering::Relaxed);
        self.stats
            .bytes_in
            .fetch_add(value.len() as u64, Ordering::Relaxed);
        // Drop any previous version first so overwrites never leave
        // stale chunks behind.
        self.remove_entry(key);
        let home = shard_of(key, self.n_shards());
        if self.chunk_bytes == 0 || value.len() <= self.chunk_bytes {
            self.touch(home, value.len());
            self.shards[home]
                .write()
                .unwrap()
                .insert(key.to_string(), Entry::Inline(Arc::new(value)));
            return;
        }
        // Multipart: chunks land on their own shards before the manifest
        // becomes visible in the home shard.
        let len = value.len();
        let chunks = len.div_ceil(self.chunk_bytes);
        for (i, part) in value.chunks(self.chunk_bytes).enumerate() {
            let ck = chunk_key(key, i);
            let s = shard_of(&ck, self.n_shards());
            self.touch(s, part.len());
            self.shards[s]
                .write()
                .unwrap()
                .insert(ck, Entry::Chunk(Arc::new(part.to_vec())));
        }
        self.touch(home, 0);
        self.shards[home]
            .write()
            .unwrap()
            .insert(key.to_string(), Entry::Manifest { len, chunks });
    }

    fn get(&self, key: &str) -> Option<Arc<Vec<u8>>> {
        let payload = self.fetch(key);
        self.count_get(payload.as_ref().map(Payload::len));
        payload.map(|p| match p {
            Payload::Bytes(b) => b,
            // Byte-oriented read of a block-staged key: materialize the
            // wire format on demand (the only remaining copy path).
            Payload::Block(b) => Arc::new(b.to_wire()),
        })
    }

    /// Zero-copy block staging: the shared payload moves into the store
    /// as a refcount bump. Blocks are never chunked — the handle is one
    /// allocation by construction — so the whole logical wire size is
    /// attributed to the home shard.
    fn put_block(&self, key: &str, block: BlockBuf) {
        debug_assert!(
            !key.contains(CHUNK_SEP),
            "user keys must not contain the internal chunk separator"
        );
        let wire = block.wire_len();
        self.stats.puts.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_in.fetch_add(wire as u64, Ordering::Relaxed);
        // Drop any previous version first so overwrites never leave
        // stale chunks behind.
        self.remove_entry(key);
        let home = shard_of(key, self.n_shards());
        self.touch(home, wire);
        self.shards[home]
            .write()
            .unwrap()
            .insert(key.to_string(), Entry::Block(block));
    }

    /// Zero-copy block fetch: a block-staged key returns the shared
    /// handle (refcount bump); a byte-staged key parses the wire format.
    /// Either way the counters report the logical wire size, and a
    /// non-wire byte payload counts as a miss (hit ⇒ `Some`, like `get`).
    fn get_block(&self, key: &str) -> Option<BlockBuf> {
        let block = self.fetch(key).and_then(|p| match p {
            Payload::Block(b) => Some(b),
            Payload::Bytes(b) => BlockBuf::from_wire(&b).ok(),
        });
        self.count_get(block.as_ref().map(BlockBuf::wire_len));
        block
    }

    fn exists(&self, key: &str) -> bool {
        let home = shard_of(key, self.n_shards());
        matches!(
            self.shards[home].read().unwrap().get(key),
            Some(Entry::Inline(_)) | Some(Entry::Manifest { .. }) | Some(Entry::Block(_))
        )
    }

    fn delete(&self, key: &str) -> bool {
        self.stats.deletes.fetch_add(1, Ordering::Relaxed);
        self.remove_entry(key)
    }

    fn list(&self, prefix: &str) -> Vec<String> {
        let mut keys: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| {
                s.read()
                    .unwrap()
                    .iter()
                    .filter(|(k, e)| {
                        k.starts_with(prefix)
                            && matches!(
                                e,
                                Entry::Inline(_) | Entry::Manifest { .. } | Entry::Block(_)
                            )
                    })
                    .map(|(k, _)| k.clone())
                    .collect::<Vec<_>>()
            })
            .collect();
        keys.sort();
        keys
    }

    fn stats(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }
}

/// Key-naming scheme for the coded matmul workflow — one place so tests,
/// workers and the coordinator agree.
pub mod keys {
    /// Coded row-block `i` of input side `side` ("a"/"b") for job `job`.
    pub fn coded_block(job: &str, side: &str, i: usize) -> String {
        format!("{job}/coded/{side}/{i:05}")
    }

    /// Output block (i, j) of the coded product grid.
    pub fn out_block(job: &str, i: usize, j: usize) -> String {
        format!("{job}/out/{i:05}x{j:05}")
    }

    /// Decoded systematic output block (i, j).
    pub fn result_block(job: &str, i: usize, j: usize) -> String {
        format!("{job}/result/{i:05}x{j:05}")
    }

    /// Matvec result block for coded row-block i.
    pub fn vec_block(job: &str, i: usize) -> String {
        format!("{job}/vec/{i:05}")
    }

    /// Key prefix owning every object a tenant's service jobs write,
    /// so per-tenant listings and rollups are one prefix scan.
    /// Anonymous jobs bill to the `"-"` pseudo-tenant.
    pub fn tenant_prefix(tenant: &str) -> String {
        format!("svc/{tenant}/")
    }

    /// Report manifest of service job `seq`, under its tenant's prefix.
    pub fn tenant_report(tenant: &str, seq: usize) -> String {
        format!("svc/{tenant}/job{seq:06}/report")
    }
}

/// Store a matrix under a key through the zero-copy block surface. The
/// owned-`&Matrix` signature forces one payload copy here (into the
/// shared handle); callers that already hold a [`BlockBuf`] should call
/// [`ObjectStore::put_block`] directly, which copies nothing.
pub fn put_matrix(store: &dyn ObjectStore, key: &str, m: &crate::linalg::Matrix) {
    store.put_block(key, BlockBuf::new(m.clone()));
}

/// Fetch a matrix through the block surface (parses the wire format only
/// when the key was byte-staged). The owned-`Matrix` return forces a copy
/// when the store still shares the payload; callers that can work with a
/// shared handle should call [`ObjectStore::get_block`] directly.
pub fn get_matrix(store: &dyn ObjectStore, key: &str) -> anyhow::Result<crate::linalg::Matrix> {
    let block = store
        .get_block(key)
        .ok_or_else(|| anyhow::anyhow!("missing object: {key}"))?;
    Ok(block.into_matrix())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::util::rng::Pcg64;

    #[test]
    fn put_get_roundtrip() {
        let s = MemStore::new();
        s.put("k1", vec![1, 2, 3]);
        assert_eq!(s.get("k1").unwrap().as_slice(), &[1, 2, 3]);
        assert!(s.exists("k1"));
        assert!(!s.exists("nope"));
        assert!(s.get("nope").is_none());
    }

    #[test]
    fn overwrite_and_delete() {
        let s = MemStore::new();
        s.put("k", vec![1]);
        s.put("k", vec![2, 3]);
        assert_eq!(s.get("k").unwrap().as_slice(), &[2, 3]);
        assert!(s.delete("k"));
        assert!(!s.delete("k"));
        assert!(s.get("k").is_none());
    }

    #[test]
    fn chunked_roundtrip_and_overwrite() {
        // 10-byte chunks over 4 shards: a 25-byte object spans 3 chunks.
        let s = MemStore::with_config(4, 10);
        let blob: Vec<u8> = (0..25u8).collect();
        s.put("big", blob.clone());
        assert_eq!(s.get("big").unwrap().as_slice(), blob.as_slice());
        assert!(s.exists("big"));
        // Internal chunk keys never leak into listings.
        assert_eq!(s.list(""), vec!["big"]);
        // Shrinking overwrite drops the stale chunks.
        s.put("big", vec![9; 5]);
        assert_eq!(s.get("big").unwrap().as_slice(), &[9; 5]);
        assert_eq!(s.list(""), vec!["big"]);
        assert!(s.delete("big"));
        assert!(s.get("big").is_none());
        // All chunks are gone: every shard map is empty.
        let total_ops: u64 = s.shard_loads().iter().map(|l| l.ops).sum();
        assert!(total_ops > 0);
        assert_eq!(s.list(""), Vec::<String>::new());
    }

    #[test]
    fn list_prefix_sorted() {
        let s = MemStore::new();
        for k in ["job/out/2", "job/out/1", "job/in/1", "other/x"] {
            s.put(k, vec![0]);
        }
        assert_eq!(s.list("job/out/"), vec!["job/out/1", "job/out/2"]);
        assert_eq!(s.list("job/").len(), 3);
        assert_eq!(s.list("zzz").len(), 0);
    }

    #[test]
    fn stats_accumulate() {
        let s = MemStore::new();
        s.put("a", vec![0u8; 100]);
        s.put("b", vec![0u8; 50]);
        let _ = s.get("a");
        let _ = s.get("missing"); // missing get counts a miss, no bytes
        s.delete("b");
        let st = s.stats();
        assert_eq!(st.puts, 2);
        assert_eq!(st.gets, 2);
        assert_eq!(st.hits, 1);
        assert_eq!(st.misses, 1);
        assert_eq!(st.deletes, 1);
        assert_eq!(st.bytes_in, 150);
        assert_eq!(st.bytes_out, 100);
    }

    #[test]
    fn shard_loads_cover_all_traffic() {
        let s = MemStore::with_config(8, 0);
        for i in 0..64 {
            s.put(&format!("k{i}"), vec![0u8; 10]);
        }
        let loads = s.shard_loads();
        assert_eq!(loads.len(), 8);
        let bytes: u64 = loads.iter().map(|l| l.bytes).sum();
        assert_eq!(bytes, 640);
        // FNV-1a spreads sequential keys: no shard holds everything.
        assert!(loads.iter().all(|l| l.bytes < 640));
    }

    #[test]
    fn shard_of_is_stable_and_in_range() {
        // The placement rule is shared with the scenario timing model:
        // pin a few values so refactors can't silently remap shards.
        let first = shard_of("job/coded/a/00000", 16);
        assert_eq!(first, shard_of("job/coded/a/00000", 16));
        for k in ["a", "b", "job/out/00001x00002"] {
            assert!(shard_of(k, 4) < 4);
            assert!(shard_of(k, 1) == 0);
        }
    }

    #[test]
    fn matrix_helpers() {
        let s = MemStore::with_config(4, 64); // chunk matrices too
        let mut rng = Pcg64::new(1);
        let m = Matrix::randn(4, 6, &mut rng, 0.0, 1.0);
        put_matrix(&s, "m", &m);
        let back = get_matrix(&s, "m").unwrap();
        assert_eq!(m, back);
        assert!(get_matrix(&s, "absent").is_err());
    }

    #[test]
    fn concurrent_access() {
        let s = Arc::new(MemStore::with_config(16, 32));
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for i in 0..100 {
                    s.put(&format!("t{t}/k{i}"), vec![t as u8; 50]);
                    assert!(s.get(&format!("t{t}/k{i}")).is_some());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.stats().puts, 800);
        assert_eq!(s.stats().hits, 800);
        assert_eq!(s.list("t3/").len(), 100);
    }

    #[test]
    fn block_staging_is_zero_copy_and_counts_logical_bytes() {
        let s = MemStore::with_config(4, 32); // chunking must not apply to blocks
        let mut rng = Pcg64::new(2);
        let blk = BlockBuf::new(Matrix::randn(8, 8, &mut rng, 0.0, 1.0));
        s.put_block("blk", blk.clone());
        let back = s.get_block("blk").unwrap();
        // The store handed back the very allocation we staged.
        assert!(BlockBuf::ptr_eq(&blk, &back));
        assert!(s.exists("blk"));
        assert_eq!(s.list(""), vec!["blk"]);
        // Counters report the logical wire size in both directions even
        // though no payload bytes moved.
        let st = s.stats();
        assert_eq!(st.puts, 1);
        assert_eq!(st.gets, 1);
        assert_eq!(st.hits, 1);
        assert_eq!(st.bytes_in, blk.wire_len() as u64);
        assert_eq!(st.bytes_out, blk.wire_len() as u64);
        assert!(s.delete("blk"));
        assert!(s.get_block("blk").is_none());
    }

    #[test]
    fn byte_and_block_surfaces_interoperate() {
        let s = MemStore::new();
        let mut rng = Pcg64::new(3);
        let blk = BlockBuf::new(Matrix::randn(5, 7, &mut rng, 0.0, 1.0));
        // Block-staged key read through the byte surface materializes the
        // wire format on demand.
        s.put_block("b", blk.clone());
        assert_eq!(s.get("b").unwrap().as_slice(), blk.to_wire().as_slice());
        // Byte-staged wire format read through the block surface parses.
        s.put("w", blk.to_wire());
        let parsed = s.get_block("w").unwrap();
        assert!(!BlockBuf::ptr_eq(&blk, &parsed));
        assert_eq!(parsed.as_matrix(), blk.as_matrix());
        // Non-wire bytes read as absent on the block surface (but the
        // byte surface still sees them).
        s.put("junk", vec![1, 2, 3]);
        assert!(s.get_block("junk").is_none());
        assert!(s.get("junk").is_some());
        // Overwriting a block with bytes (and back) never leaves both.
        s.put("b", vec![9; 4]);
        assert_eq!(s.get("b").unwrap().as_slice(), &[9; 4]);
        s.put_block("w", blk.clone());
        assert!(BlockBuf::ptr_eq(&s.get_block("w").unwrap(), &blk));
        assert_eq!(s.list("").len(), 3);
    }

    #[test]
    fn key_scheme_stable() {
        assert_eq!(keys::coded_block("j", "a", 3), "j/coded/a/00003");
        assert_eq!(keys::out_block("j", 1, 2), "j/out/00001x00002");
        assert_eq!(keys::result_block("j", 0, 0), "j/result/00000x00000");
        assert_eq!(keys::vec_block("j", 9), "j/vec/00009");
    }
}

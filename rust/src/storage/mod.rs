//! Simulated cloud object storage (the S3 substitute).
//!
//! Serverless workers are stateless: all inputs, coded blocks, task
//! results and decoded outputs flow through this store, exactly as the
//! paper's workflow (Fig 2) routes everything through S3. The in-memory
//! implementation is sharded for concurrency and counts bytes/ops so the
//! cost model can convert I/O into virtual time and EXPERIMENTS.md can
//! report communication volumes.

pub mod cost;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Operation counters exposed by every store.
#[derive(Debug, Default)]
pub struct StoreStats {
    pub puts: AtomicU64,
    pub gets: AtomicU64,
    pub deletes: AtomicU64,
    pub bytes_in: AtomicU64,
    pub bytes_out: AtomicU64,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub puts: u64,
    pub gets: u64,
    pub deletes: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

impl StoreStats {
    pub fn snapshot(&self) -> StatsSnapshot {
        StatsSnapshot {
            puts: self.puts.load(Ordering::Relaxed),
            gets: self.gets.load(Ordering::Relaxed),
            deletes: self.deletes.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
        }
    }
}

/// Blob store abstraction. Payloads are shared (`Arc<Vec<u8>>`) so
/// many simulated workers can read the same block without copying.
pub trait ObjectStore: Send + Sync {
    fn put(&self, key: &str, value: Vec<u8>);
    fn get(&self, key: &str) -> Option<Arc<Vec<u8>>>;
    fn exists(&self, key: &str) -> bool;
    fn delete(&self, key: &str) -> bool;
    /// Keys with the given prefix, sorted.
    fn list(&self, prefix: &str) -> Vec<String>;
    fn stats(&self) -> StatsSnapshot;
}

const SHARDS: usize = 16;

/// Sharded in-memory object store.
pub struct InMemoryStore {
    shards: Vec<RwLock<HashMap<String, Arc<Vec<u8>>>>>,
    stats: StoreStats,
}

impl Default for InMemoryStore {
    fn default() -> Self {
        Self::new()
    }
}

impl InMemoryStore {
    pub fn new() -> InMemoryStore {
        InMemoryStore {
            shards: (0..SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
            stats: StoreStats::default(),
        }
    }

    fn shard(&self, key: &str) -> &RwLock<HashMap<String, Arc<Vec<u8>>>> {
        // FNV-1a over the key.
        let mut h: u64 = 0xcbf29ce484222325;
        for b in key.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        &self.shards[(h % SHARDS as u64) as usize]
    }
}

impl ObjectStore for InMemoryStore {
    fn put(&self, key: &str, value: Vec<u8>) {
        self.stats.puts.fetch_add(1, Ordering::Relaxed);
        self.stats
            .bytes_in
            .fetch_add(value.len() as u64, Ordering::Relaxed);
        self.shard(key)
            .write()
            .unwrap()
            .insert(key.to_string(), Arc::new(value));
    }

    fn get(&self, key: &str) -> Option<Arc<Vec<u8>>> {
        let v = self.shard(key).read().unwrap().get(key).cloned();
        if let Some(ref blob) = v {
            self.stats.gets.fetch_add(1, Ordering::Relaxed);
            self.stats
                .bytes_out
                .fetch_add(blob.len() as u64, Ordering::Relaxed);
        }
        v
    }

    fn exists(&self, key: &str) -> bool {
        self.shard(key).read().unwrap().contains_key(key)
    }

    fn delete(&self, key: &str) -> bool {
        self.stats.deletes.fetch_add(1, Ordering::Relaxed);
        self.shard(key).write().unwrap().remove(key).is_some()
    }

    fn list(&self, prefix: &str) -> Vec<String> {
        let mut keys: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| {
                s.read()
                    .unwrap()
                    .keys()
                    .filter(|k| k.starts_with(prefix))
                    .cloned()
                    .collect::<Vec<_>>()
            })
            .collect();
        keys.sort();
        keys
    }

    fn stats(&self) -> StatsSnapshot {
        self.stats.snapshot()
    }
}

/// Key-naming scheme for the coded matmul workflow — one place so tests,
/// workers and the coordinator agree.
pub mod keys {
    /// Coded row-block `i` of input side `side` ("a"/"b") for job `job`.
    pub fn coded_block(job: &str, side: &str, i: usize) -> String {
        format!("{job}/coded/{side}/{i:05}")
    }

    /// Output block (i, j) of the coded product grid.
    pub fn out_block(job: &str, i: usize, j: usize) -> String {
        format!("{job}/out/{i:05}x{j:05}")
    }

    /// Decoded systematic output block (i, j).
    pub fn result_block(job: &str, i: usize, j: usize) -> String {
        format!("{job}/result/{i:05}x{j:05}")
    }

    /// Matvec result block for coded row-block i.
    pub fn vec_block(job: &str, i: usize) -> String {
        format!("{job}/vec/{i:05}")
    }
}

/// Store a matrix under a key (wire format from `Matrix::to_bytes`).
pub fn put_matrix(store: &dyn ObjectStore, key: &str, m: &crate::linalg::Matrix) {
    store.put(key, m.to_bytes());
}

/// Fetch + parse a matrix.
pub fn get_matrix(store: &dyn ObjectStore, key: &str) -> anyhow::Result<crate::linalg::Matrix> {
    let blob = store
        .get(key)
        .ok_or_else(|| anyhow::anyhow!("missing object: {key}"))?;
    crate::linalg::Matrix::from_bytes(&blob)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::util::rng::Pcg64;

    #[test]
    fn put_get_roundtrip() {
        let s = InMemoryStore::new();
        s.put("k1", vec![1, 2, 3]);
        assert_eq!(s.get("k1").unwrap().as_slice(), &[1, 2, 3]);
        assert!(s.exists("k1"));
        assert!(!s.exists("nope"));
        assert!(s.get("nope").is_none());
    }

    #[test]
    fn overwrite_and_delete() {
        let s = InMemoryStore::new();
        s.put("k", vec![1]);
        s.put("k", vec![2, 3]);
        assert_eq!(s.get("k").unwrap().as_slice(), &[2, 3]);
        assert!(s.delete("k"));
        assert!(!s.delete("k"));
        assert!(s.get("k").is_none());
    }

    #[test]
    fn list_prefix_sorted() {
        let s = InMemoryStore::new();
        for k in ["job/out/2", "job/out/1", "job/in/1", "other/x"] {
            s.put(k, vec![0]);
        }
        assert_eq!(s.list("job/out/"), vec!["job/out/1", "job/out/2"]);
        assert_eq!(s.list("job/").len(), 3);
        assert_eq!(s.list("zzz").len(), 0);
    }

    #[test]
    fn stats_accumulate() {
        let s = InMemoryStore::new();
        s.put("a", vec![0u8; 100]);
        s.put("b", vec![0u8; 50]);
        let _ = s.get("a");
        let _ = s.get("missing"); // missing get doesn't count bytes
        s.delete("b");
        let st = s.stats();
        assert_eq!(st.puts, 2);
        assert_eq!(st.gets, 1);
        assert_eq!(st.deletes, 1);
        assert_eq!(st.bytes_in, 150);
        assert_eq!(st.bytes_out, 100);
    }

    #[test]
    fn matrix_helpers() {
        let s = InMemoryStore::new();
        let mut rng = Pcg64::new(1);
        let m = Matrix::randn(4, 6, &mut rng, 0.0, 1.0);
        put_matrix(&s, "m", &m);
        let back = get_matrix(&s, "m").unwrap();
        assert_eq!(m, back);
        assert!(get_matrix(&s, "absent").is_err());
    }

    #[test]
    fn concurrent_access() {
        let s = Arc::new(InMemoryStore::new());
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = Arc::clone(&s);
            handles.push(std::thread::spawn(move || {
                for i in 0..100 {
                    s.put(&format!("t{t}/k{i}"), vec![t as u8; 10]);
                    assert!(s.get(&format!("t{t}/k{i}")).is_some());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.stats().puts, 800);
        assert_eq!(s.list("t3/").len(), 100);
    }

    #[test]
    fn key_scheme_stable() {
        assert_eq!(keys::coded_block("j", "a", 3), "j/coded/a/00003");
        assert_eq!(keys::out_block("j", 1, 2), "j/out/00001x00002");
        assert_eq!(keys::result_block("j", 0, 0), "j/result/00000x00000");
        assert_eq!(keys::vec_block("j", 9), "j/vec/00009");
    }
}

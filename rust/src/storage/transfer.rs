//! Per-object transfer timing: latency + bandwidth with single-stream
//! caps.
//!
//! The figure harnesses calibrate three distinct S3 regimes (documented
//! inline in `figures/fig3.rs` and `figures/fig10_11.rs`):
//!
//! - **multipart** (`fig1`/`fig5` matmul blocks): many parallel GET
//!   streams per worker, ~100 MB/s aggregate — the [`cost::CostModel`]
//!   default.
//! - **single stream** (`fig3` power-iteration row-blocks): one GET per
//!   object at ~10 MB/s.
//! - **KRR row-blocks** (`fig10`/`fig11`): large single-stream reads
//!   that sustain ~25 MB/s.
//!
//! [`TransferModel`] makes the stream structure explicit instead of
//! collapsing it into one bandwidth number: an object moved over `s`
//! streams flows at `min(s · single_stream_bps, aggregate_bps)`. The
//! chunked [`super::MemStore`] maps onto this directly — a multipart
//! object's chunk count is its stream count.
//!
//! [`cost::CostModel`]: super::cost::CostModel

use super::cost::CostModel;

/// S3-like per-worker transfer model with an explicit stream structure.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransferModel {
    /// Per-operation request latency in seconds (round-trip).
    pub op_latency_s: f64,
    /// Throughput of one GET/PUT stream, bytes/second.
    pub single_stream_bps: f64,
    /// Streams one worker can keep in flight for one object.
    pub max_streams: u64,
    /// Per-worker NIC/aggregate cap across all streams, bytes/second.
    pub aggregate_bps: f64,
}

impl Default for TransferModel {
    fn default() -> Self {
        // Lambda↔S3 circa the paper: 60 ms request latency, ~10 MB/s per
        // stream, up to 10 parts in flight ⇒ the familiar ~100 MB/s
        // multipart figure of the fig1/fig5 calibration.
        TransferModel {
            op_latency_s: 0.060,
            single_stream_bps: 10e6,
            max_streams: 10,
            aggregate_bps: 100e6,
        }
    }
}

impl TransferModel {
    /// The fig3 calibration: power-iteration row-blocks are one single
    /// S3 stream (~10 MB/s effective GET throughput).
    pub fn fig3_single_stream() -> TransferModel {
        TransferModel {
            max_streams: 1,
            ..TransferModel::default()
        }
    }

    /// The fig10/fig11 calibration: large KRR row-block objects sustain
    /// ~25 MB/s on a single stream.
    pub fn fig10_11_krr() -> TransferModel {
        TransferModel {
            single_stream_bps: 25e6,
            max_streams: 1,
            aggregate_bps: 25e6,
            ..TransferModel::default()
        }
    }

    /// Effective bandwidth of an object moved over `streams` streams.
    pub fn effective_bps(&self, streams: u64) -> f64 {
        let s = streams.clamp(1, self.max_streams.max(1)) as f64;
        (s * self.single_stream_bps).min(self.aggregate_bps)
    }

    /// Time to move one object of `bytes` over `streams` parallel
    /// streams (one request round-trip; parts share it pipelined).
    pub fn object_time(&self, bytes: u64, streams: u64) -> f64 {
        self.op_latency_s + bytes as f64 / self.effective_bps(streams)
    }

    /// Single-stream read/write of one object — the fig3 regime.
    pub fn single_stream_time(&self, bytes: u64) -> f64 {
        self.object_time(bytes, 1)
    }

    /// Multipart transfer of one object split into `part_bytes` chunks
    /// (how the chunked `MemStore` stores it): the stream count is the
    /// chunk count, capped at `max_streams`.
    pub fn multipart_time(&self, bytes: u64, part_bytes: u64) -> f64 {
        let parts = if part_bytes == 0 {
            1
        } else {
            bytes.div_ceil(part_bytes).max(1)
        };
        self.object_time(bytes, parts)
    }

    /// `n_ops` sequential object reads totalling `bytes`, each over
    /// `streams` streams (e.g. a decode worker fetching R blocks).
    pub fn read_many(&self, n_ops: u64, bytes: u64, streams: u64) -> f64 {
        n_ops as f64 * self.op_latency_s + bytes as f64 / self.effective_bps(streams)
    }

    /// Collapse to the aggregate [`CostModel`] the straggler sampler
    /// consumes, at a fixed stream count.
    pub fn to_cost_model(&self, streams: u64) -> CostModel {
        CostModel {
            op_latency_s: self.op_latency_s,
            bandwidth_bps: self.effective_bps(streams),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_scaling_caps_at_aggregate() {
        let m = TransferModel::default();
        assert!((m.effective_bps(1) - 10e6).abs() < 1.0);
        assert!((m.effective_bps(5) - 50e6).abs() < 1.0);
        // 10 streams hit the aggregate cap; more streams are clamped.
        assert!((m.effective_bps(10) - 100e6).abs() < 1.0);
        assert!((m.effective_bps(64) - 100e6).abs() < 1.0);
        assert!((m.effective_bps(0) - 10e6).abs() < 1.0); // clamped up
    }

    #[test]
    fn object_time_decomposes() {
        let m = TransferModel {
            op_latency_s: 0.1,
            single_stream_bps: 1e6,
            max_streams: 4,
            aggregate_bps: 4e6,
        };
        // 2 MB over one stream: 0.1 + 2.0.
        assert!((m.single_stream_time(2_000_000) - 2.1).abs() < 1e-12);
        // Same object over 4 streams: 0.1 + 0.5.
        assert!((m.object_time(2_000_000, 4) - 0.6).abs() < 1e-12);
        // Multipart with 500 KB parts ⇒ 4 streams.
        assert!((m.multipart_time(2_000_000, 500_000) - 0.6).abs() < 1e-12);
        // Unchunked store (part_bytes = 0) degenerates to one stream.
        assert!((m.multipart_time(2_000_000, 0) - 2.1).abs() < 1e-12);
        // read_many accumulates latency only.
        assert!((m.read_many(10, 1_000_000, 1) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn presets_match_figure_calibrations() {
        // fig3: single stream at 10 MB/s.
        let f3 = TransferModel::fig3_single_stream();
        assert!((f3.effective_bps(10) - 10e6).abs() < 1.0);
        // fig10/11: 25 MB/s effective GET throughput.
        let krr = TransferModel::fig10_11_krr();
        assert!((krr.effective_bps(1) - 25e6).abs() < 1.0);
        // Default multipart collapses to the CostModel default.
        let cost = TransferModel::default().to_cost_model(10);
        let legacy = CostModel::default();
        assert!((cost.bandwidth_bps - legacy.bandwidth_bps).abs() < 1.0);
        assert!((cost.op_latency_s - legacy.op_latency_s).abs() < 1e-12);
    }
}

//! LRU read-through block cache over any [`ObjectStore`].
//!
//! The paper's decode phase re-reads the same parity blocks from S3 many
//! times (every peeling step touches a line of blocks); a warm
//! coordinator-side cache turns those repeats into local hits. The cache
//! is byte-bounded and strictly *read-through*: `get` fills it, `put` and
//! `delete` invalidate, so a [`CachedStore`] is always coherent with its
//! backing store (single-writer workflows, like the job pipeline).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::{ObjectStore, StatsSnapshot};
use crate::linalg::matrix::BlockBuf;

/// One cached payload: raw bytes, or a shared matrix-block handle (the
/// zero-copy pipeline caches the handle itself — a hit is a refcount
/// bump, never a payload copy). Byte accounting uses the logical wire
/// size either way, so the byte bound means the same thing for both.
#[derive(Clone)]
pub enum Cached {
    Bytes(Arc<Vec<u8>>),
    Block(BlockBuf),
}

impl Cached {
    /// Logical byte size (wire size for blocks).
    pub fn len(&self) -> usize {
        match self {
            Cached::Bytes(b) => b.len(),
            Cached::Block(b) => b.wire_len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialize raw bytes (allocates for a block — the byte-surface
    /// compatibility path only).
    pub fn into_bytes(self) -> Arc<Vec<u8>> {
        match self {
            Cached::Bytes(b) => b,
            Cached::Block(b) => Arc::new(b.to_wire()),
        }
    }
}

/// Cache counters (monotonic, like [`super::StoreStats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
    /// Bytes currently resident.
    pub bytes: u64,
}

/// Byte-bounded LRU of shared blobs.
///
/// Recency is tracked lazily: each access pushes a `(key, generation)`
/// pair onto the order queue and bumps the key's generation; eviction
/// pops from the front, skipping pairs whose generation is stale. This
/// keeps both `get` and `insert` O(1) amortized with one small mutex.
pub struct BlockCache {
    cap_bytes: usize,
    inner: Mutex<LruInner>,
    hits: AtomicU64,
    misses: AtomicU64,
    insertions: AtomicU64,
    evictions: AtomicU64,
}

#[derive(Default)]
struct LruInner {
    map: HashMap<String, (Cached, u64)>,
    order: VecDeque<(String, u64)>,
    bytes: usize,
    tick: u64,
}

/// Drop stale order-queue pairs once the queue outgrows the map; keeps
/// the lazy-LRU bookkeeping O(resident entries) over long runs.
fn compact(inner: &mut LruInner) {
    if inner.order.len() > 4 * inner.map.len() + 64 {
        let map = &inner.map;
        inner
            .order
            .retain(|(k, generation)| matches!(map.get(k), Some((_, g)) if g == generation));
    }
}

impl BlockCache {
    pub fn new(cap_bytes: usize) -> BlockCache {
        BlockCache {
            cap_bytes,
            inner: Mutex::new(LruInner::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    pub fn capacity_bytes(&self) -> usize {
        self.cap_bytes
    }

    /// Look a key up as raw bytes, refreshing its recency on a hit (a
    /// cached block materializes its wire format — the byte-surface
    /// compatibility path; zero-copy readers use [`BlockCache::get_entry`]).
    pub fn get(&self, key: &str) -> Option<Arc<Vec<u8>>> {
        self.get_entry(key).map(Cached::into_bytes)
    }

    /// Look a key up, refreshing its recency on a hit.
    pub fn get_entry(&self, key: &str) -> Option<Cached> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(key) {
            Some((entry, generation)) => {
                *generation = tick;
                let entry = entry.clone();
                inner.order.push_back((key.to_string(), tick));
                compact(&mut inner);
                drop(inner);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(entry)
            }
            None => {
                drop(inner);
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert a byte blob (see [`BlockCache::insert_entry`]).
    pub fn insert(&self, key: &str, blob: Arc<Vec<u8>>) {
        self.insert_entry(key, Cached::Bytes(blob));
    }

    /// Insert a payload, evicting LRU entries past the byte capacity.
    /// Payloads larger than the whole cache are not admitted.
    pub fn insert_entry(&self, key: &str, entry: Cached) {
        if entry.len() > self.cap_bytes {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some((old, _)) = inner.map.remove(key) {
            inner.bytes -= old.len();
        }
        inner.bytes += entry.len();
        inner.map.insert(key.to_string(), (entry, tick));
        inner.order.push_back((key.to_string(), tick));
        self.insertions.fetch_add(1, Ordering::Relaxed);
        let mut evicted = 0u64;
        while inner.bytes > self.cap_bytes {
            let (victim, generation) = inner
                .order
                .pop_front()
                .expect("over-capacity cache must have queued entries");
            let is_current = matches!(inner.map.get(&victim), Some((_, g)) if *g == generation);
            if is_current {
                let (blob, _) = inner.map.remove(&victim).unwrap();
                inner.bytes -= blob.len();
                evicted += 1;
            }
            // Stale generation: a newer access re-queued the key; skip.
        }
        compact(&mut inner);
        drop(inner);
        if evicted > 0 {
            self.evictions.fetch_add(evicted, Ordering::Relaxed);
        }
    }

    /// Drop a key (store writes/deletes invalidate).
    pub fn invalidate(&self, key: &str) {
        let mut inner = self.inner.lock().unwrap();
        if let Some((old, _)) = inner.map.remove(key) {
            inner.bytes -= old.len();
        }
    }

    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            insertions: self.insertions.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            bytes: inner.bytes as u64,
        }
    }

    /// Number of resident entries.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// An [`ObjectStore`] wrapper serving reads through a [`BlockCache`].
///
/// `stats()` delegates to the backing store, so store-level `gets`
/// count only the reads the cache could not absorb; cache traffic is
/// reported separately via [`CachedStore::cache`].
pub struct CachedStore {
    inner: Arc<dyn ObjectStore>,
    cache: Arc<BlockCache>,
}

impl CachedStore {
    pub fn new(inner: Arc<dyn ObjectStore>, cap_bytes: usize) -> CachedStore {
        CachedStore {
            inner,
            cache: Arc::new(BlockCache::new(cap_bytes)),
        }
    }

    /// Shared handle to the cache (for stats reporting).
    pub fn cache(&self) -> Arc<BlockCache> {
        Arc::clone(&self.cache)
    }

    /// The backing store.
    pub fn backing(&self) -> &Arc<dyn ObjectStore> {
        &self.inner
    }
}

impl ObjectStore for CachedStore {
    fn put(&self, key: &str, value: Vec<u8>) {
        // Write-invalidate keeps the cache coherent without double
        // accounting the bytes as reads.
        self.cache.invalidate(key);
        self.inner.put(key, value);
    }

    fn get(&self, key: &str) -> Option<Arc<Vec<u8>>> {
        if let Some(entry) = self.cache.get_entry(key) {
            return Some(entry.into_bytes());
        }
        let blob = self.inner.get(key)?;
        self.cache.insert(key, Arc::clone(&blob));
        Some(blob)
    }

    fn put_block(&self, key: &str, block: BlockBuf) {
        self.cache.invalidate(key);
        self.inner.put_block(key, block);
    }

    fn get_block(&self, key: &str) -> Option<BlockBuf> {
        if let Some(entry) = self.cache.get_entry(key) {
            return match entry {
                // Cached handle: the hit is a refcount bump.
                Cached::Block(b) => Some(b),
                // Key was cached through the byte surface: parse once and
                // upgrade the entry so later block hits are refcount bumps
                // again.
                Cached::Bytes(b) => {
                    let block = BlockBuf::from_wire(&b).ok()?;
                    self.cache.insert_entry(key, Cached::Block(block.clone()));
                    Some(block)
                }
            };
        }
        let block = self.inner.get_block(key)?;
        self.cache.insert_entry(key, Cached::Block(block.clone()));
        Some(block)
    }

    fn exists(&self, key: &str) -> bool {
        self.inner.exists(key)
    }

    fn delete(&self, key: &str) -> bool {
        self.cache.invalidate(key);
        self.inner.delete(key)
    }

    fn list(&self, prefix: &str) -> Vec<String> {
        self.inner.list(prefix)
    }

    fn stats(&self) -> StatsSnapshot {
        self.inner.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::MemStore;

    fn blob(n: usize, fill: u8) -> Vec<u8> {
        vec![fill; n]
    }

    #[test]
    fn read_through_fills_and_hits() {
        let mem = Arc::new(MemStore::new());
        let s = CachedStore::new(mem.clone(), 1024);
        s.put("k", blob(10, 1));
        assert_eq!(s.get("k").unwrap().len(), 10); // miss → fill
        assert_eq!(s.get("k").unwrap().len(), 10); // hit
        let cs = s.cache().stats();
        assert_eq!(cs.hits, 1);
        assert_eq!(cs.misses, 1);
        assert_eq!(cs.insertions, 1);
        // The second read never reached the backing store.
        assert_eq!(mem.stats().gets, 1);
        assert_eq!(s.stats().gets, 1);
    }

    #[test]
    fn put_invalidates_stale_entry() {
        let s = CachedStore::new(Arc::new(MemStore::new()), 1024);
        s.put("k", blob(4, 1));
        let _ = s.get("k");
        s.put("k", blob(4, 2));
        assert_eq!(s.get("k").unwrap().as_slice(), &[2, 2, 2, 2]);
        s.delete("k");
        assert!(s.get("k").is_none());
        // A miss on the backing store must not poison the cache.
        assert_eq!(s.cache().len(), 0);
    }

    #[test]
    fn lru_evicts_cold_entries_in_order() {
        let c = BlockCache::new(30);
        c.insert("a", Arc::new(blob(10, 0)));
        c.insert("b", Arc::new(blob(10, 0)));
        c.insert("c", Arc::new(blob(10, 0)));
        // Touch "a" so "b" is now the LRU victim.
        assert!(c.get("a").is_some());
        c.insert("d", Arc::new(blob(10, 0)));
        assert!(c.get("b").is_none(), "b was LRU and must be evicted");
        assert!(c.get("a").is_some());
        assert!(c.get("c").is_some());
        assert!(c.get("d").is_some());
        let st = c.stats();
        assert_eq!(st.evictions, 1);
        assert_eq!(st.bytes, 30);
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn accounting_invariants() {
        let c = BlockCache::new(100);
        for i in 0..50 {
            c.insert(&format!("k{i}"), Arc::new(blob(10, 0)));
            let _ = c.get(&format!("k{i}"));
            let _ = c.get("never-present");
            let st = c.stats();
            assert!(st.bytes <= 100, "capacity respected: {}", st.bytes);
            assert_eq!(st.hits + st.misses, 2 * (i as u64 + 1));
            // Residents = insertions − evictions (no invalidations here).
            assert_eq!(c.len() as u64, st.insertions - st.evictions);
        }
    }

    #[test]
    fn oversize_blobs_are_not_admitted() {
        let c = BlockCache::new(8);
        c.insert("big", Arc::new(blob(9, 0)));
        assert!(c.get("big").is_none());
        assert_eq!(c.stats().insertions, 0);
        assert_eq!(c.stats().bytes, 0);
    }

    #[test]
    fn cached_block_reads_are_refcount_bumps() {
        use crate::linalg::Matrix;
        use crate::util::rng::Pcg64;

        let mem = Arc::new(MemStore::new());
        let s = CachedStore::new(mem.clone(), 1 << 20);
        let mut rng = Pcg64::new(4);
        let blk = crate::linalg::BlockBuf::new(Matrix::randn(6, 6, &mut rng, 0.0, 1.0));
        s.put_block("b", blk.clone());
        let first = s.get_block("b").unwrap(); // miss → fill from the store
        let second = s.get_block("b").unwrap(); // hit → cached handle
        assert!(crate::linalg::BlockBuf::ptr_eq(&first, &blk));
        assert!(crate::linalg::BlockBuf::ptr_eq(&second, &blk));
        let cs = s.cache().stats();
        assert_eq!((cs.hits, cs.misses), (1, 1));
        assert_eq!(cs.bytes, blk.wire_len() as u64);
        // The second read never reached the backing store.
        assert_eq!(mem.stats().gets, 1);
        // Byte-surface read of the cached block materializes the wire
        // format without touching the store.
        assert_eq!(s.get("b").unwrap().as_slice(), blk.to_wire().as_slice());
        assert_eq!(mem.stats().gets, 1);
        // A write invalidates the cached handle.
        s.put_block("b", blk.clone());
        assert_eq!(s.cache().len(), 0);
    }

    #[test]
    fn reinsert_same_key_does_not_leak_bytes() {
        let c = BlockCache::new(64);
        for _ in 0..10 {
            c.insert("k", Arc::new(blob(16, 0)));
        }
        assert_eq!(c.stats().bytes, 16);
        assert_eq!(c.len(), 1);
        c.invalidate("k");
        assert_eq!(c.stats().bytes, 0);
        assert!(c.is_empty());
    }
}

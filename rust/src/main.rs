//! `slec` — the coordinator CLI.
//!
//! Subcommands:
//!   figures <id|all>     regenerate paper figures/tables into results/
//!   run                  run one coded matmul job and print its report
//!   mc                   Monte-Carlo validation of Theorems 1–2
//!   serve <scenario>     run a service scenario (open-loop arrivals)
//!   daemon               HTTP API over a live service core (see /v1/jobs)
//!   replay <log.json>    re-run a submission log, bit-identical
//!   submit <job.json>    run one ad-hoc job through the service path
//!   scenarios            list the scenario suite with descriptions
//!   inspect-artifacts    list the AOT artifact manifest
//!   help                 this text
//!
//! Every job spec — scenario `jobs` entries, arrival templates, `submit`
//! inputs, `run` flags and daemon bodies — parses through the canonical
//! `coordinator::api` surface: one strict-keyed parser, one error
//! vocabulary.

use slec::config::Config;
use slec::coordinator::api;
use slec::coordinator::matmul::{run_matmul, MatmulJob};
use slec::coordinator::service::submit_one;
use slec::coordinator::REPORT_HEADERS;
use slec::figures::{self, RunScale};
use slec::linalg::Matrix;
use slec::platform::scenario::{parse_scenario, run_scenario};
use slec::platform::straggler::StragglerParams;
use slec::util::cli::{Args, OptSpec};
use slec::util::json;
use slec::util::rng::Pcg64;
use slec::util::stats::render_table;

fn common_specs() -> Vec<OptSpec> {
    vec![
        OptSpec { name: "config", help: "JSON config file", takes_value: true, default: None },
        OptSpec { name: "set", help: "override, e.g. platform.p=0.05 (comma-separable)", takes_value: true, default: None },
        OptSpec { name: "backend", help: "host | pjrt", takes_value: true, default: None },
        OptSpec { name: "seed", help: "base RNG seed", takes_value: true, default: None },
        OptSpec { name: "full", help: "paper-scale run (slower)", takes_value: false, default: None },
        OptSpec { name: "results-dir", help: "output directory", takes_value: true, default: None },
    ]
}

fn run_specs() -> Vec<OptSpec> {
    let mut s = common_specs();
    s.extend([
        OptSpec { name: "scheme", help: "coding scheme, name[:params]; 'help' lists the registry", takes_value: true, default: Some("local-product:2x2") },
        OptSpec { name: "rows", help: "numeric rows per side", takes_value: true, default: Some("640") },
        OptSpec { name: "k", help: "numeric inner dim", takes_value: true, default: Some("256") },
        OptSpec { name: "blocks", help: "systematic row-blocks per side", takes_value: true, default: Some("10") },
        OptSpec { name: "virtual-dim", help: "paper-scale dim for virtual time", takes_value: true, default: None },
        OptSpec { name: "decode-workers", help: "parallel decode workers", takes_value: true, default: Some("5") },
    ]);
    s
}

fn build_config(args: &Args) -> anyhow::Result<Config> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::load(std::path::Path::new(path))?,
        None => Config::default(),
    };
    if let Some(sets) = args.get("set") {
        for kv in sets.split(',') {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("--set expects key=value, got '{kv}'"))?;
            cfg.set(k.trim(), v.trim())?;
        }
    }
    if let Some(b) = args.get("backend") {
        cfg.set("backend", b)?;
    }
    if let Some(seed) = args.get_u64("seed").map_err(anyhow::Error::msg)? {
        cfg.seed = seed;
    }
    if let Some(dir) = args.get("results-dir") {
        cfg.results_dir = dir.into();
    }
    Ok(cfg)
}

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let (sub, rest) = match raw.split_first() {
        Some((s, rest)) => (s.as_str(), rest.to_vec()),
        None => ("help", vec![]),
    };

    match sub {
        "figures" => cmd_figures(&rest),
        "run" => cmd_run(&rest),
        "mc" => cmd_mc(&rest),
        "serve" => cmd_serve(&rest),
        "daemon" => cmd_daemon(&rest),
        "replay" => cmd_replay(&rest),
        "submit" => cmd_submit(&rest),
        "scenarios" => cmd_scenarios(&rest),
        "inspect-artifacts" => cmd_inspect(&rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            anyhow::bail!("unknown subcommand '{other}'")
        }
    }
}

fn print_help() {
    println!(
        "slec — Serverless Straggler Mitigation using Local Error-Correcting Codes\n\n\
         Usage: slec <subcommand> [options]\n\n\
         Subcommands:\n\
           figures <id|all>   reproduce paper figures ({}, fig12) into results/\n\
           run                one coded matmul job, printed report\n\
           mc                 Monte-Carlo validation of Theorems 1 and 2\n\
           serve <scenario>   run a service scenario (open-loop arrivals, admission, autoscale)\n\
           daemon             serve the HTTP job API on a socket (--addr, --time-scale, --log)\n\
           replay <log.json>  re-run a submission log; output is bit-identical to the run that wrote it\n\
           submit <job.json>  run one ad-hoc job through the service path, printed report\n\
           scenarios          list the scenario suite with descriptions\n\
           inspect-artifacts  list the AOT artifact manifest\n\n\
         Common options: --config <file> --set k=v[,k=v] --backend host|pjrt --seed N --full",
        figures::ALL.join(", ")
    );
}

fn cmd_figures(rest: &[String]) -> anyhow::Result<()> {
    let args = Args::parse(rest, &common_specs()).map_err(anyhow::Error::msg)?;
    let cfg = build_config(&args)?;
    let scale = if args.flag("full") { RunScale::Full } else { RunScale::Quick };
    let ids: Vec<String> = if args.positional.is_empty()
        || args.positional.iter().any(|p| p == "all")
    {
        let mut v: Vec<String> = figures::ALL.iter().map(|s| s.to_string()).collect();
        v.push("fig12".into());
        v
    } else {
        args.positional.clone()
    };
    for id in &ids {
        figures::run(id, &cfg, scale)?;
    }
    Ok(())
}

fn cmd_run(rest: &[String]) -> anyhow::Result<()> {
    let args = Args::parse(rest, &run_specs()).map_err(anyhow::Error::msg)?;
    let scheme_arg = args.get("scheme").unwrap();
    if scheme_arg == "help" {
        // The listing comes from the scheme registry, not a hardcoded
        // string: a newly registered scheme shows up here automatically.
        print!("{}", slec::codes::scheme::help_text());
        return Ok(());
    }
    let cfg = build_config(&args)?;
    let (env, _rt) = cfg.build_env()?;
    let rows = args.get_usize("rows").map_err(anyhow::Error::msg)?.unwrap();
    let k = args.get_usize("k").map_err(anyhow::Error::msg)?.unwrap();
    let blocks = args.get_usize("blocks").map_err(anyhow::Error::msg)?.unwrap();
    let vdim = args.get_usize("virtual-dim").map_err(anyhow::Error::msg)?;
    let decode_workers = args
        .get_usize("decode-workers")
        .map_err(anyhow::Error::msg)?
        .unwrap();

    // The flags become a canonical job document: `run` validates through
    // the same API parser (scheme registry, divisibility, strict keys)
    // as every other entry point.
    let doc = json::obj()
        .field("scheme", scheme_arg)
        .field("s_a", blocks)
        .field("s_b", blocks)
        .field(
            "dims",
            json::Json::Arr(vec![rows.into(), k.into(), rows.into()]),
        )
        .field("decode_workers", decode_workers)
        .build();
    let spec = api::parse_job_spec(&doc, None, api::SpecContext::Batch)?;

    let mut rng = Pcg64::new(cfg.seed);
    let a = Matrix::randn(spec.dims.0, spec.dims.1, &mut rng, 0.0, 1.0);
    let b = Matrix::randn(spec.dims.2, spec.dims.1, &mut rng, 0.0, 1.0);
    let mut builder = MatmulJob::builder()
        .blocks(spec.s_a, spec.s_b)
        .scheme(spec.scheme.clone())
        .decode_workers(spec.decode_workers)
        .verify(true)
        .seed(cfg.seed)
        .job_id("cli");
    if let Some(d) = vdim {
        builder = builder.virtual_cube(d);
    }
    let job = builder.build();
    let (_, report) = run_matmul(&env, &a, &b, &job)?;
    println!("{}", render_table(&REPORT_HEADERS, &[report.row()]));
    println!("{}", api::versioned(report.to_json()).to_string_pretty());
    Ok(())
}

fn cmd_mc(rest: &[String]) -> anyhow::Result<()> {
    let mut specs = common_specs();
    specs.extend([
        OptSpec { name: "l", help: "grid parameter L (=L_A=L_B)", takes_value: true, default: Some("10") },
        OptSpec { name: "p", help: "straggle probability", takes_value: true, default: Some("0.02") },
        OptSpec { name: "trials", help: "Monte-Carlo trials", takes_value: true, default: Some("100000") },
    ]);
    let args = Args::parse(rest, &specs).map_err(anyhow::Error::msg)?;
    let cfg = build_config(&args)?;
    let l = args.get_usize("l").map_err(anyhow::Error::msg)?.unwrap();
    let p = args.get_f64("p").map_err(anyhow::Error::msg)?.unwrap();
    let trials = args.get_usize("trials").map_err(anyhow::Error::msg)?.unwrap();

    let mc = slec::codes::montecarlo::simulate(l, l, p, trials, cfg.seed);
    let n = (l + 1) * (l + 1);
    println!(
        "L={l} n={n} p={p} trials={trials}\n\
         Pr(undecodable): empirical {:.3e}  Thm-2 bound {:.3e}\n\
         mean stragglers {:.2} (np = {:.2}); mean reads {:.2} (npL = {:.2})",
        mc.pr_undecodable,
        slec::codes::theory::thm2_bound(l, l, p),
        mc.mean_stragglers,
        n as f64 * p,
        mc.mean_reads(),
        slec::codes::theory::expected_reads(n, p, l),
    );
    for x in [1, 2, 3, 4].map(|m| m * l * 2) {
        println!(
            "Pr(R ≥ {x:>3}): empirical {:.3e}  corrected Thm-1 {:.3e}  paper form {:.3e}",
            mc.pr_reads_ge(x),
            slec::codes::theory::thm1_bound(x as f64, n, p, l),
            slec::codes::theory::thm1_bound_paper(x as f64, n, p, l),
        );
    }
    Ok(())
}

fn cmd_serve(rest: &[String]) -> anyhow::Result<()> {
    let specs = vec![
        OptSpec { name: "seed", help: "override the scenario seed", takes_value: true, default: None },
        OptSpec { name: "out", help: "write the service report JSON here (default: stdout)", takes_value: true, default: None },
        OptSpec { name: "quick", help: "cap the arrival process at 150 jobs (CI smoke)", takes_value: false, default: None },
        OptSpec { name: "log", help: "also write the submission log here (replayable via `slec replay`)", takes_value: true, default: None },
    ];
    let args = Args::parse(rest, &specs).map_err(anyhow::Error::msg)?;
    let path = args.positional.first().ok_or_else(|| {
        anyhow::anyhow!("serve needs a scenario file: slec serve <scenario.json>")
    })?;
    let src = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read scenario '{path}': {e}"))?;
    let mut sc = parse_scenario(&json::parse(&src)?)?;
    anyhow::ensure!(
        sc.arrivals.is_some(),
        "'{path}' has no 'arrivals' section — `serve` runs service scenarios; \
         explicit-jobs scenarios run through the golden suite"
    );
    if let Some(seed) = args.get_u64("seed").map_err(anyhow::Error::msg)? {
        sc.seed = seed;
    }
    if args.flag("quick") {
        if let Some(arr) = sc.arrivals.as_mut() {
            arr.jobs = arr.jobs.min(150);
        }
    }
    if let Some(log) = args.get("log") {
        // Written before the run: the log is a pure function of the
        // (possibly seed-overridden, quick-capped) scenario.
        std::fs::write(log, api::submission_log(&sc)?.to_string_pretty() + "\n")?;
        eprintln!("wrote submission log {log}");
    }
    let report = run_scenario(&sc)?;
    let text = report.to_string_pretty();
    match args.get("out") {
        Some(out) => {
            std::fs::write(out, text + "\n")?;
            eprintln!("wrote {out}");
        }
        None => println!("{text}"),
    }
    Ok(())
}

fn cmd_daemon(rest: &[String]) -> anyhow::Result<()> {
    let specs = vec![
        OptSpec { name: "addr", help: "bind address (port 0 = ephemeral)", takes_value: true, default: Some("127.0.0.1:7070") },
        OptSpec { name: "seed", help: "base RNG seed", takes_value: true, default: Some("0") },
        OptSpec { name: "workers", help: "fleet size", takes_value: true, default: Some("16") },
        OptSpec { name: "queue-depth", help: "admission queue depth (0 = unbounded)", takes_value: true, default: Some("0") },
        OptSpec { name: "max-inflight", help: "concurrent in-flight job cap (0 = unbounded)", takes_value: true, default: Some("0") },
        OptSpec { name: "time-scale", help: "virtual seconds per wall second (0 = frozen clock)", takes_value: true, default: Some("1") },
        OptSpec { name: "scenario", help: "run against a service scenario file instead of the default fleet", takes_value: true, default: None },
        OptSpec { name: "log", help: "persist the submission log here (replayable via `slec replay`)", takes_value: true, default: None },
        OptSpec { name: "io-timeout", help: "per-connection socket read/write timeout in seconds (0 = none)", takes_value: true, default: Some("10") },
    ];
    let args = Args::parse(rest, &specs).map_err(anyhow::Error::msg)?;
    let scenario = match args.get("scenario") {
        Some(path) => {
            let src = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("cannot read scenario '{path}': {e}"))?;
            Some(parse_scenario(&json::parse(&src)?)?)
        }
        None => None,
    };
    let time_scale = args.get_f64("time-scale").map_err(anyhow::Error::msg)?.unwrap();
    anyhow::ensure!(
        time_scale >= 0.0 && time_scale.is_finite(),
        "--time-scale must be a finite non-negative number"
    );
    let io_timeout_s = args.get_f64("io-timeout").map_err(anyhow::Error::msg)?.unwrap();
    anyhow::ensure!(
        io_timeout_s >= 0.0 && io_timeout_s.is_finite(),
        "--io-timeout must be a finite non-negative number"
    );
    let cfg = api::DaemonConfig {
        addr: args.get("addr").unwrap().to_string(),
        seed: args.get_u64("seed").map_err(anyhow::Error::msg)?.unwrap(),
        workers: args.get_usize("workers").map_err(anyhow::Error::msg)?.unwrap(),
        queue_depth: args.get_usize("queue-depth").map_err(anyhow::Error::msg)?.unwrap(),
        max_inflight: args.get_usize("max-inflight").map_err(anyhow::Error::msg)?.unwrap(),
        time_scale,
        scenario,
        log_path: args.get("log").map(std::path::PathBuf::from),
        io_timeout_s,
    };
    let mut daemon = api::Daemon::bind(&cfg)?;
    eprintln!("slec daemon listening on http://{}", daemon.local_addr()?);
    eprintln!("POST /v1/shutdown drains the queue and returns the final report");
    let report = daemon.serve()?;
    println!("{}", report.to_string_pretty());
    Ok(())
}

fn cmd_replay(rest: &[String]) -> anyhow::Result<()> {
    let specs = vec![
        OptSpec { name: "scenario", help: "the scenario the log was recorded against (required for serve logs)", takes_value: true, default: None },
        OptSpec { name: "seed", help: "override the scenario seed (match the recording run's --seed)", takes_value: true, default: None },
        OptSpec { name: "quick", help: "cap the arrival process at 150 jobs (match the recording run's --quick)", takes_value: false, default: None },
        OptSpec { name: "out", help: "write the replayed report JSON here (default: stdout)", takes_value: true, default: None },
    ];
    let args = Args::parse(rest, &specs).map_err(anyhow::Error::msg)?;
    let path = args.positional.first().ok_or_else(|| {
        anyhow::anyhow!("replay needs a submission log: slec replay <log.json>")
    })?;
    let log = json::load_file(std::path::Path::new(path))?;
    let scenario = match args.get("scenario") {
        Some(sp) => {
            let src = std::fs::read_to_string(sp)
                .map_err(|e| anyhow::anyhow!("cannot read scenario '{sp}': {e}"))?;
            let mut sc = parse_scenario(&json::parse(&src)?)?;
            if let Some(seed) = args.get_u64("seed").map_err(anyhow::Error::msg)? {
                sc.seed = seed;
            }
            if args.flag("quick") {
                if let Some(arr) = sc.arrivals.as_mut() {
                    arr.jobs = arr.jobs.min(150);
                }
            }
            Some(sc)
        }
        None => None,
    };
    let report = api::replay_submission_log(&log, scenario.as_ref())?;
    let text = report.to_string_pretty();
    match args.get("out") {
        Some(out) => {
            std::fs::write(out, text + "\n")?;
            eprintln!("wrote {out}");
        }
        None => println!("{text}"),
    }
    Ok(())
}

fn cmd_submit(rest: &[String]) -> anyhow::Result<()> {
    let specs = vec![
        OptSpec { name: "workers", help: "fleet size for this job", takes_value: true, default: Some("16") },
        OptSpec { name: "seed", help: "RNG seed", takes_value: true, default: Some("0") },
        OptSpec { name: "p", help: "straggle probability override", takes_value: true, default: None },
    ];
    let args = Args::parse(rest, &specs).map_err(anyhow::Error::msg)?;
    let input = args.positional.first().ok_or_else(|| {
        anyhow::anyhow!(
            "submit needs a job spec: slec submit <job.json> (a file path or inline JSON)"
        )
    })?;
    let spec = api::load_job_spec(input)?;
    let workers = args.get_usize("workers").map_err(anyhow::Error::msg)?.unwrap();
    anyhow::ensure!(workers > 0, "--workers must be ≥ 1");
    let seed = args.get_u64("seed").map_err(anyhow::Error::msg)?.unwrap();
    let mut straggler = StragglerParams::default();
    if let Some(p) = args.get_f64("p").map_err(anyhow::Error::msg)? {
        straggler.p = p;
    }
    let report = submit_one(&spec, workers, seed, straggler)?;
    println!("{}", api::versioned(report).to_string_pretty());
    Ok(())
}

fn cmd_scenarios(rest: &[String]) -> anyhow::Result<()> {
    let specs = vec![OptSpec {
        name: "dir",
        help: "scenario directory (default: rust/scenarios or scenarios)",
        takes_value: true,
        default: None,
    }];
    let args = Args::parse(rest, &specs).map_err(anyhow::Error::msg)?;
    let dir = match args.get("dir") {
        Some(d) => std::path::PathBuf::from(d),
        None => api::default_scenario_dir().ok_or_else(|| {
            anyhow::anyhow!("no scenario directory found (tried rust/scenarios, scenarios); use --dir")
        })?,
    };
    // The same index the daemon serves on GET /v1/scenarios.
    let infos = api::scenario_index(&dir)?;
    anyhow::ensure!(!infos.is_empty(), "no *.json scenarios in {}", dir.display());
    let mut rows = Vec::with_capacity(infos.len());
    for info in infos {
        let mut desc: String = info.description.chars().take(72).collect();
        if desc.len() < info.description.len() {
            desc.push('…');
        }
        rows.push(vec![info.name, info.kind.to_string(), info.jobs.to_string(), desc]);
    }
    println!("{}", render_table(&["scenario", "kind", "jobs", "description"], &rows));
    Ok(())
}

fn cmd_inspect(rest: &[String]) -> anyhow::Result<()> {
    let args = Args::parse(rest, &common_specs()).map_err(anyhow::Error::msg)?;
    let cfg = build_config(&args)?;
    let manifest = slec::runtime::Manifest::load(&cfg.artifacts_dir)?;
    println!("{} artifacts in {}:", manifest.len(), cfg.artifacts_dir.display());
    for name in manifest.names() {
        let info = manifest.get(name).unwrap();
        println!(
            "  {:<44} in={:?} out={:?}",
            info.name, info.inputs, info.outputs
        );
    }
    Ok(())
}

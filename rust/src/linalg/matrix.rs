//! Dense row-major `f32` matrix.
//!
//! The numeric payload everywhere in the system: blocks stored in the
//! object store, PJRT literals, and host reference computation all use this
//! type. f32 matches the dtype of the AOT-compiled JAX artifacts.

use crate::util::rng::Pcg64;

/// Dense row-major matrix of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Matrix from existing row-major data.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Identity.
    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// I.i.d. normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Pcg64, mean: f32, std: f32) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal_f32(&mut m.data, mean, std);
        m
    }

    /// I.i.d. uniform entries in [lo, hi).
    pub fn rand_uniform(rows: usize, cols: usize, rng: &mut Pcg64, lo: f32, hi: f32) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_uniform_f32(&mut m.data, lo, hi);
        m
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Extract the sub-matrix rows [r0, r1) × cols [c0, c1).
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Matrix::zeros(r1 - r0, c1 - c0);
        for (or, ir) in (r0..r1).enumerate() {
            let src = &self.data[ir * self.cols + c0..ir * self.cols + c1];
            out.row_mut(or).copy_from_slice(src);
        }
        out
    }

    /// Write `block` into this matrix at offset (r0, c0).
    pub fn paste(&mut self, r0: usize, c0: usize, block: &Matrix) {
        assert!(r0 + block.rows <= self.rows && c0 + block.cols <= self.cols);
        for br in 0..block.rows {
            let dst_start = (r0 + br) * self.cols + c0;
            self.data[dst_start..dst_start + block.cols].copy_from_slice(block.row(br));
        }
    }

    /// Transpose (out-of-place).
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large inputs.
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// Elementwise addition.
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    /// Elementwise subtraction.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    /// In-place `self += other`.
    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// In-place `self -= other`.
    pub fn sub_assign(&mut self, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
    }

    /// Scalar multiply.
    pub fn scale(&self, s: f32) -> Matrix {
        Matrix::from_vec(self.rows, self.cols, self.data.iter().map(|x| x * s).collect())
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0, f64::max)
    }

    /// Relative Frobenius error ‖a−b‖/‖b‖ (with ε guard).
    pub fn rel_err(&self, reference: &Matrix) -> f64 {
        let denom = reference.fro_norm().max(1e-30);
        self.sub(reference).fro_norm() / denom
    }

    /// True if all entries are finite.
    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// Serialize to little-endian bytes (8-byte header of rows/cols, then
    /// f32 payload) — the wire format stored in the simulated object store.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.data.len() * 4);
        out.extend_from_slice(&(self.rows as u64).to_le_bytes());
        out.extend_from_slice(&(self.cols as u64).to_le_bytes());
        for &x in &self.data {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out
    }

    /// Parse the wire format written by [`Matrix::to_bytes`].
    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Matrix> {
        if bytes.len() < 16 {
            anyhow::bail!("matrix blob too short: {} bytes", bytes.len());
        }
        let rows = u64::from_le_bytes(bytes[0..8].try_into().unwrap()) as usize;
        let cols = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let expect = 16 + rows * cols * 4;
        if bytes.len() != expect {
            anyhow::bail!("matrix blob size mismatch: got {}, want {expect}", bytes.len());
        }
        let mut data = Vec::with_capacity(rows * cols);
        for chunk in bytes[16..].chunks_exact(4) {
            data.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        Ok(Matrix { rows, cols, data })
    }
}

/// Dense vector helpers (vectors are (n×1) semantics stored flat).
pub mod vecops {
    /// Dot product in f64 accumulation.
    pub fn dot(a: &[f32], b: &[f32]) -> f64 {
        assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
    }

    /// 2-norm.
    pub fn norm2(a: &[f32]) -> f64 {
        dot(a, a).sqrt()
    }

    /// y += alpha * x
    pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), y.len());
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * xi;
        }
    }

    /// out = a - b
    pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
        assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(x, y)| x - y).collect()
    }

    /// Scale in place.
    pub fn scale(x: &mut [f32], s: f32) {
        for v in x.iter_mut() {
            *v *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let mut m = Matrix::zeros(2, 3);
        m.set(1, 2, 5.0);
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.get(0, 0), 0.0);
        assert_eq!(m.shape(), (2, 3));
    }

    #[test]
    fn eye_diag() {
        let i = Matrix::eye(4);
        for r in 0..4 {
            for c in 0..4 {
                assert_eq!(i.get(r, c), if r == c { 1.0 } else { 0.0 });
            }
        }
    }

    #[test]
    fn slice_paste_roundtrip() {
        let mut rng = Pcg64::new(1);
        let m = Matrix::randn(8, 10, &mut rng, 0.0, 1.0);
        let s = m.slice(2, 5, 3, 9);
        assert_eq!(s.shape(), (3, 6));
        assert_eq!(s.get(0, 0), m.get(2, 3));
        let mut back = Matrix::zeros(8, 10);
        back.paste(2, 3, &s);
        assert_eq!(back.get(4, 8), m.get(4, 8));
        assert_eq!(back.get(0, 0), 0.0);
    }

    #[test]
    fn transpose_involutive() {
        let mut rng = Pcg64::new(2);
        let m = Matrix::randn(37, 53, &mut rng, 0.0, 1.0);
        let t = m.transpose();
        assert_eq!(t.shape(), (53, 37));
        assert_eq!(t.get(10, 20), m.get(20, 10));
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn arithmetic() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![4.0, 3.0, 2.0, 1.0]);
        assert_eq!(a.add(&b).data, vec![5.0; 4]);
        assert_eq!(a.sub(&b).data, vec![-3.0, -1.0, 1.0, 3.0]);
        assert_eq!(a.scale(2.0).data, vec![2.0, 4.0, 6.0, 8.0]);
        let mut c = a.clone();
        c.add_assign(&b);
        assert_eq!(c.data, vec![5.0; 4]);
        c.sub_assign(&b);
        assert_eq!(c, a);
    }

    #[test]
    fn norms() {
        let a = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-12);
        let b = Matrix::from_vec(1, 2, vec![3.0, 5.0]);
        assert!((a.max_abs_diff(&b) - 1.0).abs() < 1e-12);
        assert!(a.rel_err(&a) < 1e-12);
    }

    #[test]
    fn bytes_roundtrip() {
        let mut rng = Pcg64::new(3);
        let m = Matrix::randn(5, 7, &mut rng, 0.0, 2.0);
        let b = m.to_bytes();
        assert_eq!(b.len(), 16 + 5 * 7 * 4);
        let m2 = Matrix::from_bytes(&b).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn bytes_rejects_corrupt() {
        assert!(Matrix::from_bytes(&[0u8; 3]).is_err());
        let m = Matrix::zeros(2, 2);
        let mut b = m.to_bytes();
        b.pop();
        assert!(Matrix::from_bytes(&b).is_err());
    }

    #[test]
    fn vecops_sanity() {
        use vecops::*;
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, 5.0, 6.0];
        assert!((dot(&a, &b) - 32.0).abs() < 1e-9);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        let mut y = [1.0f32, 1.0, 1.0];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
        assert_eq!(sub(&b, &a), vec![3.0, 3.0, 3.0]);
        let mut z = [2.0f32, 4.0];
        scale(&mut z, 0.5);
        assert_eq!(z, [1.0, 2.0]);
    }
}

//! Dense row-major `f32` matrix.
//!
//! The numeric payload everywhere in the system: blocks stored in the
//! object store, PJRT literals, and host reference computation all use this
//! type. f32 matches the dtype of the AOT-compiled JAX artifacts.

use crate::util::rng::Pcg64;

/// Dense row-major matrix of f32.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Matrix from existing row-major data.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Identity.
    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// I.i.d. normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Pcg64, mean: f32, std: f32) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal_f32(&mut m.data, mean, std);
        m
    }

    /// I.i.d. uniform entries in [lo, hi).
    pub fn rand_uniform(rows: usize, cols: usize, rng: &mut Pcg64, lo: f32, hi: f32) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_uniform_f32(&mut m.data, lo, hi);
        m
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Extract the sub-matrix rows [r0, r1) × cols [c0, c1).
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Matrix::zeros(r1 - r0, c1 - c0);
        for (or, ir) in (r0..r1).enumerate() {
            let src = &self.data[ir * self.cols + c0..ir * self.cols + c1];
            out.row_mut(or).copy_from_slice(src);
        }
        out
    }

    /// Write `block` into this matrix at offset (r0, c0).
    pub fn paste(&mut self, r0: usize, c0: usize, block: &Matrix) {
        assert!(r0 + block.rows <= self.rows && c0 + block.cols <= self.cols);
        for br in 0..block.rows {
            let dst_start = (r0 + br) * self.cols + c0;
            self.data[dst_start..dst_start + block.cols].copy_from_slice(block.row(br));
        }
    }

    /// Transpose (out-of-place).
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large inputs.
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// Elementwise addition.
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    /// Elementwise subtraction.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape());
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    /// In-place `self += other` (the [`crate::linalg::kernels`] path).
    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        crate::linalg::kernels::add_assign(&mut self.data, &other.data);
    }

    /// In-place `self -= other` (the [`crate::linalg::kernels`] path).
    pub fn sub_assign(&mut self, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        crate::linalg::kernels::sub_assign(&mut self.data, &other.data);
    }

    /// Scalar multiply.
    pub fn scale(&self, s: f32) -> Matrix {
        Matrix::from_vec(self.rows, self.cols, self.data.iter().map(|x| x * s).collect())
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0, f64::max)
    }

    /// Relative Frobenius error ‖a−b‖/‖b‖ (with ε guard).
    pub fn rel_err(&self, reference: &Matrix) -> f64 {
        let denom = reference.fro_norm().max(1e-30);
        self.sub(reference).fro_norm() / denom
    }

    /// True if all entries are finite.
    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// Serialize to little-endian bytes (8-byte header of rows/cols, then
    /// f32 payload) — the wire format stored in the simulated object store.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.data.len() * 4);
        out.extend_from_slice(&(self.rows as u64).to_le_bytes());
        out.extend_from_slice(&(self.cols as u64).to_le_bytes());
        for &x in &self.data {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out
    }

    /// Parse the wire format written by [`Matrix::to_bytes`].
    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Matrix> {
        if bytes.len() < 16 {
            anyhow::bail!("matrix blob too short: {} bytes", bytes.len());
        }
        let rows = u64::from_le_bytes(bytes[0..8].try_into().unwrap()) as usize;
        let cols = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let expect = 16 + rows * cols * 4;
        if bytes.len() != expect {
            anyhow::bail!("matrix blob size mismatch: got {}, want {expect}", bytes.len());
        }
        let mut data = Vec::with_capacity(rows * cols);
        for chunk in bytes[16..].chunks_exact(4) {
            data.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        Ok(Matrix { rows, cols, data })
    }
}

/// Shared immutable block: an `Arc`-backed [`Matrix`] whose payload (the
/// row-major little-endian `f32` slice) is exactly the wire payload of
/// [`Matrix::to_bytes`]. `BlockBuf` is the currency of the zero-copy
/// block pipeline:
///
/// - `clone()` is a refcount bump — systematic cells of an encode, grid
///   extraction in the peeling decoder, and staging the same block into
///   the object store all share one allocation.
/// - [`crate::storage::ObjectStore::put_block`] /
///   [`crate::storage::ObjectStore::get_block`] hand the same allocation
///   to and from the store (the store's byte counters still report the
///   logical wire size, [`BlockBuf::wire_len`]).
/// - Numeric kernels read through [`BlockBuf::as_matrix`] /
///   [`BlockBuf::as_slice`] without copying; only genuinely *new* values
///   (parities, recovered cells) allocate.
///
/// The payload is immutable by construction; to mutate, materialize a
/// [`Matrix`] via [`BlockBuf::into_matrix`] (zero-copy when this handle
/// is the sole owner) or [`BlockBuf::to_matrix`] (always a deep copy).
#[derive(Debug, Clone)]
pub struct BlockBuf {
    inner: std::sync::Arc<Matrix>,
}

impl BlockBuf {
    /// Wrap a matrix (no copy; the matrix moves into the shared buffer).
    pub fn new(m: Matrix) -> BlockBuf {
        BlockBuf {
            inner: std::sync::Arc::new(m),
        }
    }

    /// Borrow the underlying matrix.
    #[inline]
    pub fn as_matrix(&self) -> &Matrix {
        &self.inner
    }

    /// Borrow the f32 payload.
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.inner.data
    }

    /// Unwrap into an owned matrix: zero-copy when this handle is the
    /// sole owner, a deep copy otherwise.
    pub fn into_matrix(self) -> Matrix {
        std::sync::Arc::try_unwrap(self.inner).unwrap_or_else(|shared| (*shared).clone())
    }

    /// Deep-copy into an owned matrix.
    pub fn to_matrix(&self) -> Matrix {
        (*self.inner).clone()
    }

    /// Do two handles share one allocation? (The zero-copy assertion used
    /// by the storage round-trip tests.)
    pub fn ptr_eq(a: &BlockBuf, b: &BlockBuf) -> bool {
        std::sync::Arc::ptr_eq(&a.inner, &b.inner)
    }

    /// Logical wire size in bytes (16-byte dims header + 4 bytes per
    /// element) — what the store's `bytes_in`/`bytes_out` counters report
    /// for a staged block even though no bytes are copied.
    pub fn wire_len(&self) -> usize {
        16 + self.inner.data.len() * 4
    }

    /// Serialize to the [`Matrix::to_bytes`] wire format (allocates; only
    /// the byte-oriented compatibility paths need this).
    pub fn to_wire(&self) -> Vec<u8> {
        self.inner.to_bytes()
    }

    /// Parse a wire-format blob (see [`Matrix::from_bytes`]).
    pub fn from_wire(bytes: &[u8]) -> anyhow::Result<BlockBuf> {
        Ok(BlockBuf::new(Matrix::from_bytes(bytes)?))
    }
}

impl From<Matrix> for BlockBuf {
    fn from(m: Matrix) -> BlockBuf {
        BlockBuf::new(m)
    }
}

impl std::ops::Deref for BlockBuf {
    type Target = Matrix;

    fn deref(&self) -> &Matrix {
        &self.inner
    }
}

impl std::borrow::Borrow<Matrix> for BlockBuf {
    fn borrow(&self) -> &Matrix {
        &self.inner
    }
}

impl PartialEq for BlockBuf {
    fn eq(&self, other: &BlockBuf) -> bool {
        BlockBuf::ptr_eq(self, other) || self.inner == other.inner
    }
}

/// Dense vector helpers (vectors are (n×1) semantics stored flat).
pub mod vecops {
    /// Dot product in f64 accumulation.
    pub fn dot(a: &[f32], b: &[f32]) -> f64 {
        assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
    }

    /// 2-norm.
    pub fn norm2(a: &[f32]) -> f64 {
        dot(a, a).sqrt()
    }

    /// y += alpha * x
    pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), y.len());
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * xi;
        }
    }

    /// out = a - b
    pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
        assert_eq!(a.len(), b.len());
        a.iter().zip(b).map(|(x, y)| x - y).collect()
    }

    /// Scale in place.
    pub fn scale(x: &mut [f32], s: f32) {
        for v in x.iter_mut() {
            *v *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let mut m = Matrix::zeros(2, 3);
        m.set(1, 2, 5.0);
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.get(0, 0), 0.0);
        assert_eq!(m.shape(), (2, 3));
    }

    #[test]
    fn eye_diag() {
        let i = Matrix::eye(4);
        for r in 0..4 {
            for c in 0..4 {
                assert_eq!(i.get(r, c), if r == c { 1.0 } else { 0.0 });
            }
        }
    }

    #[test]
    fn slice_paste_roundtrip() {
        let mut rng = Pcg64::new(1);
        let m = Matrix::randn(8, 10, &mut rng, 0.0, 1.0);
        let s = m.slice(2, 5, 3, 9);
        assert_eq!(s.shape(), (3, 6));
        assert_eq!(s.get(0, 0), m.get(2, 3));
        let mut back = Matrix::zeros(8, 10);
        back.paste(2, 3, &s);
        assert_eq!(back.get(4, 8), m.get(4, 8));
        assert_eq!(back.get(0, 0), 0.0);
    }

    #[test]
    fn transpose_involutive() {
        let mut rng = Pcg64::new(2);
        let m = Matrix::randn(37, 53, &mut rng, 0.0, 1.0);
        let t = m.transpose();
        assert_eq!(t.shape(), (53, 37));
        assert_eq!(t.get(10, 20), m.get(20, 10));
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn arithmetic() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![4.0, 3.0, 2.0, 1.0]);
        assert_eq!(a.add(&b).data, vec![5.0; 4]);
        assert_eq!(a.sub(&b).data, vec![-3.0, -1.0, 1.0, 3.0]);
        assert_eq!(a.scale(2.0).data, vec![2.0, 4.0, 6.0, 8.0]);
        let mut c = a.clone();
        c.add_assign(&b);
        assert_eq!(c.data, vec![5.0; 4]);
        c.sub_assign(&b);
        assert_eq!(c, a);
    }

    #[test]
    fn norms() {
        let a = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-12);
        let b = Matrix::from_vec(1, 2, vec![3.0, 5.0]);
        assert!((a.max_abs_diff(&b) - 1.0).abs() < 1e-12);
        assert!(a.rel_err(&a) < 1e-12);
    }

    #[test]
    fn bytes_roundtrip() {
        let mut rng = Pcg64::new(3);
        let m = Matrix::randn(5, 7, &mut rng, 0.0, 2.0);
        let b = m.to_bytes();
        assert_eq!(b.len(), 16 + 5 * 7 * 4);
        let m2 = Matrix::from_bytes(&b).unwrap();
        assert_eq!(m, m2);
    }

    #[test]
    fn bytes_rejects_corrupt() {
        assert!(Matrix::from_bytes(&[0u8; 3]).is_err());
        let m = Matrix::zeros(2, 2);
        let mut b = m.to_bytes();
        b.pop();
        assert!(Matrix::from_bytes(&b).is_err());
    }

    #[test]
    fn blockbuf_shares_and_unwraps() {
        let mut rng = Pcg64::new(5);
        let m = Matrix::randn(6, 4, &mut rng, 0.0, 1.0);
        let b = BlockBuf::new(m.clone());
        let b2 = b.clone();
        assert!(BlockBuf::ptr_eq(&b, &b2));
        assert_eq!(b.as_matrix(), &m);
        assert_eq!(b.as_slice(), m.data.as_slice());
        assert_eq!(b.rows, 6); // Deref to Matrix
        assert_eq!(b.wire_len(), 16 + 24 * 4);
        // Shared handle: into_matrix deep-copies; sole owner: moves.
        let copied = b2.into_matrix();
        assert_eq!(copied, m);
        let sole = BlockBuf::new(m.clone());
        assert_eq!(sole.into_matrix(), m);
    }

    #[test]
    fn blockbuf_wire_roundtrip_is_the_matrix_format() {
        let mut rng = Pcg64::new(6);
        let m = Matrix::randn(3, 5, &mut rng, 0.0, 1.0);
        let b = BlockBuf::new(m.clone());
        let wire = b.to_wire();
        assert_eq!(wire, m.to_bytes());
        assert_eq!(wire.len(), b.wire_len());
        let back = BlockBuf::from_wire(&wire).unwrap();
        assert!(!BlockBuf::ptr_eq(&b, &back));
        assert_eq!(back, b);
        assert!(BlockBuf::from_wire(&wire[..7]).is_err());
    }

    #[test]
    fn vecops_sanity() {
        use vecops::*;
        let a = [1.0f32, 2.0, 3.0];
        let b = [4.0f32, 5.0, 6.0];
        assert!((dot(&a, &b) - 32.0).abs() < 1e-9);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        let mut y = [1.0f32, 1.0, 1.0];
        axpy(2.0, &a, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
        assert_eq!(sub(&b, &a), vec![3.0, 3.0, 3.0]);
        let mut z = [2.0f32, 4.0];
        scale(&mut z, 0.5);
        assert_eq!(z, [1.0, 2.0]);
    }
}

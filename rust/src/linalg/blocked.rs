//! Block partitioning of matrices — the grid abstraction every coding
//! scheme operates on (Remark 2: block partitioning is the communication-
//! efficient layout for distributed matmul).
//!
//! A `Partition` splits the row range of a matrix into `nblocks` equal
//! row-blocks (the paper's unit of encoding); a `Grid` describes the 2-D
//! block structure of the output `C = A·Bᵀ`, where block (i, j) is
//! `A_i · B_jᵀ`.

use crate::linalg::matrix::Matrix;

/// Row-block partition of an (rows × cols) matrix into equal blocks.
#[derive(Debug, Clone, PartialEq)]
pub struct Partition {
    pub rows: usize,
    pub cols: usize,
    pub nblocks: usize,
    pub block_rows: usize,
}

impl Partition {
    /// Partition `rows` into `nblocks` equal row-blocks. `rows` must divide
    /// evenly — callers pad to a multiple first (see [`pad_rows`]).
    pub fn new(rows: usize, cols: usize, nblocks: usize) -> Partition {
        assert!(nblocks > 0, "need at least one block");
        assert_eq!(
            rows % nblocks,
            0,
            "rows ({rows}) must be divisible by nblocks ({nblocks}); pad first"
        );
        Partition {
            rows,
            cols,
            nblocks,
            block_rows: rows / nblocks,
        }
    }

    /// Row range of block `i`.
    pub fn range(&self, i: usize) -> (usize, usize) {
        assert!(i < self.nblocks);
        (i * self.block_rows, (i + 1) * self.block_rows)
    }

    /// Extract block `i` from a matrix with this partition's shape.
    pub fn extract(&self, m: &Matrix, i: usize) -> Matrix {
        assert_eq!((m.rows, m.cols), (self.rows, self.cols));
        let (r0, r1) = self.range(i);
        m.slice(r0, r1, 0, self.cols)
    }

    /// Split the whole matrix into blocks.
    pub fn split(&self, m: &Matrix) -> Vec<Matrix> {
        (0..self.nblocks).map(|i| self.extract(m, i)).collect()
    }

    /// Reassemble blocks into the full matrix.
    pub fn assemble(&self, blocks: &[Matrix]) -> Matrix {
        assert_eq!(blocks.len(), self.nblocks);
        let mut out = Matrix::zeros(self.rows, self.cols);
        for (i, b) in blocks.iter().enumerate() {
            assert_eq!(b.shape(), (self.block_rows, self.cols), "block {i} shape");
            let (r0, _) = self.range(i);
            out.paste(r0, 0, b);
        }
        out
    }
}

/// Pad a matrix with zero rows so `rows % nblocks == 0`; returns the padded
/// matrix and the original row count.
pub fn pad_rows(m: &Matrix, multiple: usize) -> (Matrix, usize) {
    let orig = m.rows;
    let rem = m.rows % multiple;
    if rem == 0 {
        return (m.clone(), orig);
    }
    let padded_rows = m.rows + (multiple - rem);
    let mut out = Matrix::zeros(padded_rows, m.cols);
    out.paste(0, 0, m);
    (out, orig)
}

/// Strip padding rows added by [`pad_rows`].
pub fn unpad_rows(m: &Matrix, orig_rows: usize) -> Matrix {
    assert!(orig_rows <= m.rows);
    m.slice(0, orig_rows, 0, m.cols)
}

/// 2-D grid of output blocks for `C = A·Bᵀ`: `C_{ij} = A_i · B_jᵀ`,
/// block shape (a.block_rows × b.block_rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GridShape {
    /// Number of row-blocks (A side).
    pub rows: usize,
    /// Number of column-blocks (B side).
    pub cols: usize,
}

impl GridShape {
    pub fn n(&self) -> usize {
        self.rows * self.cols
    }

    /// Flatten (r, c) → linear id (row-major).
    pub fn id(&self, r: usize, c: usize) -> usize {
        debug_assert!(r < self.rows && c < self.cols);
        r * self.cols + c
    }

    /// Inverse of [`GridShape::id`].
    pub fn rc(&self, id: usize) -> (usize, usize) {
        debug_assert!(id < self.n());
        (id / self.cols, id % self.cols)
    }

    pub fn iter(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        (0..self.rows).flat_map(move |r| (0..self.cols).map(move |c| (r, c)))
    }
}

/// Assemble a full output matrix from a row-major grid of equally-shaped
/// blocks. Generic over owned [`Matrix`] grids and shared
/// [`crate::linalg::matrix::BlockBuf`] grids (the zero-copy pipeline
/// assembles straight from the staged handles).
pub fn assemble_grid<B: std::borrow::Borrow<Matrix>>(shape: GridShape, blocks: &[B]) -> Matrix {
    assert_eq!(blocks.len(), shape.n());
    let (br, bc) = blocks[0].borrow().shape();
    let mut out = Matrix::zeros(shape.rows * br, shape.cols * bc);
    for (idx, b) in blocks.iter().enumerate() {
        let b = b.borrow();
        assert_eq!(b.shape(), (br, bc), "grid block {idx} shape mismatch");
        let (r, c) = shape.rc(idx);
        out.paste(r * br, c * bc, b);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn partition_split_assemble_roundtrip() {
        let mut rng = Pcg64::new(1);
        let m = Matrix::randn(12, 5, &mut rng, 0.0, 1.0);
        let p = Partition::new(12, 5, 4);
        let blocks = p.split(&m);
        assert_eq!(blocks.len(), 4);
        assert_eq!(blocks[0].shape(), (3, 5));
        assert_eq!(p.assemble(&blocks), m);
    }

    #[test]
    #[should_panic(expected = "divisible")]
    fn partition_rejects_uneven() {
        Partition::new(10, 3, 4);
    }

    #[test]
    fn pad_unpad() {
        let mut rng = Pcg64::new(2);
        let m = Matrix::randn(10, 3, &mut rng, 0.0, 1.0);
        let (p, orig) = pad_rows(&m, 4);
        assert_eq!(p.rows, 12);
        assert_eq!(orig, 10);
        // Padding rows are zero.
        for c in 0..3 {
            assert_eq!(p.get(10, c), 0.0);
            assert_eq!(p.get(11, c), 0.0);
        }
        assert_eq!(unpad_rows(&p, orig), m);
        // Already-aligned input is unchanged.
        let (q, o2) = pad_rows(&m, 5);
        assert_eq!(q, m);
        assert_eq!(o2, 10);
    }

    #[test]
    fn grid_id_roundtrip() {
        let g = GridShape { rows: 3, cols: 5 };
        assert_eq!(g.n(), 15);
        for r in 0..3 {
            for c in 0..5 {
                assert_eq!(g.rc(g.id(r, c)), (r, c));
            }
        }
        assert_eq!(g.iter().count(), 15);
    }

    #[test]
    fn grid_assembly_matches_full_product() {
        // Blockwise A·Aᵀ assembled from blocks equals the direct product.
        let mut rng = Pcg64::new(3);
        let a = Matrix::randn(12, 7, &mut rng, 0.0, 1.0);
        let p = Partition::new(12, 7, 3);
        let ab = p.split(&a);
        let shape = GridShape { rows: 3, cols: 3 };
        let mut blocks = Vec::new();
        for i in 0..3 {
            for j in 0..3 {
                blocks.push(crate::linalg::gemm::matmul_bt(&ab[i], &ab[j]));
            }
        }
        let assembled = assemble_grid(shape, &blocks);
        let direct = crate::linalg::gemm::matmul_bt(&a, &a);
        assert!(assembled.rel_err(&direct) < 1e-5);
    }
}

//! Symmetric eigendecomposition (cyclic Jacobi) and the small-side SVD used
//! by the tall-skinny SVD application (§IV-C): B = AᵀA is p×p, its
//! eigendecomposition B = V Σ² Vᵀ runs "locally at the master".
//!
//! f64 internal arithmetic; f32 I/O to match the Matrix payload type.

use crate::linalg::matrix::Matrix;

/// Result of a symmetric eigendecomposition: `a = V · diag(vals) · Vᵀ`,
/// eigenvalues sorted descending, eigenvectors in V's columns.
#[derive(Debug, Clone)]
pub struct SymEigen {
    pub values: Vec<f64>,
    pub vectors: Matrix,
}

/// Cyclic Jacobi eigenvalue iteration for a symmetric matrix.
pub fn sym_eigen(a: &Matrix, max_sweeps: usize, tol: f64) -> anyhow::Result<SymEigen> {
    anyhow::ensure!(a.rows == a.cols, "sym_eigen needs a square matrix");
    let n = a.rows;
    let mut m: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    // Symmetrize defensively (accumulated f32 noise in gram matrices).
    for i in 0..n {
        for j in 0..i {
            let avg = 0.5 * (m[i * n + j] + m[j * n + i]);
            m[i * n + j] = avg;
            m[j * n + i] = avg;
        }
    }
    let mut v = vec![0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let off = |m: &[f64]| -> f64 {
        let mut s = 0.0;
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    s += m[i * n + j] * m[i * n + j];
                }
            }
        }
        s.sqrt()
    };

    let scale = m.iter().map(|x| x.abs()).fold(0.0, f64::max).max(1e-30);
    for _sweep in 0..max_sweeps {
        if off(&m) <= tol * scale * n as f64 {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[p * n + q];
                if apq.abs() <= 1e-300 {
                    continue;
                }
                let app = m[p * n + p];
                let aqq = m[q * n + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p and q of m.
                for k in 0..n {
                    let mkp = m[k * n + p];
                    let mkq = m[k * n + q];
                    m[k * n + p] = c * mkp - s * mkq;
                    m[k * n + q] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[p * n + k];
                    let mqk = m[q * n + k];
                    m[p * n + k] = c * mpk - s * mqk;
                    m[q * n + k] = s * mpk + c * mqk;
                }
                // Accumulate rotations into V.
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }

    // Extract eigenpairs, sort descending by eigenvalue.
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[i * n + i], i)).collect();
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let values: Vec<f64> = pairs.iter().map(|(val, _)| *val).collect();
    let mut vectors = Matrix::zeros(n, n);
    for (newcol, &(_, oldcol)) in pairs.iter().enumerate() {
        for r in 0..n {
            vectors.set(r, newcol, v[r * n + oldcol] as f32);
        }
    }
    Ok(SymEigen { values, vectors })
}

/// SVD of a tall matrix A (m×p, m ≥ p) given its precomputed gram matrix
/// `B = AᵀA`: returns (V, Σ) with `A = U Σ Vᵀ`, singular values descending.
/// U is recovered by the caller with another coded matmul `U = A·(V Σ⁻¹)`.
pub struct SmallSvd {
    pub v: Matrix,
    pub sigma: Vec<f64>,
}

pub fn svd_from_gram(b: &Matrix) -> anyhow::Result<SmallSvd> {
    let eig = sym_eigen(b, 60, 1e-14)?;
    let sigma: Vec<f64> = eig.values.iter().map(|&l| l.max(0.0).sqrt()).collect();
    Ok(SmallSvd { v: eig.vectors, sigma })
}

/// Compute `V · diag(1/σ)` (the right factor of U = A·VΣ⁻¹); zero columns
/// for σ below `cutoff` to keep the result finite for rank-deficient input.
pub fn v_sigma_inv(svd: &SmallSvd, cutoff: f64) -> Matrix {
    let p = svd.v.rows;
    let mut out = Matrix::zeros(p, p);
    for c in 0..p {
        let s = svd.sigma[c];
        let inv = if s > cutoff { 1.0 / s } else { 0.0 };
        for r in 0..p {
            out.set(r, c, (svd.v.get(r, c) as f64 * inv) as f32);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_bt};
    use crate::util::rng::Pcg64;

    #[test]
    fn eigen_reconstructs() {
        let mut rng = Pcg64::new(1);
        let a = Matrix::randn(10, 10, &mut rng, 0.0, 1.0);
        let sym = matmul_bt(&a, &a);
        let eig = sym_eigen(&sym, 50, 1e-13).unwrap();
        // V diag Vᵀ ≈ sym
        let n = 10;
        let mut d = Matrix::zeros(n, n);
        for i in 0..n {
            d.set(i, i, eig.values[i] as f32);
        }
        let recon = matmul(&matmul(&eig.vectors, &d), &eig.vectors.transpose());
        assert!(recon.rel_err(&sym) < 1e-3, "err={}", recon.rel_err(&sym));
        // Eigenvalues descending and nonnegative (gram matrix).
        for w in eig.values.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(eig.values.iter().all(|&v| v > -1e-3));
    }

    #[test]
    fn eigen_orthonormal_vectors() {
        let mut rng = Pcg64::new(2);
        let a = Matrix::randn(8, 8, &mut rng, 0.0, 1.0);
        let sym = matmul_bt(&a, &a);
        let eig = sym_eigen(&sym, 50, 1e-13).unwrap();
        let vtv = matmul(&eig.vectors.transpose(), &eig.vectors);
        assert!(vtv.rel_err(&Matrix::eye(8)) < 1e-3);
    }

    #[test]
    fn eigen_diagonal_matrix() {
        let mut d = Matrix::zeros(4, 4);
        for (i, &v) in [4.0f32, 1.0, 3.0, 2.0].iter().enumerate() {
            d.set(i, i, v);
        }
        let eig = sym_eigen(&d, 30, 1e-14).unwrap();
        let got: Vec<f64> = eig.values.iter().map(|&x| (x * 1e9).round() / 1e9).collect();
        assert_eq!(got, vec![4.0, 3.0, 2.0, 1.0]);
    }

    #[test]
    fn svd_matches_direct() {
        // Tall A: singular values of A == sqrt(eigenvalues of AᵀA).
        let mut rng = Pcg64::new(3);
        let a = Matrix::randn(40, 6, &mut rng, 0.0, 1.0);
        let gram = matmul(&a.transpose(), &a);
        let svd = svd_from_gram(&gram).unwrap();
        assert_eq!(svd.sigma.len(), 6);
        // Check A·V has orthogonal columns with norms σ_i.
        let av = matmul(&a, &svd.v);
        for c in 0..6 {
            let col: Vec<f32> = (0..40).map(|r| av.get(r, c)).collect();
            let norm = crate::linalg::matrix::vecops::norm2(&col);
            assert!(
                (norm - svd.sigma[c]).abs() < 1e-2 * (1.0 + svd.sigma[c]),
                "col {c}: {norm} vs {}",
                svd.sigma[c]
            );
        }
        // Full reconstruction: U Σ Vᵀ = A with U = A V Σ⁻¹.
        let u = matmul(&a, &v_sigma_inv(&svd, 1e-9));
        let mut sig = Matrix::zeros(6, 6);
        for i in 0..6 {
            sig.set(i, i, svd.sigma[i] as f32);
        }
        let recon = matmul(&matmul(&u, &sig), &svd.v.transpose());
        assert!(recon.rel_err(&a) < 1e-3, "err={}", recon.rel_err(&a));
    }

    #[test]
    fn v_sigma_inv_handles_rank_deficiency() {
        // Rank-1 gram.
        let ones = Matrix::from_vec(3, 1, vec![1.0, 1.0, 1.0]);
        let gram = matmul(&ones, &ones.transpose());
        let svd = svd_from_gram(&gram).unwrap();
        let vsi = v_sigma_inv(&svd, 1e-6);
        assert!(vsi.is_finite());
    }
}

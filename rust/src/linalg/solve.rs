//! Small dense solves: Cholesky factorization, triangular solves, SPD
//! inverse — the "done locally at the master" f×f steps of ALS
//! (Algorithm 2) and the small-system solves in KRR/SVD.
//!
//! Factorizations run in f64 internally for stability, with f32 matrix I/O.

use crate::linalg::matrix::Matrix;

/// Cholesky factor L (lower-triangular, row-major f64) of an SPD matrix.
#[derive(Debug, Clone)]
pub struct Cholesky {
    pub n: usize,
    l: Vec<f64>,
}

impl Cholesky {
    /// Factor an SPD matrix; returns Err if a non-positive pivot appears.
    pub fn factor(a: &Matrix) -> anyhow::Result<Cholesky> {
        anyhow::ensure!(a.rows == a.cols, "Cholesky needs a square matrix");
        let n = a.rows;
        let mut l = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                let mut s = a.get(i, j) as f64;
                for k in 0..j {
                    s -= l[i * n + k] * l[j * n + k];
                }
                if i == j {
                    anyhow::ensure!(s > 0.0, "matrix not positive definite at pivot {i} (s={s})");
                    l[i * n + i] = s.sqrt();
                } else {
                    l[i * n + j] = s / l[j * n + j];
                }
            }
        }
        Ok(Cholesky { n, l })
    }

    /// Solve A x = b via forward/back substitution.
    pub fn solve(&self, b: &[f32]) -> Vec<f32> {
        assert_eq!(b.len(), self.n);
        let n = self.n;
        // L y = b
        let mut y = vec![0f64; n];
        for i in 0..n {
            let mut s = b[i] as f64;
            for k in 0..i {
                s -= self.l[i * n + k] * y[k];
            }
            y[i] = s / self.l[i * n + i];
        }
        // Lᵀ x = y
        let mut x = vec![0f64; n];
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= self.l[k * n + i] * x[k];
            }
            x[i] = s / self.l[i * n + i];
        }
        x.into_iter().map(|v| v as f32).collect()
    }

    /// Solve A X = B for a matrix right-hand side (column by column).
    pub fn solve_matrix(&self, b: &Matrix) -> Matrix {
        assert_eq!(b.rows, self.n);
        let mut out = Matrix::zeros(b.rows, b.cols);
        for c in 0..b.cols {
            let col: Vec<f32> = (0..b.rows).map(|r| b.get(r, c)).collect();
            let x = self.solve(&col);
            for r in 0..b.rows {
                out.set(r, c, x[r]);
            }
        }
        out
    }

    /// Explicit inverse (used for the paper's `(W Wᵀ + λI)⁻¹` f×f step).
    pub fn inverse(&self) -> Matrix {
        self.solve_matrix(&Matrix::eye(self.n))
    }
}

/// Solve the regularized normal system `(G + λI) X = B` where G is SPD-ish.
pub fn solve_regularized(g: &Matrix, lambda: f32, b: &Matrix) -> anyhow::Result<Matrix> {
    anyhow::ensure!(g.rows == g.cols, "G must be square");
    let mut greg = g.clone();
    for i in 0..g.rows {
        let v = greg.get(i, i) + lambda;
        greg.set(i, i, v);
    }
    Ok(Cholesky::factor(&greg)?.solve_matrix(b))
}

/// General LU solve with partial pivoting (used by the polynomial-code
/// decoder's Vandermonde systems, which are square but not SPD).
pub fn lu_solve(a: &Matrix, b: &[f64]) -> anyhow::Result<Vec<f64>> {
    anyhow::ensure!(a.rows == a.cols, "LU needs square");
    let n = a.rows;
    anyhow::ensure!(b.len() == n, "rhs length");
    let mut m: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut x: Vec<f64> = b.to_vec();
    let mut perm: Vec<usize> = (0..n).collect();
    for col in 0..n {
        // Partial pivot.
        let (piv, pval) = (col..n)
            .map(|r| (r, m[r * n + col].abs()))
            .fold((col, -1.0), |best, cand| if cand.1 > best.1 { cand } else { best });
        anyhow::ensure!(pval > 1e-300, "singular matrix at column {col}");
        if piv != col {
            for k in 0..n {
                m.swap(col * n + k, piv * n + k);
            }
            x.swap(col, piv);
            perm.swap(col, piv);
        }
        let d = m[col * n + col];
        for r in col + 1..n {
            let f = m[r * n + col] / d;
            if f == 0.0 {
                continue;
            }
            m[r * n + col] = 0.0;
            for k in col + 1..n {
                m[r * n + k] -= f * m[col * n + k];
            }
            x[r] -= f * x[col];
        }
    }
    // Back substitution.
    let mut out = vec![0f64; n];
    for i in (0..n).rev() {
        let mut s = x[i];
        for k in i + 1..n {
            s -= m[i * n + k] * out[k];
        }
        out[i] = s / m[i * n + i];
    }
    Ok(out)
}

/// Solve a real Vandermonde-like system given the evaluation points:
/// find coefficients c such that Σ_j c_j · points[i]^j = values[i].
/// (Used as the polynomial-code decode oracle for small systems.)
pub fn vandermonde_solve(points: &[f64], values: &[f64]) -> anyhow::Result<Vec<f64>> {
    anyhow::ensure!(points.len() == values.len());
    let n = points.len();
    let mut v = Matrix::zeros(n, n);
    for i in 0..n {
        let mut p = 1f64;
        for j in 0..n {
            v.set(i, j, p as f32); // f32 storage loses precision for big powers;
            p *= points[i];
        }
    }
    // For precision, build the f64 system directly through lu on an f64 copy:
    // we bypass Matrix's f32 storage here.
    let mut m = vec![0f64; n * n];
    for i in 0..n {
        let mut p = 1f64;
        for j in 0..n {
            m[i * n + j] = p;
            p *= points[i];
        }
    }
    lu_solve_f64(&m, n, values)
}

fn lu_solve_f64(a: &[f64], n: usize, b: &[f64]) -> anyhow::Result<Vec<f64>> {
    let mut m = a.to_vec();
    let mut x = b.to_vec();
    for col in 0..n {
        let (piv, pval) = (col..n)
            .map(|r| (r, m[r * n + col].abs()))
            .fold((col, -1.0), |best, cand| if cand.1 > best.1 { cand } else { best });
        anyhow::ensure!(pval > 1e-300, "singular at column {col}");
        if piv != col {
            for k in 0..n {
                m.swap(col * n + k, piv * n + k);
            }
            x.swap(col, piv);
        }
        let d = m[col * n + col];
        for r in col + 1..n {
            let f = m[r * n + col] / d;
            if f == 0.0 {
                continue;
            }
            for k in col + 1..n {
                m[r * n + k] -= f * m[col * n + k];
            }
            x[r] -= f * x[col];
        }
    }
    let mut out = vec![0f64; n];
    for i in (0..n).rev() {
        let mut s = x[i];
        for k in i + 1..n {
            s -= m[i * n + k] * out[k];
        }
        out[i] = s / m[i * n + i];
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::{matmul, matmul_bt};
    use crate::util::rng::Pcg64;

    fn spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        let a = Matrix::randn(n, n, &mut rng, 0.0, 1.0);
        let mut g = matmul_bt(&a, &a); // A·Aᵀ is PSD
        for i in 0..n {
            g.set(i, i, g.get(i, i) + n as f32); // make strictly PD
        }
        g
    }

    #[test]
    fn cholesky_solves() {
        let a = spd(12, 1);
        let chol = Cholesky::factor(&a).unwrap();
        let b: Vec<f32> = (0..12).map(|i| (i as f32 + 1.0).sin()).collect();
        let x = chol.solve(&b);
        // Check A x ≈ b.
        let xm = Matrix::from_vec(12, 1, x);
        let ax = matmul(&a, &xm);
        for i in 0..12 {
            assert!((ax.get(i, 0) - b[i]).abs() < 1e-3, "row {i}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Matrix::eye(3);
        a.set(2, 2, -1.0);
        assert!(Cholesky::factor(&a).is_err());
    }

    #[test]
    fn inverse_roundtrip() {
        let a = spd(8, 2);
        let inv = Cholesky::factor(&a).unwrap().inverse();
        let prod = matmul(&a, &inv);
        assert!(prod.rel_err(&Matrix::eye(8)) < 1e-3);
    }

    #[test]
    fn solve_regularized_works() {
        let mut rng = Pcg64::new(3);
        let g = {
            let a = Matrix::randn(6, 6, &mut rng, 0.0, 1.0);
            matmul_bt(&a, &a)
        };
        let b = Matrix::randn(6, 2, &mut rng, 0.0, 1.0);
        let x = solve_regularized(&g, 0.5, &b).unwrap();
        // (G + λI)x ≈ b
        let mut greg = g.clone();
        for i in 0..6 {
            greg.set(i, i, greg.get(i, i) + 0.5);
        }
        assert!(matmul(&greg, &x).rel_err(&b) < 1e-3);
    }

    #[test]
    fn lu_solves_general() {
        let a = Matrix::from_vec(3, 3, vec![0.0, 2.0, 1.0, 1.0, 0.0, 0.0, 3.0, 1.0, 2.0]);
        let b = [5.0f64, 1.0, 10.0];
        let x = lu_solve(&a, &b).unwrap();
        // Verify residual.
        for i in 0..3 {
            let s: f64 = (0..3).map(|j| a.get(i, j) as f64 * x[j]).sum();
            assert!((s - b[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn lu_detects_singular() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(lu_solve(&a, &[1.0, 2.0]).is_err());
    }

    #[test]
    fn vandermonde_interpolates() {
        // c(x) = 3 + 2x − x², points 0..3
        let pts = [0.0, 1.0, 2.0, 3.0];
        let vals: Vec<f64> = pts.iter().map(|&x| 3.0 + 2.0 * x - x * x).collect();
        let c = vandermonde_solve(&pts, &vals).unwrap();
        assert!((c[0] - 3.0).abs() < 1e-9);
        assert!((c[1] - 2.0).abs() < 1e-9);
        assert!((c[2] + 1.0).abs() < 1e-9);
        assert!(c[3].abs() < 1e-9);
    }
}

//! In-place, SIMD-friendly slice kernels for the coded hot paths.
//!
//! Parity encode and peeling recovery are pure streaming arithmetic over
//! equally-shaped `f32` blocks (`parity = Σ members`,
//! `missing = parity − Σ survivors`). The historical implementations went
//! through `Matrix::clone` + `add_assign`, paying one allocation *and* one
//! extra memory pass per operand. These kernels follow the same
//! bounds-check-free slice style as `gemm::gemm_bt_panel`: equal lengths
//! are asserted once, then the loops run over `chunks_exact` windows that
//! LLVM keeps fully vectorized.
//!
//! Operand order is part of the contract: every multi-operand kernel
//! accumulates left to right, exactly like the serial clone-then-add code
//! it replaced, so encode/decode results stay **bit-identical** (the
//! parallel-vs-serial property tests in `tests/codes_prop.rs` pin this).

const LANES: usize = 8;

/// `y[i] += x[i]`.
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    assert_eq!(y.len(), x.len(), "kernel operand length mismatch");
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (yy, xx) in (&mut yc).zip(&mut xc) {
        for (a, b) in yy.iter_mut().zip(xx) {
            *a += *b;
        }
    }
    for (a, b) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *a += *b;
    }
}

/// `y[i] -= x[i]`.
pub fn sub_assign(y: &mut [f32], x: &[f32]) {
    assert_eq!(y.len(), x.len(), "kernel operand length mismatch");
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (yy, xx) in (&mut yc).zip(&mut xc) {
        for (a, b) in yy.iter_mut().zip(xx) {
            *a -= *b;
        }
    }
    for (a, b) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *a -= *b;
    }
}

/// AXPY: `y[i] += alpha · x[i]`.
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len(), "kernel operand length mismatch");
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (yy, xx) in (&mut yc).zip(&mut xc) {
        for (a, b) in yy.iter_mut().zip(xx) {
            *a += alpha * *b;
        }
    }
    for (a, b) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *a += alpha * *b;
    }
}

/// `out = terms[0] + terms[1] + …` into a caller-owned buffer (cleared
/// first) — the parity-encode kernel. `terms` must be non-empty and
/// equally sized.
pub fn sum_into(out: &mut Vec<f32>, terms: &[&[f32]]) {
    assert!(!terms.is_empty(), "sum_into needs at least one term");
    out.clear();
    out.extend_from_slice(terms[0]);
    for t in &terms[1..] {
        add_assign(out, t);
    }
}

/// `Σ terms` as a fresh buffer.
pub fn sum(terms: &[&[f32]]) -> Vec<f32> {
    let mut out = Vec::with_capacity(terms.first().map_or(0, |t| t.len()));
    sum_into(&mut out, terms);
    out
}

/// `base − Σ subs` as a fresh buffer — the peeling-recovery kernel
/// (`missing = parity − Σ survivors`).
pub fn residual(base: &[f32], subs: &[&[f32]]) -> Vec<f32> {
    let mut out = base.to_vec();
    for s in subs {
        sub_assign(&mut out, s);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_sub_assign_cover_remainders() {
        // Lengths straddling the unroll width exercise both loop halves.
        for n in [0usize, 1, 7, 8, 9, 31, 64] {
            let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let mut y = vec![1.0f32; n];
            add_assign(&mut y, &x);
            for (i, &v) in y.iter().enumerate() {
                assert_eq!(v, 1.0 + i as f32, "n={n} i={i}");
            }
            sub_assign(&mut y, &x);
            assert!(y.iter().all(|&v| v == 1.0), "n={n}");
        }
    }

    #[test]
    fn axpy_matches_scalar_loop() {
        let x: Vec<f32> = (0..37).map(|i| (i as f32).sin()).collect();
        let mut y: Vec<f32> = (0..37).map(|i| (i as f32).cos()).collect();
        let want: Vec<f32> = y.iter().zip(&x).map(|(yy, xx)| yy + 2.5 * xx).collect();
        axpy(&mut y, 2.5, &x);
        assert_eq!(y, want);
    }

    #[test]
    fn sum_and_residual_are_left_to_right() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [10.0f32, 20.0, 30.0];
        let c = [100.0f32, 200.0, 300.0];
        let s = sum(&[&a, &b, &c]);
        assert_eq!(s, vec![111.0, 222.0, 333.0]);
        let r = residual(&s, &[&a, &b]);
        assert_eq!(r, vec![100.0, 200.0, 300.0]);
        // Identical to the clone-then-add path it replaced, bit for bit.
        let mut manual = a.to_vec();
        add_assign(&mut manual, &b);
        add_assign(&mut manual, &c);
        assert_eq!(s, manual);
    }

    #[test]
    fn sum_into_reuses_the_buffer() {
        let a = [1.0f32; 16];
        let b = [2.0f32; 16];
        let mut buf = Vec::new();
        sum_into(&mut buf, &[&a, &b]);
        assert_eq!(buf, vec![3.0; 16]);
        let cap = buf.capacity();
        sum_into(&mut buf, &[&b, &b]);
        assert_eq!(buf, vec![4.0; 16]);
        assert_eq!(buf.capacity(), cap, "no reallocation on reuse");
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mismatched_lengths_panic() {
        let mut y = vec![0.0f32; 4];
        add_assign(&mut y, &[0.0; 5]);
    }
}

//! Dense linear algebra substrate: matrices, block partitioning, host
//! GEMM/GEMV, small solves and eigendecompositions.
//!
//! The host kernels here serve three roles: (1) correctness oracle for the
//! AOT-compiled PJRT artifacts, (2) the `HostBackend` compute path used in
//! unit tests, and (3) the "local at the master" small steps of the
//! applications (f×f solves in ALS, p×p eigen in SVD).

pub mod blocked;
pub mod eigen;
pub mod gemm;
pub mod kernels;
pub mod matrix;
pub mod solve;

pub use blocked::{assemble_grid, pad_rows, unpad_rows, GridShape, Partition};
pub use matrix::{BlockBuf, Matrix};

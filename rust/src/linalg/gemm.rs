//! Host GEMM/GEMV: the pure-Rust reference backend and correctness oracle
//! for the PJRT artifacts, and the workhorse for test-sized problems.
//!
//! The kernel is a cache-blocked, 4×4-register-tiled, f32 GEMM with f32
//! accumulation (matching XLA CPU's f32 semantics closely enough for
//! tolerance-based comparison) parallelized over row panels.

use crate::linalg::matrix::Matrix;
use crate::util::threadpool::parallel_for;

/// `C = A · Bᵀ` — the paper's canonical product (Eq. 1). A is m×n, B is
/// l×n, C is m×l. Row-major × row-major-transposed is the dot-product
/// friendly layout, so this is the fastest host path.
pub fn matmul_bt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "A (m×n) · Bᵀ (n×l) needs matching n");
    let m = a.rows;
    let l = b.rows;
    let n = a.cols;
    let mut c = Matrix::zeros(m, l);
    let threads = crate::util::threadpool::num_threads();
    // Parallelize over 64-row panels of A.
    const PANEL: usize = 64;
    let panels = m.div_ceil(PANEL);
    let c_ptr = SendPtr(c.data.as_mut_ptr());
    parallel_for(threads, panels, |p| {
        let r0 = p * PANEL;
        let r1 = (r0 + PANEL).min(m);
        // SAFETY: panels write disjoint row ranges of c.
        let c_panel = unsafe {
            std::slice::from_raw_parts_mut(c_ptr.get().add(r0 * l), (r1 - r0) * l)
        };
        gemm_bt_panel(&a.data[r0 * n..r1 * n], &b.data, c_panel, r1 - r0, l, n);
    });
    c
}

/// `C = A · B` with plain orientations (m×k)·(k×n).
///
/// Historically implemented as `matmul_bt(a, &b.transpose())`, which hid
/// an O(kn) transpose allocation + copy on every plain-orientation call.
/// Now a direct ikj kernel: each row of C accumulates `a[i][kk] ·
/// b.row(kk)` via the in-place AXPY kernel, so B streams row-major with
/// no transpose and no scratch matrix. Parallel over row panels of A.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "A (m×k) · B (k×n) needs matching k");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    let threads = crate::util::threadpool::num_threads();
    const PANEL: usize = 64;
    let panels = m.div_ceil(PANEL);
    let c_ptr = SendPtr(c.data.as_mut_ptr());
    parallel_for(threads, panels, |p| {
        let r0 = p * PANEL;
        let r1 = (r0 + PANEL).min(m);
        // SAFETY: panels write disjoint row ranges of c.
        let c_panel =
            unsafe { std::slice::from_raw_parts_mut(c_ptr.get().add(r0 * n), (r1 - r0) * n) };
        gemm_nn_panel(&a.data[r0 * k..r1 * k], &b.data, c_panel, r1 - r0, n, k);
    });
    c
}

/// Panel kernel for plain orientations: `c[mp×n] += a_panel[mp×k] ·
/// b[k×n]`, row-of-B streaming (ikj order, AXPY inner loop).
fn gemm_nn_panel(a: &[f32], b: &[f32], c: &mut [f32], mp: usize, n: usize, k: usize) {
    for i in 0..mp {
        let c_row = &mut c[i * n..(i + 1) * n];
        for kk in 0..k {
            // No zero-skip: 0·Inf/0·NaN must propagate exactly like the
            // transpose-based path and the naive oracle.
            crate::linalg::kernels::axpy(c_row, a[i * k + kk], &b[kk * n..(kk + 1) * n]);
        }
    }
}

struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl SendPtr {
    /// Accessor so closures capture `&SendPtr` (Sync) rather than the raw
    /// pointer field (edition-2021 disjoint capture would otherwise grab
    /// the non-Sync `*mut f32` directly).
    #[inline]
    fn get(&self) -> *mut f32 {
        self.0
    }
}

/// Panel kernel: c[mp×l] = a_panel[mp×n] · bᵀ where b is l×n.
/// Register-tiled 4×4 with k-blocking.
fn gemm_bt_panel(a: &[f32], b: &[f32], c: &mut [f32], mp: usize, l: usize, n: usize) {
    const KC: usize = 256;
    for kb in (0..n).step_by(KC) {
        let kend = (kb + KC).min(n);
        let mut i = 0;
        while i + 4 <= mp {
            let mut j = 0;
            while j + 4 <= l {
                // 4×4 register tile over bounds-check-free row slices —
                // the slices let LLVM keep the K loop fully vectorized
                // (§Perf iteration 1: +2.3× over indexed access).
                let kw = kend - kb;
                let a0 = &a[i * n + kb..i * n + kend];
                let a1 = &a[(i + 1) * n + kb..(i + 1) * n + kend];
                let a2 = &a[(i + 2) * n + kb..(i + 2) * n + kend];
                let a3 = &a[(i + 3) * n + kb..(i + 3) * n + kend];
                let b0 = &b[j * n + kb..j * n + kend];
                let b1 = &b[(j + 1) * n + kb..(j + 1) * n + kend];
                let b2 = &b[(j + 2) * n + kb..(j + 2) * n + kend];
                let b3 = &b[(j + 3) * n + kb..(j + 3) * n + kend];
                let mut acc = [[0f32; 4]; 4];
                for k in 0..kw {
                    let av = [a0[k], a1[k], a2[k], a3[k]];
                    let bv = [b0[k], b1[k], b2[k], b3[k]];
                    for (ti, &avi) in av.iter().enumerate() {
                        for (tj, &bvj) in bv.iter().enumerate() {
                            acc[ti][tj] += avi * bvj;
                        }
                    }
                }
                for (ti, row) in acc.iter().enumerate() {
                    for (tj, &v) in row.iter().enumerate() {
                        c[(i + ti) * l + j + tj] += v;
                    }
                }
                j += 4;
            }
            // Remainder columns.
            while j < l {
                for ti in 0..4 {
                    let mut s = 0f32;
                    for k in kb..kend {
                        s += a[(i + ti) * n + k] * b[j * n + k];
                    }
                    c[(i + ti) * l + j] += s;
                }
                j += 1;
            }
            i += 4;
        }
        // Remainder rows.
        while i < mp {
            for j in 0..l {
                let mut s = 0f32;
                for k in kb..kend {
                    s += a[i * n + k] * b[j * n + k];
                }
                c[i * l + j] += s;
            }
            i += 1;
        }
    }
}

/// y = A · x (GEMV), parallel over row chunks.
pub fn matvec(a: &Matrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols, x.len());
    let m = a.rows;
    let mut y = vec![0f32; m];
    let threads = crate::util::threadpool::num_threads();
    const PANEL: usize = 256;
    let panels = m.div_ceil(PANEL);
    let y_ptr = SendPtr(y.as_mut_ptr());
    parallel_for(threads, panels, |p| {
        let r0 = p * PANEL;
        let r1 = (r0 + PANEL).min(m);
        let out = unsafe { std::slice::from_raw_parts_mut(y_ptr.get().add(r0), r1 - r0) };
        for (o, r) in (r0..r1).enumerate() {
            let row = &a.data[r * a.cols..(r + 1) * a.cols];
            let mut s = 0f32;
            // Unrolled-by-4 dot.
            let mut k = 0;
            let mut s0 = 0f32;
            let mut s1 = 0f32;
            let mut s2 = 0f32;
            let mut s3 = 0f32;
            while k + 4 <= row.len() {
                s0 += row[k] * x[k];
                s1 += row[k + 1] * x[k + 1];
                s2 += row[k + 2] * x[k + 2];
                s3 += row[k + 3] * x[k + 3];
                k += 4;
            }
            while k < row.len() {
                s += row[k] * x[k];
                k += 1;
            }
            out[o] = s + s0 + s1 + s2 + s3;
        }
    });
    y
}

/// Naive triple-loop GEMM (the oracle for the blocked kernel's tests).
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows);
    let mut c = Matrix::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for k in 0..a.cols {
            let av = a.get(i, k);
            if av == 0.0 {
                continue;
            }
            for j in 0..b.cols {
                c.data[i * b.cols + j] += av * b.get(k, j);
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn matmul_bt_matches_naive() {
        let mut rng = Pcg64::new(1);
        for (m, n, l) in [(1, 1, 1), (4, 4, 4), (7, 13, 9), (65, 33, 70), (128, 64, 128)] {
            let a = Matrix::randn(m, n, &mut rng, 0.0, 1.0);
            let b = Matrix::randn(l, n, &mut rng, 0.0, 1.0);
            let fast = matmul_bt(&a, &b);
            let slow = matmul_naive(&a, &b.transpose());
            assert!(
                fast.rel_err(&slow) < 1e-5,
                "({m},{n},{l}) err={}",
                fast.rel_err(&slow)
            );
        }
    }

    #[test]
    fn matmul_plain_matches_naive() {
        let mut rng = Pcg64::new(2);
        // Shapes straddling the 64-row panel width exercise both the
        // parallel fan-out and the single-panel path of the nn kernel.
        for (m, k, n) in [(1, 1, 1), (31, 17, 23), (64, 9, 40), (130, 65, 70)] {
            let a = Matrix::randn(m, k, &mut rng, 0.0, 1.0);
            let b = Matrix::randn(k, n, &mut rng, 0.0, 1.0);
            let fast = matmul(&a, &b);
            let slow = matmul_naive(&a, &b);
            assert!(
                fast.rel_err(&slow) < 1e-5,
                "({m},{k},{n}) err={}",
                fast.rel_err(&slow)
            );
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg64::new(3);
        let a = Matrix::randn(301, 129, &mut rng, 0.0, 1.0);
        let x: Vec<f32> = (0..129).map(|i| (i as f32).sin()).collect();
        let y = matvec(&a, &x);
        let xm = Matrix::from_vec(129, 1, x.clone());
        let ym = matmul(&a, &xm);
        for i in 0..301 {
            assert!((y[i] - ym.get(i, 0)).abs() < 1e-3 * (1.0 + ym.get(i, 0).abs()));
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg64::new(4);
        let a = Matrix::randn(20, 20, &mut rng, 0.0, 1.0);
        let i = Matrix::eye(20);
        assert!(matmul(&a, &i).rel_err(&a) < 1e-6);
        assert!(matmul(&i, &a).rel_err(&a) < 1e-6);
    }

    #[test]
    fn gram_is_symmetric() {
        let mut rng = Pcg64::new(5);
        let a = Matrix::randn(40, 25, &mut rng, 0.0, 1.0);
        let g = matmul_bt(&a, &a); // A·Aᵀ
        assert_eq!(g.shape(), (40, 40));
        for r in 0..40 {
            for c in 0..40 {
                assert!((g.get(r, c) - g.get(c, r)).abs() < 1e-4);
            }
        }
    }
}

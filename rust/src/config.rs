//! Configuration system: JSON config files + dotted-path overrides.
//!
//! Every experiment is driven by a [`Config`]: platform calibration
//! (straggler model, worker rates), backend selection, seeds and output
//! paths. Defaults reproduce the paper's AWS-Lambda calibration; a JSON
//! file (`--config path.json`) and `--set key=value` overrides adjust any
//! field, e.g. `--set platform.p=0.05 --set backend=pjrt`.

use std::path::{Path, PathBuf};

use crate::coordinator::Env;
use crate::platform::{StragglerModel, StragglerParams, WorkerRates};
use crate::storage::cost::CostModel;
use crate::util::json::{obj, Json};

/// Object-store construction settings (see `storage::MemStore` and
/// `storage::cache::CachedStore`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StoreSettings {
    /// Shard count of the in-memory store.
    pub shards: usize,
    /// Multipart chunk size in bytes; 0 disables chunking.
    pub chunk_bytes: usize,
    /// LRU read-through cache capacity in bytes; 0 disables the cache.
    pub cache_bytes: usize,
}

impl Default for StoreSettings {
    fn default() -> Self {
        StoreSettings {
            shards: crate::storage::DEFAULT_SHARDS,
            chunk_bytes: 0,
            cache_bytes: 0,
        }
    }
}

/// Top-level configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Straggler-injection parameters (paper: p ≈ 0.02 on Lambda).
    pub straggler: StragglerParams,
    /// Worker compute/communication rates.
    pub rates: WorkerRates,
    /// Object-store construction (shards, chunking, cache).
    pub storage: StoreSettings,
    /// Compute backend: "host" or "pjrt".
    pub backend: String,
    /// Artifacts directory for the PJRT backend.
    pub artifacts_dir: PathBuf,
    /// Results output directory.
    pub results_dir: PathBuf,
    /// Host threads for real numerics (0 ⇒ all cores).
    pub threads: usize,
    /// Base seed for all simulations.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            straggler: StragglerParams::default(),
            rates: WorkerRates::default(),
            storage: StoreSettings::default(),
            backend: "host".into(),
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            results_dir: PathBuf::from("results"),
            threads: 0,
            seed: 42,
        }
    }
}

impl Config {
    /// Load from a JSON file over the defaults.
    pub fn load(path: &Path) -> anyhow::Result<Config> {
        let root = crate::util::json::load_file(path)?;
        let mut cfg = Config::default();
        cfg.apply_json(&root)?;
        Ok(cfg)
    }

    /// Apply a JSON object onto this config (unknown keys are errors so
    /// config typos fail loudly).
    pub fn apply_json(&mut self, root: &Json) -> anyhow::Result<()> {
        let fields = root
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("config root must be an object"))?;
        for (key, val) in fields {
            match key.as_str() {
                "platform" | "storage" => {
                    let sub = val
                        .as_obj()
                        .ok_or_else(|| anyhow::anyhow!("'{key}' must be an object"))?;
                    for (k, v) in sub {
                        self.set(&format!("{key}.{k}"), &json_scalar(v))?;
                    }
                }
                other => self.set(other, &json_scalar(val))?,
            }
        }
        Ok(())
    }

    /// Set a single dotted-path field from a string value.
    pub fn set(&mut self, path: &str, value: &str) -> anyhow::Result<()> {
        let f64v = || -> anyhow::Result<f64> {
            value
                .parse()
                .map_err(|_| anyhow::anyhow!("'{path}' expects a number, got '{value}'"))
        };
        match path {
            "platform.p" => self.straggler.p = f64v()?,
            "platform.slow_mu" => self.straggler.slow_mu = f64v()?,
            "platform.slow_sigma" => self.straggler.slow_sigma = f64v()?,
            "platform.slow_min" => self.straggler.slow_min = f64v()?,
            "platform.slow_max" => self.straggler.slow_max = f64v()?,
            "platform.jitter_sigma" => self.straggler.jitter_sigma = f64v()?,
            "platform.invoke_mean_s" => self.rates.invoke_mean_s = f64v()?,
            "platform.invoke_sigma" => self.rates.invoke_sigma = f64v()?,
            "platform.flops_per_s" => self.rates.flops_per_s = f64v()?,
            "platform.s3_latency_s" => self.rates.cost.op_latency_s = f64v()?,
            "platform.s3_bandwidth_bps" => self.rates.cost.bandwidth_bps = f64v()?,
            "storage.shards" => {
                let shards: usize = value.parse()?;
                anyhow::ensure!(shards >= 1, "'storage.shards' must be ≥ 1");
                self.storage.shards = shards;
            }
            "storage.chunk_bytes" => self.storage.chunk_bytes = value.parse()?,
            "storage.cache_bytes" => self.storage.cache_bytes = value.parse()?,
            "backend" => {
                anyhow::ensure!(
                    value == "host" || value == "pjrt",
                    "backend must be 'host' or 'pjrt', got '{value}'"
                );
                self.backend = value.to_string();
            }
            "artifacts_dir" => self.artifacts_dir = PathBuf::from(value),
            "results_dir" => self.results_dir = PathBuf::from(value),
            "threads" => self.threads = value.parse()?,
            "seed" => self.seed = value.parse()?,
            other => anyhow::bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// The straggler model this config describes.
    pub fn model(&self) -> StragglerModel {
        StragglerModel::new(self.straggler, self.rates)
    }

    /// Build the execution environment. For the PJRT backend the returned
    /// runtime must outlive the env. Selecting `backend = "pjrt"` in a
    /// build without the `pjrt` cargo feature is an error (the config
    /// parser accepts the name so config files stay portable across
    /// feature sets).
    pub fn build_env(&self) -> anyhow::Result<(Env, Option<crate::runtime::PjrtRuntime>)> {
        let threads = if self.threads == 0 {
            crate::util::threadpool::num_threads()
        } else {
            self.threads
        };
        let (backend, rt): (
            std::sync::Arc<dyn crate::runtime::ComputeBackend>,
            Option<crate::runtime::PjrtRuntime>,
        ) = match self.backend.as_str() {
            #[cfg(feature = "pjrt")]
            "pjrt" => {
                let rt = crate::runtime::PjrtRuntime::start(&self.artifacts_dir)?;
                (
                    std::sync::Arc::new(crate::runtime::PjrtBackend::new(rt.handle())),
                    Some(rt),
                )
            }
            #[cfg(not(feature = "pjrt"))]
            "pjrt" => anyhow::bail!(
                "backend 'pjrt' requires building with `cargo build --features pjrt`"
            ),
            _ => (std::sync::Arc::new(crate::runtime::HostBackend), None),
        };
        let store: std::sync::Arc<dyn crate::storage::ObjectStore> = std::sync::Arc::new(
            crate::storage::MemStore::with_config(self.storage.shards, self.storage.chunk_bytes),
        );
        let env = Env::builder()
            .backend(backend)
            .store(store)
            .cache_bytes(self.storage.cache_bytes)
            .model(self.model())
            .threads(threads)
            .build();
        Ok((env, rt))
    }

    pub fn to_json(&self) -> Json {
        obj()
            .field(
                "platform",
                obj()
                    .field("p", self.straggler.p)
                    .field("slow_mu", self.straggler.slow_mu)
                    .field("slow_sigma", self.straggler.slow_sigma)
                    .field("slow_min", self.straggler.slow_min)
                    .field("slow_max", self.straggler.slow_max)
                    .field("jitter_sigma", self.straggler.jitter_sigma)
                    .field("invoke_mean_s", self.rates.invoke_mean_s)
                    .field("invoke_sigma", self.rates.invoke_sigma)
                    .field("flops_per_s", self.rates.flops_per_s)
                    .field("s3_latency_s", self.rates.cost.op_latency_s)
                    .field("s3_bandwidth_bps", self.rates.cost.bandwidth_bps)
                    .build(),
            )
            .field(
                "storage",
                obj()
                    .field("shards", self.storage.shards)
                    .field("chunk_bytes", self.storage.chunk_bytes)
                    .field("cache_bytes", self.storage.cache_bytes)
                    .build(),
            )
            .field("backend", self.backend.as_str())
            .field("artifacts_dir", self.artifacts_dir.display().to_string())
            .field("results_dir", self.results_dir.display().to_string())
            .field("threads", self.threads)
            .field("seed", self.seed)
            .build()
    }

    /// Write a JSON result document under `results_dir`.
    pub fn write_result(&self, name: &str, value: &Json) -> anyhow::Result<PathBuf> {
        std::fs::create_dir_all(&self.results_dir)?;
        let path = self.results_dir.join(format!("{name}.json"));
        std::fs::write(&path, value.to_string_pretty())?;
        Ok(path)
    }
}

fn json_scalar(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        other => other.to_string_compact(),
    }
}

/// A default CostModel mirror (re-exported for doc purposes).
pub fn default_cost() -> CostModel {
    CostModel::default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_calibration() {
        let c = Config::default();
        assert!((c.straggler.p - 0.02).abs() < 1e-12);
        assert_eq!(c.backend, "host");
    }

    #[test]
    fn set_overrides() {
        let mut c = Config::default();
        c.set("platform.p", "0.05").unwrap();
        c.set("backend", "pjrt").unwrap();
        c.set("seed", "7").unwrap();
        c.set("threads", "2").unwrap();
        assert!((c.straggler.p - 0.05).abs() < 1e-12);
        assert_eq!(c.backend, "pjrt");
        assert_eq!(c.seed, 7);
        assert!(c.set("nope", "1").is_err());
        assert!(c.set("platform.p", "abc").is_err());
        assert!(c.set("backend", "gpu").is_err());
    }

    #[test]
    fn storage_settings_roundtrip_and_validate() {
        let mut c = Config::default();
        assert_eq!(c.storage, StoreSettings::default());
        c.set("storage.shards", "4").unwrap();
        c.set("storage.chunk_bytes", "65536").unwrap();
        c.set("storage.cache_bytes", "1048576").unwrap();
        assert_eq!(c.storage.shards, 4);
        assert_eq!(c.storage.chunk_bytes, 65536);
        assert_eq!(c.storage.cache_bytes, 1048576);
        assert!(c.set("storage.shards", "0").is_err());
        assert!(c.set("storage.nope", "1").is_err());
        // JSON round-trip carries the storage block.
        let j = c.to_json();
        let mut c2 = Config::default();
        c2.apply_json(&j).unwrap();
        assert_eq!(c2.storage.shards, 4);
        assert_eq!(c2.storage.cache_bytes, 1048576);
        // And build_env wires the cache through.
        let (env, _) = c2.build_env().unwrap();
        assert!(env.cache.is_some());
        let (env, _) = Config::default().build_env().unwrap();
        assert!(env.cache.is_none());
    }

    #[test]
    fn json_roundtrip() {
        let mut c = Config::default();
        c.set("platform.p", "0.1").unwrap();
        c.set("platform.flops_per_s", "5e8").unwrap();
        let j = c.to_json();
        let mut c2 = Config::default();
        c2.apply_json(&j).unwrap();
        assert!((c2.straggler.p - 0.1).abs() < 1e-12);
        assert!((c2.rates.flops_per_s - 5e8).abs() < 1.0);
    }

    #[test]
    fn load_from_file() {
        let dir = std::env::temp_dir().join(format!("slec-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(&path, r#"{"platform": {"p": 0.03}, "seed": 9}"#).unwrap();
        let c = Config::load(&path).unwrap();
        assert!((c.straggler.p - 0.03).abs() < 1e-12);
        assert_eq!(c.seed, 9);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unknown_json_key_fails() {
        let mut c = Config::default();
        let j = crate::util::json::parse(r#"{"bogus": 1}"#).unwrap();
        assert!(c.apply_json(&j).is_err());
    }

    #[test]
    fn build_env_host() {
        let c = Config::default();
        let (env, rt) = c.build_env().unwrap();
        assert!(rt.is_none());
        assert_eq!(env.backend.name(), "host");
        assert!(env.threads >= 1);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn build_env_pjrt_requires_feature() {
        let mut c = Config::default();
        c.set("backend", "pjrt").unwrap();
        let err = c.build_env().unwrap_err();
        assert!(err.to_string().contains("--features pjrt"), "{err}");
    }
}

//! Coding-theoretic core: the paper's local product code, the peeling
//! decoder, baseline codes (product [16], polynomial [18]), coded matvec
//! ([17]-style), the §III theory bounds, and Monte-Carlo validation.

pub mod layout;
pub mod local_product;
pub mod matvec;
pub mod montecarlo;
pub mod peeling;
pub mod polynomial;
pub mod product;
pub mod theory;

/// Straggler-mitigation strategy selector used by the coordinator and the
/// figure harnesses (Fig 5's four contenders).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scheme {
    /// No redundancy; wait for every worker.
    Uncoded,
    /// Speculative execution: wait until `wait_frac` of tasks finish, then
    /// relaunch the stragglers (first finisher wins).
    Speculative { wait_frac: f64 },
    /// The paper's local product code with group sizes (l_a, l_b).
    LocalProduct { l_a: usize, l_b: usize },
    /// Product code with global MDS parities (t_a, t_b per axis).
    Product { t_a: usize, t_b: usize },
    /// Polynomial (MDS) code with the given redundancy over threshold K.
    Polynomial { redundancy: f64 },
}

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Uncoded => "uncoded",
            Scheme::Speculative { .. } => "speculative",
            Scheme::LocalProduct { .. } => "local-product",
            Scheme::Product { .. } => "product",
            Scheme::Polynomial { .. } => "polynomial",
        }
    }

    /// Parse from a CLI string like `local-product`, `speculative:0.79`,
    /// `local-product:10x10`, `product:1x1`, `polynomial:0.21`.
    pub fn parse(s: &str) -> anyhow::Result<Scheme> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        Ok(match head {
            "uncoded" => Scheme::Uncoded,
            "speculative" => Scheme::Speculative {
                wait_frac: arg.map(|a| a.parse()).transpose()?.unwrap_or(0.79),
            },
            "local-product" => {
                let (la, lb) = parse_pair(arg.unwrap_or("10x10"))?;
                Scheme::LocalProduct { l_a: la, l_b: lb }
            }
            "product" => {
                let (ta, tb) = parse_pair(arg.unwrap_or("1x1"))?;
                Scheme::Product { t_a: ta, t_b: tb }
            }
            "polynomial" => Scheme::Polynomial {
                redundancy: arg.map(|a| a.parse()).transpose()?.unwrap_or(0.21),
            },
            other => anyhow::bail!("unknown scheme '{other}'"),
        })
    }
}

fn parse_pair(s: &str) -> anyhow::Result<(usize, usize)> {
    let (a, b) = s
        .split_once('x')
        .ok_or_else(|| anyhow::anyhow!("expected AxB, got '{s}'"))?;
    Ok((a.parse()?, b.parse()?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_parsing() {
        assert_eq!(Scheme::parse("uncoded").unwrap(), Scheme::Uncoded);
        assert_eq!(
            Scheme::parse("speculative:0.9").unwrap(),
            Scheme::Speculative { wait_frac: 0.9 }
        );
        assert_eq!(
            Scheme::parse("local-product:5x8").unwrap(),
            Scheme::LocalProduct { l_a: 5, l_b: 8 }
        );
        assert_eq!(
            Scheme::parse("product:2x3").unwrap(),
            Scheme::Product { t_a: 2, t_b: 3 }
        );
        assert!(matches!(
            Scheme::parse("polynomial").unwrap(),
            Scheme::Polynomial { .. }
        ));
        assert!(Scheme::parse("bogus").is_err());
        assert!(Scheme::parse("local-product:5").is_err());
    }

    #[test]
    fn scheme_names() {
        assert_eq!(Scheme::parse("local-product").unwrap().name(), "local-product");
        assert_eq!(
            Scheme::Speculative { wait_frac: 0.79 }.name(),
            "speculative"
        );
    }
}

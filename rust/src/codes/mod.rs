//! Coding-theoretic core: the paper's local product code, the peeling
//! decoder, baseline codes (product [16], polynomial [18]), coded matvec
//! ([17]-style), the §III theory bounds, and Monte-Carlo validation.

pub mod layout;
pub mod local_product;
pub mod matvec;
pub mod montecarlo;
pub mod peeling;
pub mod polynomial;
pub mod product;
pub mod scheme;
pub mod theory;

pub use scheme::{CodingScheme, ComputePolicy, DecodePlan, DecodeProbe, EncodePlan, JobShape};

/// Straggler-mitigation strategy selector used by the coordinator and the
/// figure harnesses (Fig 5's four contenders).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Scheme {
    /// No redundancy; wait for every worker.
    Uncoded,
    /// Speculative execution: wait until `wait_frac` of tasks finish, then
    /// relaunch the stragglers (first finisher wins).
    Speculative { wait_frac: f64 },
    /// The paper's local product code with group sizes (l_a, l_b).
    LocalProduct { l_a: usize, l_b: usize },
    /// Product code with global MDS parities (t_a, t_b per axis).
    Product { t_a: usize, t_b: usize },
    /// Polynomial (MDS) code with the given redundancy over threshold K.
    Polynomial { redundancy: f64 },
}

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Uncoded => "uncoded",
            Scheme::Speculative { .. } => "speculative",
            Scheme::LocalProduct { .. } => "local-product",
            Scheme::Product { .. } => "product",
            Scheme::Polynomial { .. } => "polynomial",
        }
    }

    /// Parse from a CLI string like `local-product`, `speculative:0.79`,
    /// `local-product:10x10`, `product:1x1`, `polynomial:0.21` — resolved
    /// through the one [`scheme::REGISTRY`] table.
    pub fn parse(s: &str) -> anyhow::Result<Scheme> {
        scheme::parse(s)
    }

    /// Build the pluggable [`CodingScheme`] object for an `s_a × s_b`
    /// systematic grid, validating parameters against the partitioning.
    pub fn instantiate(&self, s_a: usize, s_b: usize) -> anyhow::Result<Box<dyn CodingScheme>> {
        scheme::instantiate(*self, s_a, s_b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_parsing() {
        assert_eq!(Scheme::parse("uncoded").unwrap(), Scheme::Uncoded);
        assert_eq!(
            Scheme::parse("speculative:0.9").unwrap(),
            Scheme::Speculative { wait_frac: 0.9 }
        );
        assert_eq!(
            Scheme::parse("local-product:5x8").unwrap(),
            Scheme::LocalProduct { l_a: 5, l_b: 8 }
        );
        assert_eq!(
            Scheme::parse("product:2x3").unwrap(),
            Scheme::Product { t_a: 2, t_b: 3 }
        );
        assert!(matches!(
            Scheme::parse("polynomial").unwrap(),
            Scheme::Polynomial { .. }
        ));
        assert!(Scheme::parse("bogus").is_err());
        assert!(Scheme::parse("local-product:5").is_err());
    }

    #[test]
    fn scheme_names() {
        assert_eq!(Scheme::parse("local-product").unwrap().name(), "local-product");
        assert_eq!(
            Scheme::Speculative { wait_frac: 0.79 }.name(),
            "speculative"
        );
    }
}

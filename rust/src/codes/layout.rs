//! Coded row-block layouts: where systematic blocks and parity blocks live
//! in an encoded matrix, for all schemes.
//!
//! The paper's local encoding (§II-B) inserts one parity row-block after
//! every `L` systematic row-blocks, so an input with `s` row-blocks
//! (s divisible by L) becomes `s + s/L` coded row-blocks, grouped into
//! `s/L` groups of `L+1`.

/// Identity of a coded row-block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CodedBlock {
    /// Systematic block carrying original row-block `orig`.
    Systematic { orig: usize },
    /// Parity block of local `group` (sum of that group's L systematic
    /// blocks).
    Parity { group: usize },
}

/// Local-parity layout with parameter `l`: groups of `l` systematic blocks
/// each followed by one parity block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LocalLayout {
    /// Number of systematic blocks (original row-blocks).
    pub systematic: usize,
    /// Group length L.
    pub l: usize,
}

impl LocalLayout {
    pub fn new(systematic: usize, l: usize) -> LocalLayout {
        assert!(l > 0, "L must be positive");
        assert!(systematic > 0, "need at least one block");
        assert_eq!(
            systematic % l,
            0,
            "systematic blocks ({systematic}) must be divisible by L ({l})"
        );
        LocalLayout { systematic, l }
    }

    /// Number of groups (= number of parity blocks).
    pub fn groups(&self) -> usize {
        self.systematic / self.l
    }

    /// Total coded blocks.
    pub fn coded_len(&self) -> usize {
        self.systematic + self.groups()
    }

    /// Identify the coded block at coded index `k` (parities interleaved:
    /// [S_0..S_{L-1}, P_0, S_L..S_{2L-1}, P_1, ...]).
    pub fn block_at(&self, k: usize) -> CodedBlock {
        assert!(k < self.coded_len());
        let group = k / (self.l + 1);
        let within = k % (self.l + 1);
        if within < self.l {
            CodedBlock::Systematic {
                orig: group * self.l + within,
            }
        } else {
            CodedBlock::Parity { group }
        }
    }

    /// Coded index of original systematic block `orig`.
    pub fn systematic_pos(&self, orig: usize) -> usize {
        assert!(orig < self.systematic);
        let group = orig / self.l;
        group * (self.l + 1) + (orig % self.l)
    }

    /// Coded index of group `g`'s parity block.
    pub fn parity_pos(&self, g: usize) -> usize {
        assert!(g < self.groups());
        g * (self.l + 1) + self.l
    }

    /// Original systematic blocks belonging to group `g`.
    pub fn group_members(&self, g: usize) -> std::ops::Range<usize> {
        assert!(g < self.groups());
        g * self.l..(g + 1) * self.l
    }

    /// Fraction of extra computation the code adds along this axis:
    /// `coded_len / systematic − 1` = 1/L.
    pub fn redundancy(&self) -> f64 {
        self.coded_len() as f64 / self.systematic as f64 - 1.0
    }
}

/// Redundancy of the full 2-D local product code:
/// `(L_A+1)(L_B+1)/(L_A·L_B) − 1` (e.g. 21% for L_A=L_B=10, §II-B).
pub fn product_redundancy(la: usize, lb: usize) -> f64 {
    ((la + 1) * (lb + 1)) as f64 / (la * lb) as f64 - 1.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_interleaving() {
        let l = LocalLayout::new(4, 2);
        assert_eq!(l.groups(), 2);
        assert_eq!(l.coded_len(), 6);
        use CodedBlock::*;
        let blocks: Vec<CodedBlock> = (0..6).map(|k| l.block_at(k)).collect();
        assert_eq!(
            blocks,
            vec![
                Systematic { orig: 0 },
                Systematic { orig: 1 },
                Parity { group: 0 },
                Systematic { orig: 2 },
                Systematic { orig: 3 },
                Parity { group: 1 },
            ]
        );
    }

    #[test]
    fn positions_invert_block_at() {
        let l = LocalLayout::new(12, 3);
        for orig in 0..12 {
            let k = l.systematic_pos(orig);
            assert_eq!(l.block_at(k), CodedBlock::Systematic { orig });
        }
        for g in 0..4 {
            let k = l.parity_pos(g);
            assert_eq!(l.block_at(k), CodedBlock::Parity { group: g });
        }
    }

    #[test]
    fn group_members_partition() {
        let l = LocalLayout::new(9, 3);
        let all: Vec<usize> = (0..3).flat_map(|g| l.group_members(g)).collect();
        assert_eq!(all, (0..9).collect::<Vec<_>>());
    }

    #[test]
    fn redundancy_values() {
        // L=1: 100% along an axis; 2-D L_A=L_B=1 → 300% total blocks ( (2·2)/(1·1) − 1 ).
        assert!((LocalLayout::new(4, 1).redundancy() - 1.0).abs() < 1e-12);
        // L=10 axis redundancy 10%; 2-D 21% (paper).
        assert!((LocalLayout::new(10, 10).redundancy() - 0.1).abs() < 1e-12);
        assert!((product_redundancy(10, 10) - 0.21).abs() < 1e-12);
        // L_A=L_B=5 → 44% (paper §II-B).
        assert!((product_redundancy(5, 5) - 0.44).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "divisible")]
    fn rejects_non_divisible() {
        LocalLayout::new(10, 3);
    }
}

//! The pluggable coding-scheme API: one trait, one registry, one driver.
//!
//! The paper's framework is scheme-agnostic — encode → compute → decode
//! phases on serverless workers, with local product codes as one
//! instantiation among uncoded, speculative, global-parity product and
//! polynomial codes. This module makes that pluggability explicit:
//!
//! - [`ComputePolicy`] is the event-driven compute-phase contract shared
//!   by the matmul and matvec workloads: task fan-out, [`Termination`]
//!   rule, and a stateful earliest-decodable probe.
//! - [`CodingScheme`] extends it with the matmul job surface — encode
//!   plan, decode plan, and the numeric encode/product/decode hooks — so
//!   the single generic driver ([`crate::coordinator::driver::run_job`])
//!   and the timing-only scenario runner ([`crate::platform::scenario`])
//!   both execute any scheme without per-scheme branches.
//! - [`REGISTRY`] is the one name → constructor table behind
//!   [`Scheme::parse`], the CLI's `--scheme help`, scenario JSON dispatch
//!   and the figure harnesses.
//!
//! Adding a sixth scheme is one new file: implement [`CodingScheme`],
//! add a [`SchemeInfo`] row, and every entry point picks it up (see
//! DESIGN.md §Adding a scheme for the trait contract and the RNG
//! draw-order compatibility rules).

use crate::codes::Scheme;
use crate::linalg::matrix::BlockBuf;
use crate::platform::event::Termination;
use crate::platform::straggler::WorkProfile;
use crate::runtime::ComputeBackend;

/// Encode phases relaunch stragglers at this quantile (every scheme uses
/// the same value so sampled timelines stay comparable across schemes).
pub const ENCODE_WAIT_FRAC: f64 = 0.95;

/// Decode phases (where parallel) relaunch stragglers at this quantile.
pub const DECODE_WAIT_FRAC: f64 = 0.8;

/// Geometry of one matmul job at *virtual* (simulated) scale: the
/// systematic output grid is `s_a × s_b` blocks of
/// `block_rows × block_cols`, with inner dimension `inner`.
#[derive(Debug, Clone, Copy)]
pub struct JobShape {
    pub s_a: usize,
    pub s_b: usize,
    pub block_rows: usize,
    pub inner: usize,
    pub block_cols: usize,
}

impl JobShape {
    /// Shape from full virtual dims `(rows_a, inner, rows_b)`.
    pub fn new(s_a: usize, s_b: usize, dims: (usize, usize, usize)) -> JobShape {
        JobShape {
            s_a,
            s_b,
            block_rows: dims.0 / s_a,
            inner: dims.1,
            block_cols: dims.2 / s_b,
        }
    }

    /// Work profile of one compute-phase block product.
    pub fn compute_profile(&self) -> WorkProfile {
        WorkProfile::block_product(self.block_rows, self.inner, self.block_cols)
    }
}

/// Timing plan of a scheme's encode phase.
#[derive(Debug, Clone)]
pub struct EncodePlan {
    /// Per-worker profile (the fleet is uniform).
    pub profile: WorkProfile,
    /// Phase termination rule (conventionally speculative at
    /// [`ENCODE_WAIT_FRAC`]).
    pub termination: Termination,
    /// Blocks read by the encode workers (report accounting).
    pub blocks_read: usize,
}

/// Timing plan of a scheme's decode phase, derived from the compute
/// phase's arrival mask.
#[derive(Debug, Clone)]
pub struct DecodePlan {
    /// One profile per decode worker with work; empty ⇒ no decode phase.
    pub profiles: Vec<WorkProfile>,
    pub termination: Termination,
    /// Blocks read during recovery (the Fig-5 cost driver).
    pub blocks_read: usize,
    /// Output cells no parity can recover — the recompute fallback's task
    /// count (0 under earliest-decodable termination).
    pub undecodable: usize,
}

impl DecodePlan {
    /// No decode work at all (uncoded schemes, or nothing straggled).
    pub fn none() -> DecodePlan {
        DecodePlan {
            profiles: Vec::new(),
            termination: Termination::WaitAll,
            blocks_read: 0,
            undecodable: 0,
        }
    }
}

/// Stateful decodability predicate consulted by
/// [`Termination::EarliestDecodable`]: receives the arrival mask plus
/// `Some(index)` of the task that just arrived (or was partially
/// credited) and returns `true` when the phase may cut off. A `None`
/// hint is a **pure feasibility query** over an arbitrary hypothetical
/// mask — the up-front zero-requirement probe and the post-death
/// infeasibility re-check — and must not mutate the probe's state.
/// Probes must never draw from the job RNG (draw-order contract).
pub type DecodeProbe = Box<dyn FnMut(&[bool], Option<usize>) -> bool + Send>;

/// Event-driven compute-phase policy — the sub-trait shared by the matmul
/// and matvec workloads.
pub trait ComputePolicy: Send + Sync {
    /// Compute-phase task fan-out (the coded grid size).
    fn compute_tasks(&self) -> usize;

    /// Compute-phase termination rule.
    fn compute_termination(&self) -> Termination;

    /// Fresh decodability probe for one compute phase. Only consulted
    /// under [`Termination::EarliestDecodable`]; the default never fires.
    fn decode_probe(&self) -> DecodeProbe {
        Box::new(|_, _| false)
    }

    /// Can this policy consume a straggler's *partial* block-product?
    /// Linear schemes whose decode is an AXPY reduction over summands can
    /// (a prefix of a block product is a usable summand); `false` —
    /// the safe default — makes the scenario runner discard straggler
    /// work even when the `"progress"` section asks to exploit it.
    fn partial_credit(&self) -> bool {
        false
    }
}

/// A pluggable straggler-mitigation scheme for the coded matmul workflow.
///
/// The trait splits into a *timing* surface (encode/decode plans,
/// compute policy) consumed by both the coordinator and the timing-only
/// scenario runner, and a *numeric* surface (encode/product/decode
/// through a [`ComputeBackend`]) consumed by the coordinator alone. See
/// DESIGN.md §Adding a scheme for the full contract.
pub trait CodingScheme: ComputePolicy {
    /// Registry name (also the `JobReport` scheme label).
    fn name(&self) -> &'static str;

    /// Redundant-computation fraction of the scheme.
    fn redundancy(&self) -> f64 {
        0.0
    }

    /// Encode-phase plan for a `fleet`-worker encode fleet; `None` ⇒ the
    /// scheme has no encode phase (uncoded/speculative).
    fn encode_plan(&self, shape: &JobShape, fleet: usize) -> Option<EncodePlan> {
        let _ = (shape, fleet);
        None
    }

    /// Decode-phase plan from the compute-phase arrival mask.
    fn decode_plan(
        &self,
        arrived: &[bool],
        shape: &JobShape,
        decode_workers: usize,
    ) -> DecodePlan;

    /// Coded compute-grid dims `(rows, cols)` — plan metadata for the
    /// storage-aware scenario timing model: grid cell `c` reads coded
    /// a-block `c / cols` and coded b-block `c % cols` (the same
    /// row-major convention as [`CodingScheme::cell_product`]). 1-D
    /// schemes (polynomial) keep the `1 × n` default, where cell `c`
    /// reads coded input pair `c`. Must satisfy
    /// `rows · cols == compute_tasks()`.
    fn coded_grid_dims(&self) -> (usize, usize) {
        (1, self.compute_tasks())
    }

    /// Can the scheme produce real numerics at this size? (Polynomial
    /// codes past their conditioning wall return `false`; the driver then
    /// simulates timing only and reports `numerics_ok = false`.)
    fn numerics_feasible(&self) -> bool {
        true
    }

    /// Does the job stage its coded inputs and result blocks in the
    /// object store? (The paper's serverless dataflow for the local
    /// scheme; baselines skip it.)
    fn stages_blocks_in_store(&self) -> bool {
        false
    }

    /// Numerically encode both sides through the backend; returns the
    /// inputs the compute cells draw from. Schemes that encode lazily per
    /// task (polynomial) return the plain blocks. Blocks are shared
    /// [`BlockBuf`] handles: systematic coded cells are refcount bumps of
    /// the input blocks, and the driver stages the returned handles into
    /// the object store without copying.
    fn encode_numeric(
        &self,
        backend: &dyn ComputeBackend,
        a_blocks: &[BlockBuf],
        b_blocks: &[BlockBuf],
    ) -> (Vec<BlockBuf>, Vec<BlockBuf>);

    /// Numeric result of compute cell `cell`. Default: the cross product
    /// of the encoded sides over a row-major `… × b_coded.len()` grid.
    fn cell_product(
        &self,
        backend: &dyn ComputeBackend,
        a_coded: &[BlockBuf],
        b_coded: &[BlockBuf],
        cell: usize,
    ) -> BlockBuf {
        let rb = b_coded.len();
        BlockBuf::new(backend.block_product(
            a_coded[cell / rb].as_matrix(),
            b_coded[cell % rb].as_matrix(),
        ))
    }

    /// Numeric decode: consume the computed grid (`None` = never
    /// computed) and return the `s_a × s_b` systematic output blocks in
    /// row-major order. `arrival_order` lists completed cells in
    /// completion order (wait-k schemes decode from the first K). Grid
    /// cells arrive as shared [`BlockBuf`] handles (the driver re-reads
    /// staged block-products from the store as refcount bumps);
    /// already-present systematic outputs should be returned as clones of
    /// those handles, not copies.
    fn decode_numeric(
        &self,
        backend: &dyn ComputeBackend,
        grid: Vec<Option<BlockBuf>>,
        arrival_order: &[usize],
    ) -> anyhow::Result<Vec<BlockBuf>>;
}

// ---------------------------------------------------------------------------
// Trivial schemes: uncoded and speculative execution
// ---------------------------------------------------------------------------

/// No redundancy; the compute phase waits for every worker.
#[derive(Debug, Clone, Copy)]
pub struct UncodedScheme {
    pub s_a: usize,
    pub s_b: usize,
}

/// Speculative execution: wait for `wait_frac` of the tasks, then
/// relaunch the stragglers (first finisher wins) — the paper's §I
/// baseline.
#[derive(Debug, Clone, Copy)]
pub struct SpeculativeScheme {
    pub s_a: usize,
    pub s_b: usize,
    pub wait_frac: f64,
}

/// Shared numeric path of the uncoded family: every systematic block
/// product eventually arrives, so decode is a plain unwrap.
fn unwrap_full_grid(grid: Vec<Option<BlockBuf>>) -> anyhow::Result<Vec<BlockBuf>> {
    grid.into_iter()
        .enumerate()
        .map(|(i, c)| c.ok_or_else(|| anyhow::anyhow!("uncoded cell {i} missing")))
        .collect()
}

impl ComputePolicy for UncodedScheme {
    fn compute_tasks(&self) -> usize {
        self.s_a * self.s_b
    }

    fn compute_termination(&self) -> Termination {
        Termination::WaitAll
    }
}

impl CodingScheme for UncodedScheme {
    fn name(&self) -> &'static str {
        "uncoded"
    }

    fn coded_grid_dims(&self) -> (usize, usize) {
        (self.s_a, self.s_b)
    }

    fn decode_plan(&self, arrived: &[bool], _shape: &JobShape, _workers: usize) -> DecodePlan {
        // No parity exists: any cell missing at termination (a worker
        // churn casualty) is unrecoverable, not silently complete.
        DecodePlan {
            undecodable: arrived.iter().filter(|&&a| !a).count(),
            ..DecodePlan::none()
        }
    }

    fn encode_numeric(
        &self,
        _backend: &dyn ComputeBackend,
        a_blocks: &[BlockBuf],
        b_blocks: &[BlockBuf],
    ) -> (Vec<BlockBuf>, Vec<BlockBuf>) {
        // Shared handles: "encoding" an uncoded job is pure refcount bumps.
        (a_blocks.to_vec(), b_blocks.to_vec())
    }

    fn decode_numeric(
        &self,
        _backend: &dyn ComputeBackend,
        grid: Vec<Option<BlockBuf>>,
        _arrival_order: &[usize],
    ) -> anyhow::Result<Vec<BlockBuf>> {
        unwrap_full_grid(grid)
    }
}

impl ComputePolicy for SpeculativeScheme {
    fn compute_tasks(&self) -> usize {
        self.s_a * self.s_b
    }

    fn compute_termination(&self) -> Termination {
        Termination::Speculative {
            wait_frac: self.wait_frac,
        }
    }
}

impl CodingScheme for SpeculativeScheme {
    fn name(&self) -> &'static str {
        "speculative"
    }

    fn coded_grid_dims(&self) -> (usize, usize) {
        (self.s_a, self.s_b)
    }

    fn decode_plan(&self, arrived: &[bool], _shape: &JobShape, _workers: usize) -> DecodePlan {
        // Speculation re-executes but cannot reconstruct: cells still
        // missing at termination stay undecodable, like the uncoded case.
        DecodePlan {
            undecodable: arrived.iter().filter(|&&a| !a).count(),
            ..DecodePlan::none()
        }
    }

    fn encode_numeric(
        &self,
        _backend: &dyn ComputeBackend,
        a_blocks: &[BlockBuf],
        b_blocks: &[BlockBuf],
    ) -> (Vec<BlockBuf>, Vec<BlockBuf>) {
        (a_blocks.to_vec(), b_blocks.to_vec())
    }

    fn decode_numeric(
        &self,
        _backend: &dyn ComputeBackend,
        grid: Vec<Option<BlockBuf>>,
        _arrival_order: &[usize],
    ) -> anyhow::Result<Vec<BlockBuf>> {
        unwrap_full_grid(grid)
    }
}

// ---------------------------------------------------------------------------
// Instantiation: parsed params → trait objects
// ---------------------------------------------------------------------------

/// Build the matmul-workload scheme object for an `s_a × s_b` systematic
/// grid, validating the scheme's parameters against the partitioning.
pub fn instantiate(
    scheme: Scheme,
    s_a: usize,
    s_b: usize,
) -> anyhow::Result<Box<dyn CodingScheme>> {
    Ok(match scheme {
        Scheme::Uncoded => Box::new(UncodedScheme { s_a, s_b }),
        Scheme::Speculative { wait_frac } => Box::new(SpeculativeScheme { s_a, s_b, wait_frac }),
        Scheme::LocalProduct { l_a, l_b } => Box::new(
            crate::codes::local_product::LocalProductScheme::new(s_a, l_a, s_b, l_b)?,
        ),
        Scheme::Product { t_a, t_b } => Box::new(
            crate::codes::product::ProductScheme::new(s_a, t_a, s_b, t_b),
        ),
        Scheme::Polynomial { redundancy } => Box::new(
            crate::codes::polynomial::PolynomialScheme::new(s_a, s_b, redundancy)?,
        ),
    })
}

/// Build the matvec-workload compute policy (and the 2-D code it decodes
/// with, when coded) for `s` systematic row-blocks.
pub fn instantiate_matvec(
    scheme: Scheme,
    s: usize,
) -> anyhow::Result<(
    Option<crate::codes::matvec::CodedMatvec2D>,
    Box<dyn ComputePolicy>,
)> {
    use crate::codes::matvec::{CodedMatvec2D, Matvec2DPolicy, PlainMatvecPolicy};
    Ok(match scheme {
        Scheme::LocalProduct { l_a, l_b } => {
            // The 2-D matvec construction is square; a rectangular group
            // spec would silently run a different code than requested.
            anyhow::ensure!(
                l_a == l_b,
                "matvec local-product needs square group sizes, got {l_a}x{l_b}"
            );
            let code = CodedMatvec2D::new(s, l_a)?;
            (Some(code), Box::new(Matvec2DPolicy { code }))
        }
        Scheme::Uncoded => (
            None,
            Box::new(PlainMatvecPolicy {
                tasks: s,
                termination: Termination::WaitAll,
            }),
        ),
        Scheme::Speculative { wait_frac } => (
            None,
            Box::new(PlainMatvecPolicy {
                tasks: s,
                termination: Termination::Speculative { wait_frac },
            }),
        ),
        other => anyhow::bail!("matvec engine does not support {:?}", other),
    })
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// One registered scheme: the name → constructor row behind CLI parsing,
/// scenario JSON dispatch, `--scheme help`, and the conformance suite.
pub struct SchemeInfo {
    /// Registry name (`--scheme <name>[:params]`, scenario `"scheme"`).
    pub name: &'static str,
    /// Parameter syntax after the colon, `""` when the scheme takes none.
    pub params: &'static str,
    /// Defaults applied when the params are omitted.
    pub default_params: &'static str,
    /// Params valid on the conformance suite's small 4×4 systematic grid.
    pub smoke_params: &'static str,
    /// One-line description (CLI help and the README scheme table).
    pub summary: &'static str,
    parse: fn(Option<&str>) -> anyhow::Result<Scheme>,
}

impl SchemeInfo {
    /// Construct the parsed-params [`Scheme`] from an optional arg
    /// string; an omitted arg is substituted with `default_params` (the
    /// registry row is the single source of defaults).
    pub fn parse_args(&self, arg: Option<&str>) -> anyhow::Result<Scheme> {
        let arg = arg.or(if self.default_params.is_empty() {
            None
        } else {
            Some(self.default_params)
        });
        (self.parse)(arg)
    }

    /// The scheme string the conformance suite runs (`name[:smoke]`).
    pub fn smoke_spec(&self) -> String {
        if self.smoke_params.is_empty() {
            self.name.to_string()
        } else {
            format!("{}:{}", self.name, self.smoke_params)
        }
    }
}

fn no_params(scheme: Scheme, name: &str, arg: Option<&str>) -> anyhow::Result<Scheme> {
    anyhow::ensure!(
        arg.is_none(),
        "scheme '{name}' takes no parameters, got ':{}'",
        arg.unwrap_or_default()
    );
    Ok(scheme)
}

/// Param-taking schemes always receive an arg: [`SchemeInfo::parse_args`]
/// substitutes `default_params` when the caller omits it.
fn required(arg: Option<&str>) -> anyhow::Result<&str> {
    arg.ok_or_else(|| anyhow::anyhow!("scheme parameters missing and no registry default"))
}

fn parse_pair(s: &str) -> anyhow::Result<(usize, usize)> {
    let (a, b) = s
        .split_once('x')
        .ok_or_else(|| anyhow::anyhow!("expected AxB, got '{s}'"))?;
    Ok((a.parse()?, b.parse()?))
}

/// All registered schemes, in paper order (Fig 5's contenders).
pub static REGISTRY: &[SchemeInfo] = &[
    SchemeInfo {
        name: "uncoded",
        params: "",
        default_params: "",
        smoke_params: "",
        summary: "no redundancy; wait for every worker",
        parse: |arg| no_params(Scheme::Uncoded, "uncoded", arg),
    },
    SchemeInfo {
        name: "speculative",
        params: "q",
        default_params: "0.79",
        smoke_params: "0.75",
        summary: "wait for a q-fraction, then relaunch the stragglers",
        parse: |arg| {
            Ok(Scheme::Speculative {
                wait_frac: required(arg)?.parse()?,
            })
        },
    },
    SchemeInfo {
        name: "local-product",
        params: "L_AxL_B",
        default_params: "10x10",
        smoke_params: "2x2",
        summary: "the paper's local product code; per-grid peeling decode",
        parse: |arg| {
            let (l_a, l_b) = parse_pair(required(arg)?)?;
            Ok(Scheme::LocalProduct { l_a, l_b })
        },
    },
    SchemeInfo {
        name: "product",
        params: "T_AxT_B",
        default_params: "1x1",
        smoke_params: "1x1",
        summary: "global-parity product code [16]; whole-line MDS recovery",
        parse: |arg| {
            let (t_a, t_b) = parse_pair(required(arg)?)?;
            Ok(Scheme::Product { t_a, t_b })
        },
    },
    SchemeInfo {
        name: "polynomial",
        params: "r",
        default_params: "0.21",
        smoke_params: "0.25",
        summary: "polynomial (MDS) code [18]; wait-K, all-K-block decode",
        parse: |arg| {
            Ok(Scheme::Polynomial {
                redundancy: required(arg)?.parse()?,
            })
        },
    },
];

/// Look a scheme up by registry name.
pub fn lookup(name: &str) -> Option<&'static SchemeInfo> {
    REGISTRY.iter().find(|info| info.name == name)
}

/// Parse a `name[:params]` scheme string through the registry — the one
/// code path behind [`Scheme::parse`], the CLI and scenario JSON.
pub fn parse(s: &str) -> anyhow::Result<Scheme> {
    let (head, arg) = match s.split_once(':') {
        Some((h, a)) => (h, Some(a)),
        None => (s, None),
    };
    let info = lookup(head).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown scheme '{head}' (known: {})",
            REGISTRY
                .iter()
                .map(|i| i.name)
                .collect::<Vec<_>>()
                .join(", ")
        )
    })?;
    info.parse_args(arg)
}

/// Multi-line scheme listing for `slec run --scheme help`.
pub fn help_text() -> String {
    let mut out = String::from("registered schemes (--scheme <name>[:params]):\n");
    for info in REGISTRY {
        let spec = if info.params.is_empty() {
            info.name.to_string()
        } else {
            format!("{}[:{}]", info.name, info.params)
        };
        out.push_str(&format!("  {spec:<28} {}", info.summary));
        if !info.default_params.is_empty() {
            out.push_str(&format!(" (default {})", info.default_params));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_resolvable() {
        let mut seen = std::collections::BTreeSet::new();
        for info in REGISTRY {
            assert!(seen.insert(info.name), "duplicate scheme '{}'", info.name);
            let scheme = parse(&info.smoke_spec()).unwrap();
            assert_eq!(scheme.name(), info.name);
            assert!(help_text().contains(info.name));
        }
        assert!(lookup("bogus").is_none());
    }

    #[test]
    fn omitted_params_use_the_registry_defaults() {
        // The registry row is the single source of defaults: the bare
        // name must parse exactly as `name:default_params` does.
        for info in REGISTRY {
            if info.default_params.is_empty() {
                continue;
            }
            let bare = parse(info.name).unwrap();
            let explicit = parse(&format!("{}:{}", info.name, info.default_params)).unwrap();
            assert_eq!(bare, explicit, "{}", info.name);
        }
        assert_eq!(
            parse("local-product").unwrap(),
            Scheme::LocalProduct { l_a: 10, l_b: 10 }
        );
    }

    #[test]
    fn matvec_rejects_rectangular_groups() {
        let err = instantiate_matvec(Scheme::LocalProduct { l_a: 2, l_b: 4 }, 8)
            .unwrap_err()
            .to_string();
        assert!(err.contains("square group sizes"), "{err}");
    }

    #[test]
    fn uncoded_rejects_parameters() {
        assert!(parse("uncoded").is_ok());
        let err = parse("uncoded:3").unwrap_err().to_string();
        assert!(err.contains("takes no parameters"), "{err}");
    }

    #[test]
    fn instantiate_validates_parameters() {
        assert!(instantiate(Scheme::LocalProduct { l_a: 3, l_b: 3 }, 4, 4).is_err());
        assert!(instantiate(Scheme::LocalProduct { l_a: 0, l_b: 2 }, 4, 4).is_err());
        assert!(instantiate(Scheme::Polynomial { redundancy: -0.5 }, 4, 4).is_err());
        let lp = instantiate(Scheme::LocalProduct { l_a: 2, l_b: 2 }, 4, 4).unwrap();
        assert_eq!(lp.name(), "local-product");
        assert_eq!(lp.compute_tasks(), 36);
        assert!((lp.redundancy() - 1.25).abs() < 1e-12);
    }

    #[test]
    fn trivial_schemes_have_no_encode_or_decode_phase() {
        let shape = JobShape::new(4, 4, (4000, 2000, 4000));
        for scheme in [Scheme::Uncoded, Scheme::Speculative { wait_frac: 0.5 }] {
            let s = instantiate(scheme, 4, 4).unwrap();
            assert!(s.encode_plan(&shape, 2).is_none());
            let plan = s.decode_plan(&vec![true; 16], &shape, 4);
            assert!(plan.profiles.is_empty());
            assert_eq!(plan.undecodable, 0);
            assert_eq!(s.compute_tasks(), 16);
            assert!(s.numerics_feasible());
        }
    }

    #[test]
    fn coded_grid_dims_cover_the_task_fanout() {
        // Plan metadata contract: rows · cols == compute_tasks for every
        // registered scheme (the storage timing model maps cells to
        // coded-block reads through these dims).
        for info in REGISTRY {
            let scheme = parse(&info.smoke_spec()).unwrap();
            let s = instantiate(scheme, 4, 4).unwrap();
            let (r, c) = s.coded_grid_dims();
            assert_eq!(r * c, s.compute_tasks(), "{}", info.name);
            assert!(r >= 1 && c >= 1, "{}", info.name);
        }
        let un = instantiate(Scheme::Uncoded, 3, 5).unwrap();
        assert_eq!(un.coded_grid_dims(), (3, 5));
    }

    #[test]
    fn matvec_instantiation_mirrors_engine_support() {
        assert!(instantiate_matvec(Scheme::Polynomial { redundancy: 0.2 }, 8).is_err());
        let (code, policy) =
            instantiate_matvec(Scheme::LocalProduct { l_a: 2, l_b: 2 }, 8).unwrap();
        assert!(code.is_some());
        assert_eq!(policy.compute_tasks(), 18); // 2 grids × (2+1)²
        let (code, policy) = instantiate_matvec(Scheme::Uncoded, 8).unwrap();
        assert!(code.is_none());
        assert_eq!(policy.compute_tasks(), 8);
    }
}

//! Closed-form theory from §III and §V: Theorem 1 (decode-read tail
//! bound), Corollary 1, Theorem 2 (undecodability bound with the α_s
//! configuration counts), and the LRC locality/minimum-distance bounds
//! (Eqs. 2–3). These generate Figs. 6 and 9 and are validated against
//! Monte-Carlo simulation in [`crate::codes::montecarlo`].

/// ln(n!) via direct summation (exact enough for n ≤ ~10⁶; we use n ≤ 10⁴).
pub fn ln_factorial(n: usize) -> f64 {
    (2..=n).map(|k| (k as f64).ln()).sum()
}

/// ln C(n, k); −∞ when k > n.
pub fn ln_choose(n: usize, k: usize) -> f64 {
    if k > n {
        return f64::NEG_INFINITY;
    }
    ln_factorial(n) - ln_factorial(k) - ln_factorial(n - k)
}

/// C(n, k) as f64 (may overflow to inf only for huge inputs).
pub fn choose(n: usize, k: usize) -> f64 {
    ln_choose(n, k).exp()
}

/// Binomial pmf P(S = s) for S ~ Binomial(n, p).
pub fn binom_pmf(n: usize, s: usize, p: f64) -> f64 {
    if s > n {
        return 0.0;
    }
    if p <= 0.0 {
        return if s == 0 { 1.0 } else { 0.0 };
    }
    if p >= 1.0 {
        return if s == n { 1.0 } else { 0.0 };
    }
    (ln_choose(n, s) + s as f64 * p.ln() + (n - s) as f64 * (1.0 - p).ln()).exp()
}

/// Binomial upper tail P(S ≥ s0).
pub fn binom_tail(n: usize, s0: usize, p: f64) -> f64 {
    (s0..=n).map(|s| binom_pmf(n, s, p)).sum()
}

/// **Theorem 1, as printed in the paper**: with straggling probability
/// `p`, grid size `n = (L_A+1)(L_B+1)`, and `L = max(L_A, L_B)`,
/// `Pr(R ≥ x) ≤ (x / (npL))^{-x/L} · e^{-x/L + np}`.
///
/// ⚠ REPRODUCTION NOTE: this printed expression contains a sign typo. The
/// Chernoff argument in §V-A gives `Pr(R ≥ x) ≤ e^{-tx}(1-p+pe^{tL})^n ≤
/// exp(-tx + np(e^{tL} − 1))`, and optimizing `t = (1/L)·ln(x/(npL))`
/// yields `(x/(npL))^{-x/L} · e^{+x/L − np}` — the standard multiplicative
/// Chernoff tail for `S ≥ x/L`. The printed form (with `e^{-x/L+np}`) is
/// *smaller than the true probability*: e.g. for n=121, p=0.02, L=10 the
/// paper's caption claims Pr(R ≥ 2E[R]) ≤ 3.1×10⁻³, but already
/// Pr(S ≥ 5) ≈ 0.10 for S ~ Binomial(121, 0.02) and R ≈ S·L on a square
/// grid. Our Monte-Carlo validator ([`crate::codes::montecarlo`])
/// confirms the violation empirically.
///
/// We therefore provide both: this function reproduces the figure as
/// printed (Fig 6), and [`thm1_bound`] is the corrected, MC-validated
/// bound. See EXPERIMENTS.md §fig6 for the side-by-side.
pub fn thm1_bound_paper(x: f64, n: usize, p: f64, l: usize) -> f64 {
    assert!(x > 0.0 && p > 0.0 && l > 0);
    let npl = n as f64 * p * l as f64;
    let ln_bound = -(x / l as f64) * (x / npl).ln() + (-(x / l as f64) + n as f64 * p);
    ln_bound.exp().min(1.0)
}

/// **Theorem 1, corrected**: the valid Chernoff bound
/// `Pr(R ≥ x) ≤ (x/(npL))^{-x/L} · e^{x/L − np}` (nontrivial for
/// x > npL = E[R]). This is what the §V-A derivation actually yields; see
/// [`thm1_bound_paper`] for the discrepancy discussion.
pub fn thm1_bound(x: f64, n: usize, p: f64, l: usize) -> f64 {
    assert!(x > 0.0 && p > 0.0 && l > 0);
    let npl = n as f64 * p * l as f64;
    if x <= npl {
        // The Chernoff optimizer t* = ln(x/(npL))/L is ≤ 0 here; no
        // nontrivial upper bound exists below the mean.
        return 1.0;
    }
    let ln_bound = -(x / l as f64) * (x / npl).ln() + (x / l as f64) - n as f64 * p;
    ln_bound.exp().min(1.0)
}

/// Expected reads E[R] = npL for the square case L_A = L_B = L (§III-B).
pub fn expected_reads(n: usize, p: f64, l: usize) -> f64 {
    n as f64 * p * l as f64
}

/// **Corollary 1, as printed**: Pr(R ≥ E[R] + εL) ≤ (1 + ε/(np))^{−np−ε} e^{−ε}.
/// Inherits the Theorem-1 sign typo (see [`thm1_bound_paper`]).
pub fn cor1_bound_paper(eps: f64, n: usize, p: f64) -> f64 {
    assert!(eps > 0.0);
    let np = n as f64 * p;
    let ln_bound = (-np - eps) * (1.0 + eps / np).ln() - eps;
    ln_bound.exp().min(1.0)
}

/// **Corollary 1, corrected**: Pr(R ≥ E[R] + εL) ≤ (1 + ε/(np))^{−np−ε} e^{+ε}
/// (specializing the corrected Theorem 1 at x = (np + ε)L).
pub fn cor1_bound(eps: f64, n: usize, p: f64) -> f64 {
    assert!(eps > 0.0);
    let np = n as f64 * p;
    let ln_bound = (-np - eps) * (1.0 + eps / np).ln() + eps;
    ln_bound.exp().min(1.0)
}

/// The α_s configuration counts of Theorem 2 (upper bounds for s = 6, 7).
pub fn alpha_counts(l_a: usize, l_b: usize) -> [f64; 4] {
    let n = (l_a + 1) * (l_b + 1);
    let a4 = choose(l_a + 1, 2) * choose(l_b + 1, 2);
    let a5 = a4 * (n as f64 - 4.0);
    let three_by_three = choose(l_a + 1, 3) * choose(l_b + 1, 3);
    let a6 = three_by_three * choose(9, 6) + a4 * choose(n - 4, 2);
    let a7 = three_by_three * choose(9, 7) + a4 * choose(n - 4, 3);
    [a4, a5, a6, a7]
}

/// **Theorem 2**: upper bound on Pr(D̄) — a decoding worker with an
/// `(L_A+1)×(L_B+1)` grid (n ≥ 8 blocks) being unable to decode:
/// `Σ_{s=4}^{7} α_s p^s (1−p)^{n−s} + Σ_{s=8}^{n} C(n,s) p^s (1−p)^{n−s}`.
pub fn thm2_bound(l_a: usize, l_b: usize, p: f64) -> f64 {
    let n = (l_a + 1) * (l_b + 1);
    assert!(n >= 8, "Theorem 2 requires n ≥ 8 (got {n})");
    let alphas = alpha_counts(l_a, l_b);
    let mut total = 0.0;
    for (i, &alpha) in alphas.iter().enumerate() {
        let s = 4 + i;
        // α_s p^s (1-p)^{n-s}, computed in log space for stability.
        if alpha > 0.0 {
            let ln_term =
                alpha.ln() + s as f64 * p.ln() + (n - s) as f64 * (1.0 - p).ln();
            total += ln_term.exp();
        }
    }
    total += binom_tail(n, 8, p);
    total.min(1.0)
}

/// Union bound over `k` parallel decoding workers (Remark 3).
pub fn union_over_workers(per_worker: f64, k: usize) -> f64 {
    (per_worker * k as f64).min(1.0)
}

/// LRC Singleton-like bound (Eq. 2): d ≤ n − k − ⌈k/r⌉ + 2.
pub fn lrc_distance_bound(n: usize, k: usize, r: usize) -> isize {
    n as isize - k as isize - (k as isize + r as isize - 1) / r as isize + 2
}

/// Locality lower bound for any code tolerating ≥1 straggler (Eq. 3):
/// r ≥ k / (n − k).
pub fn lrc_locality_lower_bound(n: usize, k: usize) -> f64 {
    assert!(n > k);
    k as f64 / (n - k) as f64
}

/// The paper's optimality claim (§III-A): the local product code's locality
/// `min(L_A, L_B)` is within a constant factor (2 + o(1)) of the lower
/// bound for its (n, k). Returns (achieved, lower_bound).
pub fn locality_vs_bound(l_a: usize, l_b: usize) -> (usize, f64) {
    let k = l_a * l_b;
    let n = (l_a + 1) * (l_b + 1);
    (l_a.min(l_b), lrc_locality_lower_bound(n, k))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choose_small_values() {
        assert_eq!(choose(5, 2).round() as u64, 10);
        assert_eq!(choose(9, 6).round() as u64, 84);
        assert_eq!(choose(9, 7).round() as u64, 36);
        assert_eq!(choose(11, 2).round() as u64, 55);
        assert_eq!(choose(3, 5), 0.0);
    }

    #[test]
    fn binom_pmf_sums_to_one() {
        let n = 30;
        let total: f64 = (0..=n).map(|s| binom_pmf(n, s, 0.13)).sum();
        assert!((total - 1.0).abs() < 1e-10);
        assert!((binom_tail(n, 0, 0.13) - 1.0).abs() < 1e-10);
    }

    #[test]
    fn thm1_paper_fig6_reference_points() {
        // Fig 6 caption (paper formula): L=10, n=121, p=0.02 ⇒
        // Pr(R ≥ 2E[R]) ≤ 3.1e−3. We reproduce the printed curve exactly.
        let (n, p, l) = (121usize, 0.02, 10usize);
        let er = expected_reads(n, p, l);
        assert!((er - 24.2).abs() < 1e-9);
        let b = thm1_bound_paper(2.0 * er, n, p, l);
        assert!(
            (b - 3.1e-3).abs() < 0.3e-3,
            "Pr(R≥2E[R]) paper bound = {b:.4e}, caption says ≈3.1e−3"
        );
        // §III-B: Pr(R ≥ 100) ≤ 3.5e−10 (paper formula).
        let b100 = thm1_bound_paper(100.0, n, p, l);
        assert!(
            (b100 - 3.5e-10).abs() < 1.0e-10,
            "Pr(R≥100) paper bound = {b100:.4e}, paper says ≈3.5e−10"
        );
    }

    #[test]
    fn thm1_corrected_dominates_paper_form() {
        // The corrected bound is necessarily weaker (larger) than the
        // typo'd printed form for x > E[R].
        let (n, p, l) = (121usize, 0.02, 10usize);
        for x in [30.0, 50.0, 100.0] {
            assert!(thm1_bound(x, n, p, l) >= thm1_bound_paper(x, n, p, l));
        }
    }

    #[test]
    fn thm1_corrected_bounds_binomial_tail() {
        // Validity check: Pr(R ≥ x) ≤ Pr(S ≥ x/L) ≤ corrected bound —
        // compare against the exact binomial tail.
        let (n, p, l) = (121usize, 0.02, 10usize);
        for x in [30.0, 50.0, 80.0, 100.0] {
            let s0 = (x / l as f64).ceil() as usize;
            let exact_tail = binom_tail(n, s0, p);
            let bound = thm1_bound(x, n, p, l);
            assert!(
                bound >= exact_tail,
                "x={x}: corrected bound {bound:.3e} < exact tail {exact_tail:.3e}"
            );
        }
    }

    #[test]
    fn cor1_matches_thm1_at_eps_np() {
        // Paper form at ε = np: Pr(R ≥ 2E[R]) ≤ (4e)^{−np};
        // corrected form: (4/e)^{−np}.
        let (n, p) = (121usize, 0.02);
        let np = n as f64 * p;
        let via_paper = cor1_bound_paper(np, n, p);
        let closed_paper = (4.0 * std::f64::consts::E).powf(-np);
        assert!((via_paper - closed_paper).abs() < 1e-12);
        let via_corr = cor1_bound(np, n, p);
        let closed_corr = (4.0 / std::f64::consts::E).powf(-np);
        assert!((via_corr - closed_corr).abs() < 1e-12);
    }

    #[test]
    fn thm1_decreasing_in_x() {
        let (n, p, l) = (121usize, 0.02, 10usize);
        let xs = [30.0, 50.0, 80.0, 100.0, 120.0];
        for w in xs.windows(2) {
            assert!(thm1_bound(w[1], n, p, l) <= thm1_bound(w[0], n, p, l));
        }
    }

    #[test]
    fn alpha4_exact_small_grid() {
        // 3×3 grid (L_A=L_B=2): 4-undecodable sets = C(3,2)² = 9.
        let a = alpha_counts(2, 2);
        assert_eq!(a[0].round() as u64, 9);
        // α5 = α4 (n−4) = 9·5 = 45.
        assert_eq!(a[1].round() as u64, 45);
    }

    #[test]
    fn thm2_fig9_reference_point() {
        // §III-C: for L_A=L_B=10, p=0.02, a worker decodes w.p. ≥ 99.64%.
        let b = thm2_bound(10, 10, 0.02);
        assert!(b <= 1.0 - 0.9964 + 2e-4, "Pr(D̄) bound = {b:.4e} should be ≈3.6e−3");
        assert!(b > 1e-4, "bound should not be vacuously small: {b:.4e}");
    }

    #[test]
    fn thm2_has_sweet_spot_shape() {
        // Fig 9: bound vs L is U-shaped-ish with small values in the
        // L≈5..15 region and growth for large L.
        let p = 0.02;
        let small = thm2_bound(2, 2, p); // n=9 < 8? no: 9 ≥ 8 ok
        let mid = thm2_bound(10, 10, p);
        let large = thm2_bound(25, 25, p);
        assert!(mid < large, "mid {mid} < large {large}");
        // The n=9 grid has fewer blocks so fewer 4-sets, but mid should
        // still be the same order or below `small`'s neighborhood scaled.
        assert!(small < 1.0 && mid < 1.0 && large < 1.0);
    }

    #[test]
    fn lrc_bounds() {
        // Product code with one parity per axis: k = L², n = (L+1)².
        // d = 4 must satisfy Eq. 2.
        for l in [2usize, 5, 10] {
            let k = l * l;
            let n = (l + 1) * (l + 1);
            let bound = lrc_distance_bound(n, k, l);
            assert!(4 <= bound, "d=4 ≤ {bound} for L={l}");
        }
        // Eq. 3 sanity + §III-A: min(LA,LB) within 2+o(1) of the bound.
        let (ach, low) = locality_vs_bound(10, 10);
        assert_eq!(ach, 10);
        assert!((low - 100.0 / 21.0).abs() < 1e-12);
        assert!(ach as f64 >= low);
        assert!((ach as f64) <= low * (2.0 + 0.5));
    }

    #[test]
    fn union_bound_clamps() {
        assert_eq!(union_over_workers(0.3, 5), 1.0);
        assert!((union_over_workers(1e-3, 25) - 0.025).abs() < 1e-12);
    }
}

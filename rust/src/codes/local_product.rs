//! The paper's contribution: the **local product code** for distributed
//! matrix multiplication (§II-B).
//!
//! Encoding: insert one parity row-block (sum of the preceding `L_A`
//! blocks) after every `L_A` row-blocks of `A`, likewise `L_B` for `B`.
//! The coded output `C_coded = A_coded · B_codedᵀ` then decomposes into
//! `(s_A/L_A) × (s_B/L_B)` local grids of `(L_A+1)×(L_B+1)` blocks, each an
//! independent product code decodable in parallel by a cheap peeling
//! decoder ([`crate::codes::peeling`]).

use std::collections::BTreeSet;

use crate::codes::layout::{CodedBlock, LocalLayout};
use crate::codes::peeling::{plan_peel, wavefront_levels, Axis, PeelPlan};
use crate::codes::scheme::{
    CodingScheme, ComputePolicy, DecodePlan, DecodeProbe, EncodePlan, JobShape,
    DECODE_WAIT_FRAC, ENCODE_WAIT_FRAC,
};
use crate::linalg::kernels;
use crate::linalg::matrix::{BlockBuf, Matrix};
use crate::platform::event::Termination;
use crate::platform::straggler::WorkProfile;
use crate::runtime::ComputeBackend;
use crate::util::threadpool::{num_threads, parallel_map};

/// Parameters and index math of a local product code over the output of
/// `C = A·Bᵀ` with `s_a × s_b` systematic blocks.
#[derive(Debug, Clone, Copy)]
pub struct LocalProductCode {
    pub a: LocalLayout,
    pub b: LocalLayout,
}

impl LocalProductCode {
    /// `s_a`/`s_b`: systematic row-blocks of A/B; `l_a`/`l_b`: group sizes.
    pub fn new(s_a: usize, l_a: usize, s_b: usize, l_b: usize) -> LocalProductCode {
        LocalProductCode {
            a: LocalLayout::new(s_a, l_a),
            b: LocalLayout::new(s_b, l_b),
        }
    }

    /// Coded output grid dims (rows, cols) in blocks.
    pub fn coded_grid(&self) -> (usize, usize) {
        (self.a.coded_len(), self.b.coded_len())
    }

    /// Number of local grids (ga, gb).
    pub fn groups(&self) -> (usize, usize) {
        (self.a.groups(), self.b.groups())
    }

    /// Total redundancy of the coded computation.
    pub fn redundancy(&self) -> f64 {
        crate::codes::layout::product_redundancy(self.a.l, self.b.l)
    }

    /// Locality: blocks read to recover one isolated straggler.
    pub fn locality(&self) -> usize {
        self.a.l.min(self.b.l)
    }

    /// Worst-case reads per straggler (Theorem 1's `L`).
    pub fn max_reads_per_straggler(&self) -> usize {
        self.a.l.max(self.b.l)
    }

    /// Coded-grid cell for local grid (gi, gj) position (r, c),
    /// r in 0..=l_a, c in 0..=l_b.
    pub fn grid_cell(&self, gi: usize, gj: usize, r: usize, c: usize) -> (usize, usize) {
        assert!(r <= self.a.l && c <= self.b.l);
        (gi * (self.a.l + 1) + r, gj * (self.b.l + 1) + c)
    }

    /// Which local grid (row-major over `ga × gb`) owns flat coded-output
    /// cell `cell` (row-major over the `ra × rb` coded grid)? Inverse of
    /// [`LocalProductCode::grid_cell`] at grid granularity — used to
    /// retest only the affected grid when one result arrives.
    pub fn grid_of_cell(&self, cell: usize) -> usize {
        let (_, rb) = self.coded_grid();
        let (r, c) = (cell / rb, cell % rb);
        (r / (self.a.l + 1)) * self.b.groups() + c / (self.b.l + 1)
    }

    /// Encode the row-blocks of one input matrix side: returns coded blocks
    /// in coded order. Parities are sums of each group's members (the
    /// [`kernels`] accumulate path; left-to-right member order, so results
    /// are bit-identical to the historical clone-then-add encode). This is
    /// the *serial reference* — the coordinator's hot path is the parallel
    /// zero-copy [`encode_side_parallel`].
    pub fn encode_side(layout: LocalLayout, blocks: &[Matrix]) -> Vec<Matrix> {
        assert_eq!(blocks.len(), layout.systematic);
        let mut out = Vec::with_capacity(layout.coded_len());
        for k in 0..layout.coded_len() {
            match layout.block_at(k) {
                CodedBlock::Systematic { orig } => out.push(blocks[orig].clone()),
                CodedBlock::Parity { group } => {
                    let members = layout.group_members(group);
                    let r0 = members.start;
                    let slices: Vec<&[f32]> =
                        members.map(|m| blocks[m].data.as_slice()).collect();
                    out.push(Matrix::from_vec(
                        blocks[r0].rows,
                        blocks[r0].cols,
                        kernels::sum(&slices),
                    ));
                }
            }
        }
        out
    }

    /// Compute a parity block from its group members (the unit of work an
    /// *encoding worker* performs).
    pub fn parity_of(blocks: &[Matrix]) -> Matrix {
        assert!(!blocks.is_empty());
        let slices: Vec<&[f32]> = blocks.iter().map(|b| b.data.as_slice()).collect();
        Matrix::from_vec(blocks[0].rows, blocks[0].cols, kernels::sum(&slices))
    }
}

/// Numerically execute a peeling plan on one local grid.
///
/// `cells` is the (l_a+1)×(l_b+1) row-major grid; `None` marks straggled
/// blocks. On success every cell is `Some` and the returned plan describes
/// exactly what was read. Returns the plan even when undecodable (the
/// coordinator then recomputes the remaining cells).
pub fn decode_local_grid(l_a: usize, l_b: usize, cells: &mut [Option<Matrix>]) -> PeelPlan {
    let rows = l_a + 1;
    let cols = l_b + 1;
    assert_eq!(cells.len(), rows * cols);
    let present: Vec<bool> = cells.iter().map(Option::is_some).collect();
    let plan = plan_peel(rows, cols, &present);
    for step in &plan.steps {
        let (r, c) = step.cell;
        let value = match step.axis {
            Axis::Row => reconstruct_from_line(
                cells,
                (0..cols).map(|cc| r * cols + cc),
                r * cols + c,
                c == cols - 1,
            ),
            Axis::Col => reconstruct_from_line(
                cells,
                (0..rows).map(|rr| rr * cols + c),
                r * cols + c,
                r == rows - 1,
            ),
        };
        cells[r * cols + c] = Some(value);
    }
    plan
}

/// Reconstruct the missing cell of a parity line. The line's constraint is
/// `last cell (parity) = Σ other cells`; if the missing cell IS the parity,
/// sum the others; otherwise missing = parity − Σ other systematic cells.
fn reconstruct_from_line(
    cells: &[Option<Matrix>],
    line: impl Iterator<Item = usize>,
    target: usize,
    target_is_parity: bool,
) -> Matrix {
    let idxs: Vec<usize> = line.collect();
    let parity_idx = *idxs.last().unwrap();
    if target_is_parity {
        // Sum all systematic cells on the line.
        let mut acc: Option<Matrix> = None;
        for &i in idxs.iter().take(idxs.len() - 1) {
            let cell = cells[i].as_ref().expect("plan guarantees availability");
            match &mut acc {
                None => acc = Some(cell.clone()),
                Some(a) => a.add_assign(cell),
            }
        }
        acc.expect("line has systematic cells")
    } else {
        let mut acc = cells[parity_idx]
            .as_ref()
            .expect("plan guarantees parity availability")
            .clone();
        for &i in idxs.iter().take(idxs.len() - 1) {
            if i == target {
                continue;
            }
            acc.sub_assign(cells[i].as_ref().expect("plan guarantees availability"));
        }
        acc
    }
}

/// Peeling plan of one local grid `(gi, gj)` from the coded-output
/// arrival mask alone (no numerics). The single source of truth for
/// mask-level grid extraction, shared by [`grid_decodable`] and
/// [`plan_grids`].
pub fn plan_grid(code: &LocalProductCode, gi: usize, gj: usize, arrived: &[bool]) -> PeelPlan {
    let (l_a, l_b) = (code.a.l, code.b.l);
    let (_, rb) = code.coded_grid();
    let mut present = Vec::with_capacity((l_a + 1) * (l_b + 1));
    for r in 0..=l_a {
        for c in 0..=l_b {
            let (cr, cc) = code.grid_cell(gi, gj, r, c);
            present.push(arrived[cr * rb + cc]);
        }
    }
    plan_peel(l_a + 1, l_b + 1, &present)
}

/// Is local grid `g` (row-major over the `ga × gb` grid-of-grids)
/// peeling-decodable given the coded-output arrival mask? This is the
/// boolean predicate behind the earliest-decodable termination of both
/// the coordinator and the scenario runner.
pub fn grid_decodable(code: &LocalProductCode, g: usize, arrived: &[bool]) -> bool {
    let gb = code.b.groups();
    plan_grid(code, g / gb, g % gb, arrived).decodable()
}

/// Peeling plans for every local grid from an arrival mask alone (no
/// numerics) — the scenario runner's decode-phase accounting.
pub fn plan_grids(code: &LocalProductCode, arrived: &[bool]) -> Vec<PeelPlan> {
    let (ga, gb) = code.groups();
    let mut plans = Vec::with_capacity(ga * gb);
    for gi in 0..ga {
        for gj in 0..gb {
            plans.push(plan_grid(code, gi, gj, arrived));
        }
    }
    plans
}

/// Full-output decode: given the coded output grid (row-major
/// `(ra × rb)` of `Option<Matrix>`), decode every local grid in place and
/// return per-grid plans. The caller can then extract systematic blocks.
pub fn decode_coded_output(
    code: &LocalProductCode,
    coded: &mut [Option<Matrix>],
) -> Vec<PeelPlan> {
    let (ra, rb) = code.coded_grid();
    assert_eq!(coded.len(), ra * rb);
    let (ga, gb) = code.groups();
    let (la, lb) = (code.a.l, code.b.l);
    let mut plans = Vec::with_capacity(ga * gb);
    for gi in 0..ga {
        for gj in 0..gb {
            // Extract the local grid.
            let mut cells: Vec<Option<Matrix>> = Vec::with_capacity((la + 1) * (lb + 1));
            for r in 0..=la {
                for c in 0..=lb {
                    let (cr, cc) = code.grid_cell(gi, gj, r, c);
                    cells.push(coded[cr * rb + cc].take());
                }
            }
            let plan = decode_local_grid(la, lb, &mut cells);
            // Write back.
            let mut it = cells.into_iter();
            for r in 0..=la {
                for c in 0..=lb {
                    let (cr, cc) = code.grid_cell(gi, gj, r, c);
                    coded[cr * rb + cc] = it.next().unwrap();
                }
            }
            plans.push(plan);
        }
    }
    plans
}

/// Extract the systematic `s_a × s_b` output blocks from a (fully decoded)
/// coded grid. Generic over the cell type so both owned [`Matrix`] grids
/// (symbolic path) and shared [`BlockBuf`] grids (numeric path, where
/// `clone()` is a refcount bump) extract through the one placement rule.
pub fn extract_systematic<B: Clone>(
    code: &LocalProductCode,
    coded: &[Option<B>],
) -> anyhow::Result<Vec<B>> {
    let (_, rb) = code.coded_grid();
    let mut out = Vec::with_capacity(code.a.systematic * code.b.systematic);
    for i in 0..code.a.systematic {
        let ci = code.a.systematic_pos(i);
        for j in 0..code.b.systematic {
            let cj = code.b.systematic_pos(j);
            let cell = coded[ci * rb + cj]
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("systematic block ({i},{j}) still missing"))?;
            out.push(cell.clone());
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// CodingScheme impl — the paper's scheme as a pluggable job description
// ---------------------------------------------------------------------------

/// Round-robin recovery steps (each costing `reads` block-reads) over
/// `workers` decode workers and build one aggregate [`WorkProfile`] per
/// worker that has any work — the local scheme's parallel-decode
/// accounting (Remark 3).
pub fn decode_worker_profiles(
    step_reads: impl Iterator<Item = usize>,
    workers: usize,
    block_rows: usize,
    block_cols: usize,
) -> Vec<WorkProfile> {
    let out_bytes = (block_rows * block_cols * 4) as u64;
    let mut per_worker_reads = vec![0usize; workers];
    let mut per_worker_writes = vec![0usize; workers];
    let mut next = 0usize;
    for reads in step_reads {
        per_worker_reads[next % workers] += reads;
        per_worker_writes[next % workers] += 1;
        next += 1;
    }
    per_worker_reads
        .iter()
        .zip(&per_worker_writes)
        .filter(|(&reads, _)| reads > 0)
        .map(|(&reads, &writes)| WorkProfile {
            bytes_read: reads as u64 * out_bytes,
            read_ops: reads as u64,
            flops: (reads * block_rows * block_cols) as f64,
            bytes_written: writes as u64 * out_bytes,
            write_ops: writes as u64,
        })
        .collect()
}

/// Backend-routed **parallel** side encode over shared block handles:
/// systematic cells are refcount bumps of the input blocks, and every
/// parity (`stack_sum`, so the PJRT artifacts stay on the hot path) is an
/// independent task fanned out over `threads`. Member order within a
/// parity is fixed, so the result is bit-identical to
/// [`LocalProductCode::encode_side`] at every thread count (pinned by
/// `tests/codes_prop.rs`).
pub fn encode_side_parallel(
    backend: &dyn ComputeBackend,
    layout: LocalLayout,
    blocks: &[BlockBuf],
    threads: usize,
) -> Vec<BlockBuf> {
    assert_eq!(blocks.len(), layout.systematic);
    parallel_map(threads, layout.coded_len(), |k| match layout.block_at(k) {
        CodedBlock::Systematic { orig } => blocks[orig].clone(),
        CodedBlock::Parity { group } => {
            let members: Vec<&Matrix> = layout
                .group_members(group)
                .map(|m| blocks[m].as_matrix())
                .collect();
            BlockBuf::new(backend.stack_sum(&members))
        }
    })
}

/// Backend-routed **wavefront** peeling decode of one local grid (numeric
/// twin of [`decode_local_grid`], every recovery through the compute
/// backend so the PJRT `parity_residual` / `stack_sum` artifacts are on
/// the decode hot path).
///
/// The existing [`PeelPlan`] is untouched — golden peel orders and all
/// read accounting are exactly the serial plan's. Execution walks the
/// plan's [`wavefront_levels`]: steps within a level read only original
/// cells and cells recovered in earlier levels, so each level fans out
/// over `threads` and writes back when the whole level completes. Values
/// are bit-identical to serial execution (each step consumes exactly the
/// cells the serial order would have handed it).
pub fn peel_grid_wavefront(
    backend: &dyn ComputeBackend,
    l_a: usize,
    l_b: usize,
    cells: &mut [Option<BlockBuf>],
    threads: usize,
) {
    let rows = l_a + 1;
    let cols = l_b + 1;
    assert_eq!(cells.len(), rows * cols);
    let present: Vec<bool> = cells.iter().map(Option::is_some).collect();
    let plan = plan_peel(rows, cols, &present);
    for level in wavefront_levels(&plan) {
        let cells_ref: &[Option<BlockBuf>] = cells;
        let steps = &plan.steps;
        let level_ref = &level;
        let recovered: Vec<(usize, BlockBuf)> = parallel_map(threads, level.len(), move |i| {
            let step = &steps[level_ref[i]];
            let (r, c) = step.cell;
            let line: Vec<usize> = match step.axis {
                Axis::Row => (0..cols).map(|cc| r * cols + cc).collect(),
                Axis::Col => (0..rows).map(|rr| rr * cols + c).collect(),
            };
            let target = r * cols + c;
            let parity_idx = *line.last().unwrap();
            let value = if target == parity_idx {
                let members: Vec<&Matrix> = line[..line.len() - 1]
                    .iter()
                    .map(|&i| cells_ref[i].as_ref().expect("wavefront order").as_matrix())
                    .collect();
                backend.stack_sum(&members)
            } else {
                let parity = cells_ref[parity_idx]
                    .as_ref()
                    .expect("wavefront order")
                    .as_matrix();
                let survivors: Vec<&Matrix> = line[..line.len() - 1]
                    .iter()
                    .filter(|&&i| i != target)
                    .map(|&i| cells_ref[i].as_ref().expect("wavefront order").as_matrix())
                    .collect();
                backend.parity_residual(parity, &survivors)
            };
            (target, BlockBuf::new(value))
        });
        for (target, value) in recovered {
            cells[target] = Some(value);
        }
    }
}

/// The local product code as a pluggable [`CodingScheme`].
#[derive(Debug, Clone, Copy)]
pub struct LocalProductScheme {
    pub code: LocalProductCode,
}

impl LocalProductScheme {
    /// Validate the group sizes against the systematic partitioning.
    pub fn new(s_a: usize, l_a: usize, s_b: usize, l_b: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(l_a > 0 && l_b > 0, "group sizes l_a/l_b must be positive");
        anyhow::ensure!(s_a % l_a == 0, "s_a ({s_a}) % l_a ({l_a}) != 0");
        anyhow::ensure!(s_b % l_b == 0, "s_b ({s_b}) % l_b ({l_b}) != 0");
        Ok(LocalProductScheme {
            code: LocalProductCode::new(s_a, l_a, s_b, l_b),
        })
    }
}

impl ComputePolicy for LocalProductScheme {
    fn compute_tasks(&self) -> usize {
        let (ra, rb) = self.code.coded_grid();
        ra * rb
    }

    fn compute_termination(&self) -> Termination {
        Termination::EarliestDecodable
    }

    fn decode_probe(&self) -> DecodeProbe {
        // A grid's decodability only changes when one of its own cells
        // arrives: retest just that grid per completion. A `None` hint is
        // a pure feasibility query over a hypothetical mask — answer it
        // without touching the pending set.
        let code = self.code;
        let (ga, gb) = code.groups();
        let mut pending: BTreeSet<usize> = (0..ga * gb).collect();
        Box::new(move |mask: &[bool], newly: Option<usize>| match newly {
            Some(cell) => {
                let g = code.grid_of_cell(cell);
                if pending.contains(&g) && grid_decodable(&code, g, mask) {
                    pending.remove(&g);
                }
                pending.is_empty()
            }
            None => pending.iter().all(|&g| grid_decodable(&code, g, mask)),
        })
    }

    fn partial_credit(&self) -> bool {
        // Local decode is an AXPY reduction over block-product summands:
        // the durable prefix of a straggler's product is usable as-is.
        true
    }
}

impl CodingScheme for LocalProductScheme {
    fn name(&self) -> &'static str {
        "local-product"
    }

    fn redundancy(&self) -> f64 {
        self.code.redundancy()
    }

    fn coded_grid_dims(&self) -> (usize, usize) {
        self.code.coded_grid()
    }

    fn encode_plan(&self, shape: &JobShape, fleet: usize) -> Option<EncodePlan> {
        // Column-sliced across a small fleet (Remark 1),
        // straggler-protected by speculative relaunch.
        let code = &self.code;
        Some(EncodePlan {
            profile: WorkProfile::sliced_encode(
                code.a.groups() + code.b.groups(),
                code.a.l.max(code.b.l),
                shape.block_rows,
                shape.inner,
                fleet,
            ),
            termination: Termination::Speculative {
                wait_frac: ENCODE_WAIT_FRAC,
            },
            blocks_read: code.a.l * code.a.groups() + code.b.l * code.b.groups(),
        })
    }

    fn decode_plan(&self, arrived: &[bool], shape: &JobShape, decode_workers: usize) -> DecodePlan {
        // Recovery steps round-robin over decode workers (Remark 3); each
        // worker's time is sampled from its aggregate read/write profile.
        let plans = plan_grids(&self.code, arrived);
        DecodePlan {
            profiles: decode_worker_profiles(
                plans.iter().flat_map(|p| p.steps.iter().map(|s| s.reads)),
                decode_workers.max(1),
                shape.block_rows,
                shape.block_cols,
            ),
            termination: Termination::Speculative {
                wait_frac: DECODE_WAIT_FRAC,
            },
            blocks_read: plans.iter().map(|p| p.total_reads).sum(),
            undecodable: plans.iter().map(|p| p.undecodable.len()).sum(),
        }
    }

    fn stages_blocks_in_store(&self) -> bool {
        true
    }

    fn encode_numeric(
        &self,
        backend: &dyn ComputeBackend,
        a_blocks: &[BlockBuf],
        b_blocks: &[BlockBuf],
    ) -> (Vec<BlockBuf>, Vec<BlockBuf>) {
        let threads = num_threads();
        (
            encode_side_parallel(backend, self.code.a, a_blocks, threads),
            encode_side_parallel(backend, self.code.b, b_blocks, threads),
        )
    }

    fn decode_numeric(
        &self,
        backend: &dyn ComputeBackend,
        grid: Vec<Option<BlockBuf>>,
        _arrival_order: &[usize],
    ) -> anyhow::Result<Vec<BlockBuf>> {
        let code = &self.code;
        let (ra, rb) = code.coded_grid();
        let (ga, gb) = code.groups();
        let (la, lb) = (code.a.l, code.b.l);
        let threads = num_threads();

        // Extract every local grid as shared handles (refcount bumps).
        let mut grids: Vec<Vec<Option<BlockBuf>>> = Vec::with_capacity(ga * gb);
        for gi in 0..ga {
            for gj in 0..gb {
                let mut cells: Vec<Option<BlockBuf>> = Vec::with_capacity((la + 1) * (lb + 1));
                for r in 0..=la {
                    for c in 0..=lb {
                        let (cr, cc) = code.grid_cell(gi, gj, r, c);
                        cells.push(grid[cr * rb + cc].clone());
                    }
                }
                grids.push(cells);
            }
        }
        drop(grid);

        // Grids are independent product codes (§II-B "decodable in
        // parallel") — fan the grids out over the pool; inside a grid the
        // wavefront levels parallelize only when this job has a single
        // grid (no nested oversubscription).
        let inner_threads = if grids.len() > 1 { 1 } else { threads };
        let grids_ref = &grids;
        let decoded: Vec<Vec<Option<BlockBuf>>> =
            parallel_map(threads, grids.len(), move |g| {
                let mut cells = grids_ref[g].clone();
                peel_grid_wavefront(backend, la, lb, &mut cells, inner_threads);
                cells
            });

        // Write the decoded grids back into the full coded grid (refcount
        // bumps) and extract through the one placement rule.
        let mut coded: Vec<Option<BlockBuf>> = vec![None; ra * rb];
        for gi in 0..ga {
            for gj in 0..gb {
                let cells = &decoded[gi * gb + gj];
                for r in 0..=la {
                    for c in 0..=lb {
                        let (cr, cc) = code.grid_cell(gi, gj, r, c);
                        coded[cr * rb + cc] = cells[r * (lb + 1) + c].clone();
                    }
                }
            }
        }
        extract_systematic(code, &coded)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blocked::Partition;
    use crate::linalg::gemm::matmul_bt;
    use crate::util::prop::proptest;
    use crate::util::rng::Pcg64;

    /// Compute the full coded grid for A (sa×la) and B (sb×lb) directly.
    fn coded_grid_products(
        code: &LocalProductCode,
        a_blocks: &[Matrix],
        b_blocks: &[Matrix],
    ) -> Vec<Option<Matrix>> {
        let ac = LocalProductCode::encode_side(code.a, a_blocks);
        let bc = LocalProductCode::encode_side(code.b, b_blocks);
        let (ra, rb) = code.coded_grid();
        let mut grid = Vec::with_capacity(ra * rb);
        for i in 0..ra {
            for j in 0..rb {
                grid.push(Some(matmul_bt(&ac[i], &bc[j])));
            }
        }
        grid
    }

    fn random_blocks(s: usize, rows: usize, cols: usize, seed: u64) -> Vec<Matrix> {
        let mut rng = Pcg64::new(seed);
        (0..s).map(|_| Matrix::randn(rows, cols, &mut rng, 0.0, 1.0)).collect()
    }

    #[test]
    fn encode_side_parity_is_group_sum() {
        let blocks = random_blocks(4, 3, 5, 1);
        let layout = LocalLayout::new(4, 2);
        let coded = LocalProductCode::encode_side(layout, &blocks);
        assert_eq!(coded.len(), 6);
        let p0 = blocks[0].add(&blocks[1]);
        let p1 = blocks[2].add(&blocks[3]);
        assert_eq!(coded[2], p0);
        assert_eq!(coded[5], p1);
        assert_eq!(coded[0], blocks[0]);
        assert_eq!(coded[3], blocks[2]);
    }

    #[test]
    fn coded_grid_satisfies_parity_constraints() {
        // Every row and column of each local grid must satisfy
        // parity = Σ systematic (this is what makes peeling sound).
        let code = LocalProductCode::new(4, 2, 6, 3);
        let a = random_blocks(4, 4, 6, 2);
        let b = random_blocks(6, 5, 6, 3);
        let grid = coded_grid_products(&code, &a, &b);
        let (_, rb) = code.coded_grid();
        let (ga, gb) = code.groups();
        for gi in 0..ga {
            for gj in 0..gb {
                // Row constraints.
                for r in 0..=code.a.l {
                    let mut sum: Option<Matrix> = None;
                    for c in 0..code.b.l {
                        let (cr, cc) = code.grid_cell(gi, gj, r, c);
                        let m = grid[cr * rb + cc].as_ref().unwrap();
                        match &mut sum {
                            None => sum = Some(m.clone()),
                            Some(s) => s.add_assign(m),
                        }
                    }
                    let (cr, cc) = code.grid_cell(gi, gj, r, code.b.l);
                    let parity = grid[cr * rb + cc].as_ref().unwrap();
                    assert!(sum.unwrap().rel_err(parity) < 1e-4);
                }
                // Column constraints.
                for c in 0..=code.b.l {
                    let mut sum: Option<Matrix> = None;
                    for r in 0..code.a.l {
                        let (cr, cc) = code.grid_cell(gi, gj, r, c);
                        let m = grid[cr * rb + cc].as_ref().unwrap();
                        match &mut sum {
                            None => sum = Some(m.clone()),
                            Some(s) => s.add_assign(m),
                        }
                    }
                    let (cr, cc) = code.grid_cell(gi, gj, code.a.l, c);
                    let parity = grid[cr * rb + cc].as_ref().unwrap();
                    assert!(sum.unwrap().rel_err(parity) < 1e-4);
                }
            }
        }
    }

    #[test]
    fn decode_recovers_exact_product() {
        // Knock out ≤3 random cells per local grid; decode; compare the
        // assembled systematic output against the direct product A·Bᵀ.
        let code = LocalProductCode::new(4, 2, 4, 2);
        let mut rng = Pcg64::new(7);
        let a_full = Matrix::randn(16, 10, &mut rng, 0.0, 1.0);
        let b_full = Matrix::randn(12, 10, &mut rng, 0.0, 1.0);
        let pa = Partition::new(16, 10, 4);
        let pb = Partition::new(12, 10, 4);
        let a_blocks = pa.split(&a_full);
        let b_blocks = pb.split(&b_full);
        let mut grid = coded_grid_products(&code, &a_blocks, &b_blocks);
        let (ra, rb) = code.coded_grid();

        // Straggle 3 cells in each local grid.
        let (ga, gb) = code.groups();
        for gi in 0..ga {
            for gj in 0..gb {
                let picks = rng.sample_indices((code.a.l + 1) * (code.b.l + 1), 3);
                for p in picks {
                    let (r, c) = (p / (code.b.l + 1), p % (code.b.l + 1));
                    let (cr, cc) = code.grid_cell(gi, gj, r, c);
                    grid[cr * rb + cc] = None;
                }
            }
        }
        let _ = ra;

        let plans = decode_coded_output(&code, &mut grid);
        assert!(plans.iter().all(|p| p.decodable()));

        let sys = extract_systematic(&code, &grid).unwrap();
        // Assemble into the full C and compare.
        let shape = crate::linalg::blocked::GridShape { rows: 4, cols: 4 };
        let c = crate::linalg::blocked::assemble_grid(shape, &sys);
        let direct = matmul_bt(&a_full, &b_full);
        assert!(c.rel_err(&direct) < 1e-4, "err={}", c.rel_err(&direct));
    }

    #[test]
    fn decode_property_random_stragglers() {
        // Property: whenever the peel plan says decodable, the numeric
        // decode reproduces the true blocks exactly (up to f32 tolerance).
        proptest(40, 0xC0DE, |g| {
            let la = g.usize_in(1, 3);
            let lb = g.usize_in(1, 3);
            let block = g.usize_in(2, 4);
            let inner = g.usize_in(2, 5);
            let code = LocalProductCode::new(la, la, lb, lb); // 1 group per side
            let mut rng = crate::util::rng::Pcg64::new(g.case as u64 + 99);
            let a_blocks: Vec<Matrix> = (0..la)
                .map(|_| Matrix::randn(block, inner, &mut rng, 0.0, 1.0))
                .collect();
            let b_blocks: Vec<Matrix> = (0..lb)
                .map(|_| Matrix::randn(block, inner, &mut rng, 0.0, 1.0))
                .collect();
            let mut grid = coded_grid_products(&code, &a_blocks, &b_blocks);
            let truth: Vec<Matrix> = grid.iter().map(|c| c.clone().unwrap()).collect();
            let n = grid.len();
            let s = g.usize_in(0, n.min(5));
            for i in g.subset(n, s) {
                grid[i] = None;
            }
            let plans = decode_coded_output(&code, &mut grid);
            if plans.iter().all(|p| p.decodable()) {
                for (i, cell) in grid.iter().enumerate() {
                    let got = cell.as_ref().expect("decoded");
                    assert!(
                        got.rel_err(&truth[i]) < 1e-3,
                        "cell {i} err {}",
                        got.rel_err(&truth[i])
                    );
                }
            }
        });
    }

    #[test]
    fn parameters_match_paper() {
        let code = LocalProductCode::new(100, 10, 100, 10);
        assert!((code.redundancy() - 0.21).abs() < 1e-12);
        assert_eq!(code.locality(), 10);
        assert_eq!(code.max_reads_per_straggler(), 10);
        assert_eq!(code.coded_grid(), (110, 110));
        assert_eq!(code.groups(), (10, 10));
    }

    #[test]
    fn extract_systematic_fails_on_missing() {
        let code = LocalProductCode::new(2, 2, 2, 2);
        let a = random_blocks(2, 2, 3, 10);
        let b = random_blocks(2, 2, 3, 11);
        let mut grid = coded_grid_products(&code, &a, &b);
        grid[0] = None;
        assert!(extract_systematic(&code, &grid).is_err());
    }
}

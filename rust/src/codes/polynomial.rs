//! Baseline: **polynomial codes** [18] (Yu–Maddah-Ali–Avestimehr), the
//! MDS scheme with optimal recovery threshold that Fig 5 compares against.
//!
//! Encoding over the reals: worker k receives
//!   Ã_k = Σ_i A_i x_k^i          (degree < s_a)
//!   B̃_k = Σ_j B_j x_k^{s_a·j}    (degree < s_a·s_b)
//! and computes Ã_k·B̃_kᵀ = Σ_{i,j} (A_i·B_jᵀ) x_k^{i + s_a·j} — an
//! evaluation of a matrix polynomial whose s_a·s_b coefficients are
//! exactly the output blocks. Any K = s_a·s_b results reconstruct C by
//! polynomial interpolation.
//!
//! The decode reads **all K blocks** regardless of how many workers
//! straggled, and over the reals the Vandermonde interpolation is
//! numerically ill-conditioned as K grows — both are the paper's stated
//! reasons polynomial codes lose end-to-end in serverless settings (and
//! why "for large matrix dimensions, decoding with a polynomial code is
//! not feasible"). We use Chebyshev evaluation points to push the
//! feasible K as far as possible; the instability threshold is measured
//! in `tests` and reported in EXPERIMENTS.md.

use crate::codes::scheme::{
    CodingScheme, ComputePolicy, DecodePlan, EncodePlan, JobShape, ENCODE_WAIT_FRAC,
};
use crate::linalg::kernels;
use crate::linalg::matrix::{BlockBuf, Matrix};
use crate::platform::event::Termination;
use crate::platform::straggler::WorkProfile;
use crate::runtime::ComputeBackend;
use crate::util::threadpool::{num_threads, parallel_map};

/// Past this recovery threshold the real-arithmetic Vandermonde decode is
/// numerically meaningless (and the paper's master "cannot store" the
/// blocks): harnesses report virtual time but mark numerics infeasible.
pub const NUMERIC_CAP: usize = 64;

/// Polynomial code over `s_a × s_b` systematic blocks with `n_workers ≥ K`
/// total workers.
#[derive(Debug, Clone)]
pub struct PolynomialCode {
    pub s_a: usize,
    pub s_b: usize,
    pub n_workers: usize,
    /// Per-worker evaluation points (Chebyshev nodes on [-1, 1]).
    pub points: Vec<f64>,
}

impl PolynomialCode {
    pub fn new(s_a: usize, s_b: usize, n_workers: usize) -> PolynomialCode {
        let k = s_a * s_b;
        assert!(n_workers >= k, "need at least K = {k} workers");
        let points: Vec<f64> = (0..n_workers)
            .map(|i| {
                // Chebyshev points of the first kind.
                let t = (2.0 * i as f64 + 1.0) * std::f64::consts::PI
                    / (2.0 * n_workers as f64);
                t.cos()
            })
            .collect();
        PolynomialCode {
            s_a,
            s_b,
            n_workers,
            points,
        }
    }

    /// Recovery threshold K = s_a · s_b.
    pub fn threshold(&self) -> usize {
        self.s_a * self.s_b
    }

    pub fn redundancy(&self) -> f64 {
        self.n_workers as f64 / self.threshold() as f64 - 1.0
    }

    /// Encode the A side for worker k: Σ_i A_i x_k^i. Generic so shared
    /// [`BlockBuf`] handles encode without conversion.
    pub fn encode_a<B: std::borrow::Borrow<Matrix>>(&self, a_blocks: &[B], k: usize) -> Matrix {
        assert_eq!(a_blocks.len(), self.s_a);
        weighted_sum(a_blocks, |i| self.points[k].powi(i as i32))
    }

    /// Encode the B side for worker k: Σ_j B_j x_k^{s_a·j}.
    pub fn encode_b<B: std::borrow::Borrow<Matrix>>(&self, b_blocks: &[B], k: usize) -> Matrix {
        assert_eq!(b_blocks.len(), self.s_b);
        weighted_sum(b_blocks, |j| self.points[k].powi((self.s_a * j) as i32))
    }

    /// Decode from any ≥K worker results `(worker_index, Ã_k·B̃_kᵀ)`.
    /// Returns the `s_a × s_b` output blocks (row-major, C_{ij} at
    /// i·s_b + j) and the number of blocks read (always K — the MDS decode
    /// cost the paper highlights).
    pub fn decode(&self, results: &[(usize, Matrix)]) -> anyhow::Result<(Vec<Matrix>, usize)> {
        let k = self.threshold();
        anyhow::ensure!(
            results.len() >= k,
            "need {k} results, got {}",
            results.len()
        );
        let use_results = &results[..k];
        let (br, bc) = use_results[0].1.shape();

        // Build the K×K Vandermonde V[t][m] = x_{k_t}^m and invert it by
        // solving K unit systems (f64 throughout).
        let n = k;
        let mut v = vec![0f64; n * n];
        for (t, &(w, _)) in use_results.iter().enumerate() {
            let x = self.points[w];
            let mut p = 1f64;
            for m in 0..n {
                v[t * n + m] = p;
                p *= x;
            }
        }
        let vinv = invert_f64(&v, n)
            .map_err(|e| anyhow::anyhow!("polynomial decode ill-conditioned: {e}"))?;

        // Coefficient m (block C at exponent m = i + s_a·j) is
        // Σ_t vinv[m][t] · R_t — one independent AXPY reduction per
        // output block, fanned out over the host pool (the paper's
        // parallel-decoding story; per-block accumulation order is fixed,
        // so the result is thread-count independent).
        let out: Vec<Matrix> = parallel_map(num_threads(), k, |m| {
            let mut dst = Matrix::zeros(br, bc);
            for (t, (_, r)) in use_results.iter().enumerate() {
                let coef = vinv[m * n + t] as f32;
                if coef == 0.0 {
                    continue;
                }
                kernels::axpy(&mut dst.data, coef, &r.data);
            }
            dst
        });

        // Reorder exponent m = i + s_a·j into row-major (i, j).
        let mut blocks = Vec::with_capacity(k);
        for i in 0..self.s_a {
            for j in 0..self.s_b {
                blocks.push(out[i + self.s_a * j].clone());
            }
        }
        Ok((blocks, k))
    }
}

// ---------------------------------------------------------------------------
// CodingScheme impl — the MDS baseline as a pluggable scheme
// ---------------------------------------------------------------------------

/// Per-worker decode profile of the polynomial code: every decode worker
/// reads all K blocks (locality = K) and the K² block combines split
/// across the fleet.
pub fn polynomial_decode_profile(
    k: usize,
    workers: usize,
    block_rows: usize,
    block_cols: usize,
) -> WorkProfile {
    let out_bytes = (block_rows * block_cols * 4) as u64;
    WorkProfile {
        bytes_read: k as u64 * out_bytes,
        read_ops: k as u64,
        flops: (k * k / workers) as f64 * (block_rows * block_cols) as f64,
        bytes_written: (k / workers).max(1) as u64 * out_bytes,
        write_ops: (k / workers).max(1) as u64,
    }
}

/// The polynomial (MDS) code as a pluggable [`CodingScheme`].
#[derive(Debug, Clone)]
pub struct PolynomialScheme {
    pub code: PolynomialCode,
}

impl PolynomialScheme {
    /// Worker count from the redundancy factor: `n = ceil(K·(1 + r))`.
    pub fn new(s_a: usize, s_b: usize, redundancy: f64) -> anyhow::Result<PolynomialScheme> {
        anyhow::ensure!(
            redundancy.is_finite() && redundancy >= 0.0,
            "polynomial redundancy must be a non-negative number"
        );
        let k = s_a * s_b;
        let n_workers = ((k as f64) * (1.0 + redundancy)).ceil() as usize;
        Ok(PolynomialScheme {
            code: PolynomialCode::new(s_a, s_b, n_workers),
        })
    }
}

impl ComputePolicy for PolynomialScheme {
    fn compute_tasks(&self) -> usize {
        self.code.n_workers
    }

    /// MDS termination at the K-th arrival (wait-k as an event policy:
    /// the cutoff abandons the stragglers).
    fn compute_termination(&self) -> Termination {
        Termination::WaitK(self.code.threshold())
    }
}

impl CodingScheme for PolynomialScheme {
    fn name(&self) -> &'static str {
        "polynomial"
    }

    fn redundancy(&self) -> f64 {
        self.code.redundancy()
    }

    fn encode_plan(&self, shape: &JobShape, fleet: usize) -> Option<EncodePlan> {
        // Every one of the n_workers coded inputs Ã_k/B̃_k is a weighted
        // sum of ALL the side's blocks — n× more encode volume than the
        // local scheme. Column-sliced across a fleet sized like the other
        // schemes' for a fair comparison.
        let (s_a, s_b, n) = (self.code.s_a, self.code.s_b, self.code.n_workers);
        Some(EncodePlan {
            profile: WorkProfile::sliced_encode(
                2 * n,
                s_a.max(s_b),
                shape.block_rows,
                shape.inner,
                fleet,
            ),
            termination: Termination::Speculative {
                wait_frac: ENCODE_WAIT_FRAC,
            },
            blocks_read: n * (s_a + s_b),
        })
    }

    fn decode_plan(&self, _arrived: &[bool], shape: &JobShape, workers: usize) -> DecodePlan {
        // EVERY decode worker reads all K blocks (the paper's
        // communication-overhead point) and the interpolation costs K²
        // block combines.
        let k = self.code.threshold();
        let workers = workers.max(1);
        DecodePlan {
            profiles: vec![
                polynomial_decode_profile(k, workers, shape.block_rows, shape.block_cols);
                workers
            ],
            termination: Termination::WaitAll,
            blocks_read: workers * k,
            undecodable: 0,
        }
    }

    /// Numerics only below the conditioning wall ([`NUMERIC_CAP`]).
    fn numerics_feasible(&self) -> bool {
        self.code.threshold() <= NUMERIC_CAP
    }

    fn encode_numeric(
        &self,
        _backend: &dyn ComputeBackend,
        a_blocks: &[BlockBuf],
        b_blocks: &[BlockBuf],
    ) -> (Vec<BlockBuf>, Vec<BlockBuf>) {
        // Coded inputs are built lazily per arrived task in
        // `cell_product` — only the first K products are ever needed, so
        // "encoding" here is pure refcount bumps.
        (a_blocks.to_vec(), b_blocks.to_vec())
    }

    fn cell_product(
        &self,
        backend: &dyn ComputeBackend,
        a_blocks: &[BlockBuf],
        b_blocks: &[BlockBuf],
        cell: usize,
    ) -> BlockBuf {
        let at = self.code.encode_a(a_blocks, cell);
        let bt = self.code.encode_b(b_blocks, cell);
        BlockBuf::new(backend.block_product(&at, &bt))
    }

    fn decode_numeric(
        &self,
        _backend: &dyn ComputeBackend,
        mut grid: Vec<Option<BlockBuf>>,
        arrival_order: &[usize],
    ) -> anyhow::Result<Vec<BlockBuf>> {
        let k = self.code.threshold();
        anyhow::ensure!(
            arrival_order.len() == k,
            "wait-k must deliver exactly K arrivals"
        );
        // Never staged ⇒ sole-owned handles ⇒ `into_matrix` moves.
        let results: Vec<(usize, Matrix)> = arrival_order
            .iter()
            .map(|&w| {
                let buf = grid[w].take().expect("arrived cell was computed");
                (w, buf.into_matrix())
            })
            .collect();
        let (blocks, _) = self.code.decode(&results)?;
        Ok(blocks.into_iter().map(BlockBuf::new).collect())
    }
}

/// `Σ_i weight(i) · blocks[i]` through the AXPY kernel (left-to-right,
/// zero weights skipped — bit-identical to the historical scalar loop).
fn weighted_sum<B: std::borrow::Borrow<Matrix>>(
    blocks: &[B],
    weight: impl Fn(usize) -> f64,
) -> Matrix {
    let first = blocks[0].borrow();
    let mut acc = Matrix::zeros(first.rows, first.cols);
    for (i, b) in blocks.iter().enumerate() {
        let w = weight(i) as f32;
        if w == 0.0 {
            continue;
        }
        kernels::axpy(&mut acc.data, w, &b.borrow().data);
    }
    acc
}

/// Dense f64 matrix inverse via Gauss–Jordan with partial pivoting.
fn invert_f64(a: &[f64], n: usize) -> Result<Vec<f64>, String> {
    let mut m = a.to_vec();
    let mut inv = vec![0f64; n * n];
    for i in 0..n {
        inv[i * n + i] = 1.0;
    }
    for col in 0..n {
        // Pivot.
        let (piv, pval) = (col..n)
            .map(|r| (r, m[r * n + col].abs()))
            .fold((col, -1.0), |best, cand| if cand.1 > best.1 { cand } else { best });
        if pval < 1e-12 {
            return Err(format!("pivot {pval:.2e} at column {col}"));
        }
        if piv != col {
            for k in 0..n {
                m.swap(col * n + k, piv * n + k);
                inv.swap(col * n + k, piv * n + k);
            }
        }
        let d = m[col * n + col];
        for k in 0..n {
            m[col * n + k] /= d;
            inv[col * n + k] /= d;
        }
        for r in 0..n {
            if r == col {
                continue;
            }
            let f = m[r * n + col];
            if f == 0.0 {
                continue;
            }
            for k in 0..n {
                m[r * n + k] -= f * m[col * n + k];
                inv[r * n + k] -= f * inv[col * n + k];
            }
        }
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul_bt;
    use crate::util::rng::Pcg64;

    fn random_blocks(s: usize, rows: usize, cols: usize, seed: u64) -> Vec<Matrix> {
        let mut rng = Pcg64::new(seed);
        (0..s).map(|_| Matrix::randn(rows, cols, &mut rng, 0.0, 1.0)).collect()
    }

    fn worker_results(
        code: &PolynomialCode,
        a: &[Matrix],
        b: &[Matrix],
        workers: &[usize],
    ) -> Vec<(usize, Matrix)> {
        workers
            .iter()
            .map(|&k| (k, matmul_bt(&code.encode_a(a, k), &code.encode_b(b, k))))
            .collect()
    }

    #[test]
    fn decodes_with_first_k_workers() {
        let (sa, sb) = (3usize, 2usize);
        let code = PolynomialCode::new(sa, sb, 8);
        let a = random_blocks(sa, 4, 5, 1);
        let b = random_blocks(sb, 4, 5, 2);
        let workers: Vec<usize> = (0..code.threshold()).collect();
        let results = worker_results(&code, &a, &b, &workers);
        let (blocks, read) = code.decode(&results).unwrap();
        assert_eq!(read, 6);
        for i in 0..sa {
            for j in 0..sb {
                let truth = matmul_bt(&a[i], &b[j]);
                let err = blocks[i * sb + j].rel_err(&truth);
                assert!(err < 1e-2, "({i},{j}) err={err}");
            }
        }
    }

    #[test]
    fn decodes_with_any_k_subset() {
        // MDS property: stragglers on arbitrary workers don't matter.
        let (sa, sb) = (2usize, 2usize);
        let code = PolynomialCode::new(sa, sb, 7);
        let a = random_blocks(sa, 3, 4, 3);
        let b = random_blocks(sb, 3, 4, 4);
        for subset in [[0usize, 2, 4, 6], [1, 3, 5, 6], [3, 4, 5, 6]] {
            let results = worker_results(&code, &a, &b, &subset);
            let (blocks, _) = code.decode(&results).unwrap();
            for i in 0..sa {
                for j in 0..sb {
                    let truth = matmul_bt(&a[i], &b[j]);
                    assert!(blocks[i * sb + j].rel_err(&truth) < 1e-2);
                }
            }
        }
    }

    #[test]
    fn fewer_than_k_fails() {
        let code = PolynomialCode::new(2, 2, 6);
        let a = random_blocks(2, 2, 3, 5);
        let b = random_blocks(2, 2, 3, 6);
        let results = worker_results(&code, &a, &b, &[0, 1, 2]);
        assert!(code.decode(&results).is_err());
    }

    #[test]
    fn instability_grows_with_k() {
        // The real-arithmetic conditioning wall the paper alludes to:
        // reconstruction error grows rapidly with K = s_a·s_b. We assert
        // the *trend* — small K fine, large K degraded by orders of
        // magnitude — which EXPERIMENTS.md reports quantitatively.
        let mut errs = Vec::new();
        for &(sa, sb) in &[(2usize, 2usize), (4, 4), (6, 6)] {
            let code = PolynomialCode::new(sa, sb, sa * sb + 4);
            let a = random_blocks(sa, 2, 3, 7);
            let b = random_blocks(sb, 2, 3, 8);
            let workers: Vec<usize> = (0..code.threshold()).collect();
            let results = worker_results(&code, &a, &b, &workers);
            let (blocks, _) = code.decode(&results).unwrap();
            let mut worst = 0f64;
            for i in 0..sa {
                for j in 0..sb {
                    let truth = matmul_bt(&a[i], &b[j]);
                    worst = worst.max(blocks[i * sb + j].rel_err(&truth));
                }
            }
            errs.push(worst);
        }
        assert!(errs[0] < 1e-3, "K=4 should be accurate: {errs:?}");
        assert!(errs[2] > errs[0], "error should grow with K: {errs:?}");
    }

    #[test]
    fn redundancy_and_threshold() {
        let code = PolynomialCode::new(10, 10, 121);
        assert_eq!(code.threshold(), 100);
        assert!((code.redundancy() - 0.21).abs() < 1e-12);
    }
}

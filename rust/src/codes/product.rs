//! Baseline: classic **product code** with *global* parities ([16], the
//! scheme the paper compares against in Fig 5).
//!
//! Unlike the local product code, parities here are MDS along each full
//! axis: `t_a` parity row-blocks are Vandermonde-weighted combinations of
//! ALL `s_a` systematic row-blocks (likewise `t_b` columns). Decoding even
//! a single straggler therefore requires reading an **entire row or column
//! of C_coded** (§II-B: "Product codes have to read the entire column (or
//! row) block of C_coded ... this results in a huge communication
//! overhead") — which is exactly the effect the Fig-5 comparison measures.

use crate::codes::scheme::{
    CodingScheme, ComputePolicy, DecodePlan, DecodeProbe, EncodePlan, JobShape,
    DECODE_WAIT_FRAC, ENCODE_WAIT_FRAC,
};
use crate::linalg::kernels;
use crate::linalg::matrix::{BlockBuf, Matrix};
use crate::linalg::solve::lu_solve;
use crate::platform::event::Termination;
use crate::platform::straggler::WorkProfile;
use crate::runtime::ComputeBackend;
use crate::util::threadpool::{num_threads, parallel_map};

/// MDS code along one axis: `systematic` data blocks + `parities`
/// Vandermonde parity blocks. Any `systematic` of the `systematic +
/// parities` blocks suffice to reconstruct.
#[derive(Debug, Clone)]
pub struct MdsAxisCode {
    pub systematic: usize,
    pub parities: usize,
    /// Evaluation points: systematic block i acts as coefficient of x^i;
    /// parity p is the polynomial evaluated at `points[p]`.
    points: Vec<f64>,
}

impl MdsAxisCode {
    pub fn new(systematic: usize, parities: usize) -> MdsAxisCode {
        assert!(systematic > 0);
        // Small spread-out points keep the Vandermonde system conditioned
        // well enough for the modest axis sizes the simulator uses; the
        // instability at scale is *the paper's point* about such schemes.
        let points: Vec<f64> = (0..parities)
            .map(|p| 0.3 + 0.7 * (p as f64 + 1.0) / parities.max(1) as f64)
            .collect();
        MdsAxisCode {
            systematic,
            parities,
            points,
        }
    }

    pub fn coded_len(&self) -> usize {
        self.systematic + self.parities
    }

    /// Weight of systematic block `i` in parity `p`.
    pub fn weight(&self, p: usize, i: usize) -> f64 {
        self.points[p].powi(i as i32)
    }

    /// Compute parity block `p` from all systematic blocks (the
    /// [`kernels::axpy`] accumulate path; generic so shared
    /// [`BlockBuf`] handles encode without conversion).
    pub fn parity<B: std::borrow::Borrow<Matrix>>(&self, p: usize, blocks: &[B]) -> Matrix {
        assert_eq!(blocks.len(), self.systematic);
        let first = blocks[0].borrow();
        let mut acc = Matrix::zeros(first.rows, first.cols);
        for (i, b) in blocks.iter().enumerate() {
            let w = self.weight(p, i) as f32;
            kernels::axpy(&mut acc.data, w, &b.borrow().data);
        }
        acc
    }

    /// Encode a side: systematic blocks followed by parity blocks (serial
    /// reference; the coordinator path is [`MdsAxisCode::encode_parallel`]).
    pub fn encode(&self, blocks: &[Matrix]) -> Vec<Matrix> {
        let mut out = blocks.to_vec();
        for p in 0..self.parities {
            out.push(self.parity(p, blocks));
        }
        out
    }

    /// Parallel shared-handle encode: the systematic prefix is refcount
    /// bumps and each (global) parity is an independent task. Bit-identical
    /// to [`MdsAxisCode::encode`] at every thread count.
    pub fn encode_parallel(&self, blocks: &[BlockBuf], threads: usize) -> Vec<BlockBuf> {
        assert_eq!(blocks.len(), self.systematic);
        parallel_map(threads, self.coded_len(), |k| {
            if k < self.systematic {
                blocks[k].clone()
            } else {
                BlockBuf::new(self.parity(k - self.systematic, blocks))
            }
        })
    }

    /// Recover missing systematic blocks along one line.
    ///
    /// `line[k]` is the k-th coded block of the line (`None` = missing),
    /// k < systematic are data, k ≥ systematic are parities. Returns the
    /// fully recovered systematic prefix, or Err if more than `parities`
    /// blocks are missing / insufficient parities survive.
    pub fn recover_line(&self, line: &[Option<Matrix>]) -> anyhow::Result<Vec<Matrix>> {
        anyhow::ensure!(line.len() == self.coded_len(), "line length");
        let missing: Vec<usize> = (0..self.systematic).filter(|&i| line[i].is_none()).collect();
        if missing.is_empty() {
            return Ok(line[..self.systematic]
                .iter()
                .map(|b| b.clone().unwrap())
                .collect());
        }
        let avail_parities: Vec<usize> = (0..self.parities)
            .filter(|&p| line[self.systematic + p].is_some())
            .collect();
        anyhow::ensure!(
            avail_parities.len() >= missing.len(),
            "{} missing but only {} parities available",
            missing.len(),
            avail_parities.len()
        );
        let e = missing.len();
        let use_parities = &avail_parities[..e];

        // Each used parity p gives: Σ_{m in missing} w_{p,m} X_m
        //   = parity_p − Σ_{present i} w_{p,i} D_i  (the "syndrome").
        let (br, bc) = {
            let any = line.iter().flatten().next().expect("some block present");
            (any.rows, any.cols)
        };
        let mut syndromes: Vec<Matrix> = Vec::with_capacity(e);
        for &p in use_parities {
            let mut s = line[self.systematic + p].clone().unwrap();
            for i in 0..self.systematic {
                if let Some(d) = &line[i] {
                    let w = self.weight(p, i) as f32;
                    kernels::axpy(&mut s.data, -w, &d.data);
                }
            }
            syndromes.push(s);
        }

        // Solve the e×e system W·X = S for each entry; W is shared, so
        // invert once by solving against unit vectors.
        let mut w = Matrix::zeros(e, e);
        for (r, &p) in use_parities.iter().enumerate() {
            for (c, &m) in missing.iter().enumerate() {
                w.set(r, c, self.weight(p, m) as f32);
            }
        }
        let mut winv = vec![vec![0f64; e]; e]; // winv[row][col]
        for col in 0..e {
            let mut rhs = vec![0f64; e];
            rhs[col] = 1.0;
            let x = lu_solve(&w, &rhs)?;
            for row in 0..e {
                winv[row][col] = x[row];
            }
        }

        // X_m = Σ_p winv[m][p] · S_p.
        let mut recovered: Vec<Matrix> = (0..e).map(|_| Matrix::zeros(br, bc)).collect();
        for (m, rec) in recovered.iter_mut().enumerate() {
            for (pi, syn) in syndromes.iter().enumerate() {
                let coef = winv[m][pi] as f32;
                kernels::axpy(&mut rec.data, coef, &syn.data);
            }
        }

        let mut out: Vec<Matrix> = Vec::with_capacity(self.systematic);
        let mut next_rec = 0usize;
        for i in 0..self.systematic {
            if line[i].is_some() {
                out.push(line[i].clone().unwrap());
            } else {
                out.push(recovered[next_rec].clone());
                next_rec += 1;
            }
        }
        Ok(out)
    }
}

/// The 2-D product code over the output grid: `(s_a + t_a) × (s_b + t_b)`
/// coded blocks where coded row i ≥ s_a is the Vandermonde combination of
/// all systematic rows (and likewise for columns).
#[derive(Debug, Clone)]
pub struct ProductCode {
    pub row_code: MdsAxisCode,
    pub col_code: MdsAxisCode,
}

/// Result of a product-code decode attempt.
#[derive(Debug, Clone)]
pub struct ProductDecode {
    /// Recovered systematic blocks, row-major `s_a × s_b`.
    pub systematic: Vec<Matrix>,
    /// Total blocks read during recovery (the Fig-5 cost driver).
    pub blocks_read: usize,
    /// Stragglers recovered.
    pub recovered: usize,
}

impl ProductCode {
    pub fn new(s_a: usize, t_a: usize, s_b: usize, t_b: usize) -> ProductCode {
        ProductCode {
            row_code: MdsAxisCode::new(s_a, t_a),
            col_code: MdsAxisCode::new(s_b, t_b),
        }
    }

    pub fn coded_grid(&self) -> (usize, usize) {
        (self.row_code.coded_len(), self.col_code.coded_len())
    }

    pub fn redundancy(&self) -> f64 {
        let (ra, rb) = self.coded_grid();
        (ra * rb) as f64 / (self.row_code.systematic * self.col_code.systematic) as f64 - 1.0
    }

    /// Encode both sides' row-blocks.
    pub fn encode_sides(&self, a: &[Matrix], b: &[Matrix]) -> (Vec<Matrix>, Vec<Matrix>) {
        (self.row_code.encode(a), self.col_code.encode(b))
    }

    /// Boolean decodability: iterate axis recoveries on the arrival mask
    /// to fixpoint (the earliest-decodable predicate).
    pub fn decodable(&self, arrived: &[bool]) -> bool {
        self.plan_decode(arrived).is_some()
    }

    /// Mask-level twin of [`ProductCode::decode`]: runs the same
    /// column-then-row recovery passes over a presence mask and returns
    /// `(blocks_read, recovered)` with identical accounting, or `None`
    /// when the pattern is stuck. Used by the scenario runner, which
    /// simulates timing without materializing matrices.
    pub fn plan_decode(&self, arrived: &[bool]) -> Option<(usize, usize)> {
        let (ra, rb) = self.coded_grid();
        assert_eq!(arrived.len(), ra * rb);
        let s_a = self.row_code.systematic;
        let s_b = self.col_code.systematic;
        let mut have = arrived.to_vec();
        let mut blocks_read = 0usize;
        let mut recovered = 0usize;
        loop {
            let mut progressed = false;
            for c in 0..rb {
                let missing_data = (0..s_a).filter(|&r| !have[r * rb + c]).count();
                if missing_data == 0 {
                    continue;
                }
                let avail_par = (s_a..ra).filter(|&r| have[r * rb + c]).count();
                if missing_data <= avail_par {
                    blocks_read += (0..ra).filter(|&r| have[r * rb + c]).count();
                    for r in 0..s_a {
                        if !have[r * rb + c] {
                            recovered += 1;
                            progressed = true;
                        }
                        have[r * rb + c] = true;
                    }
                }
            }
            for r in 0..s_a {
                let missing_data = (0..s_b).filter(|&c| !have[r * rb + c]).count();
                if missing_data == 0 {
                    continue;
                }
                let avail_par = (s_b..rb).filter(|&c| have[r * rb + c]).count();
                if missing_data <= avail_par {
                    blocks_read += (0..rb).filter(|&c| have[r * rb + c]).count();
                    for c in 0..s_b {
                        if !have[r * rb + c] {
                            recovered += 1;
                            progressed = true;
                        }
                        have[r * rb + c] = true;
                    }
                }
            }
            let all_sys = (0..s_a).all(|r| (0..s_b).all(|c| have[r * rb + c]));
            if all_sys {
                return Some((blocks_read, recovered));
            }
            if !progressed {
                return None;
            }
        }
    }

    /// Decode the coded output grid (row-major `Option<Matrix>`); uses
    /// column-wise then row-wise MDS recovery passes until fixpoint.
    pub fn decode(&self, coded: &mut [Option<Matrix>]) -> anyhow::Result<ProductDecode> {
        let (ra, rb) = self.coded_grid();
        assert_eq!(coded.len(), ra * rb);
        let s_a = self.row_code.systematic;
        let s_b = self.col_code.systematic;
        let mut blocks_read = 0usize;
        let mut recovered = 0usize;

        loop {
            let mut progressed = false;
            // Column passes: for each coded column, treat the s_a
            // systematic rows as data and t_a parity rows as parities.
            for c in 0..rb {
                let missing_data =
                    (0..s_a).filter(|&r| coded[r * rb + c].is_none()).count();
                if missing_data == 0 {
                    continue;
                }
                let avail_par = (s_a..ra).filter(|&r| coded[r * rb + c].is_some()).count();
                if missing_data <= avail_par {
                    let line: Vec<Option<Matrix>> =
                        (0..ra).map(|r| coded[r * rb + c].clone()).collect();
                    let present = line.iter().flatten().count();
                    blocks_read += present; // read the entire surviving column
                    let rec = self.row_code.recover_line(&line)?;
                    for (r, blk) in rec.into_iter().enumerate() {
                        if coded[r * rb + c].is_none() {
                            recovered += 1;
                            progressed = true;
                        }
                        coded[r * rb + c] = Some(blk);
                    }
                }
            }
            // Row passes over systematic rows only (parity rows beyond the
            // systematic columns are never needed for output).
            for r in 0..s_a {
                let missing_data =
                    (0..s_b).filter(|&c| coded[r * rb + c].is_none()).count();
                if missing_data == 0 {
                    continue;
                }
                let avail_par = (s_b..rb).filter(|&c| coded[r * rb + c].is_some()).count();
                if missing_data <= avail_par {
                    let line: Vec<Option<Matrix>> =
                        (0..rb).map(|c| coded[r * rb + c].clone()).collect();
                    let present = line.iter().flatten().count();
                    blocks_read += present;
                    let rec = self.col_code.recover_line(&line)?;
                    for (c, blk) in rec.into_iter().enumerate() {
                        if coded[r * rb + c].is_none() {
                            recovered += 1;
                            progressed = true;
                        }
                        coded[r * rb + c] = Some(blk);
                    }
                }
            }
            // Done when all systematic cells are present.
            let all_sys = (0..s_a).all(|r| (0..s_b).all(|c| coded[r * rb + c].is_some()));
            if all_sys {
                break;
            }
            anyhow::ensure!(progressed, "product code stuck: undecodable straggler pattern");
        }

        let mut systematic = Vec::with_capacity(s_a * s_b);
        for r in 0..s_a {
            for c in 0..s_b {
                systematic.push(coded[r * rb + c].clone().unwrap());
            }
        }
        Ok(ProductDecode {
            systematic,
            blocks_read,
            recovered,
        })
    }
}

// ---------------------------------------------------------------------------
// CodingScheme impl — the global-parity baseline as a pluggable scheme
// ---------------------------------------------------------------------------

/// Decode-phase profile of the product code's single decode worker: the
/// row/column recovery passes are globally coupled, so one worker reads
/// every surviving block of the touched lines and rewrites the recovered
/// cells.
pub fn product_decode_profile(
    reads: usize,
    recovered: usize,
    block_rows: usize,
    block_cols: usize,
) -> WorkProfile {
    let out_bytes = (block_rows * block_cols * 4) as u64;
    WorkProfile {
        bytes_read: reads as u64 * out_bytes,
        read_ops: reads as u64,
        flops: (reads * block_rows * block_cols) as f64,
        bytes_written: (recovered.max(1) as u64) * out_bytes,
        write_ops: recovered as u64,
    }
}

/// The global-parity product code as a pluggable [`CodingScheme`].
#[derive(Debug, Clone)]
pub struct ProductScheme {
    pub code: ProductCode,
}

impl ProductScheme {
    pub fn new(s_a: usize, t_a: usize, s_b: usize, t_b: usize) -> ProductScheme {
        ProductScheme {
            code: ProductCode::new(s_a, t_a, s_b, t_b),
        }
    }
}

impl ComputePolicy for ProductScheme {
    fn compute_tasks(&self) -> usize {
        let (ra, rb) = self.code.coded_grid();
        ra * rb
    }

    fn compute_termination(&self) -> Termination {
        Termination::EarliestDecodable
    }

    fn decode_probe(&self) -> DecodeProbe {
        // Global parities couple every cell, so the whole-mask fixpoint is
        // re-run per completion (no per-grid incremental form exists).
        // Stateless, so `None`-hint feasibility queries are pure for free.
        let code = self.code.clone();
        Box::new(move |mask: &[bool], _| code.decodable(mask))
    }

    fn partial_credit(&self) -> bool {
        // The peeling decode is a chain of AXPY subtractions over
        // block-product summands — partial products substitute cleanly.
        true
    }
}

impl CodingScheme for ProductScheme {
    fn name(&self) -> &'static str {
        "product"
    }

    fn redundancy(&self) -> f64 {
        self.code.redundancy()
    }

    fn coded_grid_dims(&self) -> (usize, usize) {
        self.code.coded_grid()
    }

    fn encode_plan(&self, shape: &JobShape, fleet: usize) -> Option<EncodePlan> {
        // Each parity reads ALL s blocks of its side (global parities —
        // the encode-cost handicap vs local codes), column-sliced across
        // the same small fleet.
        let (s_a, s_b) = (self.code.row_code.systematic, self.code.col_code.systematic);
        let (t_a, t_b) = (self.code.row_code.parities, self.code.col_code.parities);
        Some(EncodePlan {
            profile: WorkProfile::sliced_encode(
                t_a + t_b,
                s_a.max(s_b),
                shape.block_rows,
                shape.inner,
                fleet,
            ),
            termination: Termination::Speculative {
                wait_frac: ENCODE_WAIT_FRAC,
            },
            blocks_read: t_a * s_a + t_b * s_b,
        })
    }

    fn decode_plan(&self, arrived: &[bool], shape: &JobShape, _workers: usize) -> DecodePlan {
        // Unlike the local scheme's independent grids, the recovery
        // passes are globally coupled (a column pass feeds the next row
        // pass), so decode does not parallelize across workers — the
        // paper's "huge communication overhead" (§II-B).
        let (reads, recovered) = self
            .code
            .plan_decode(arrived)
            .expect("earliest-decodable terminated on a decodable mask");
        if reads == 0 {
            return DecodePlan::none();
        }
        DecodePlan {
            profiles: vec![product_decode_profile(
                reads,
                recovered,
                shape.block_rows,
                shape.block_cols,
            )],
            termination: Termination::Speculative {
                wait_frac: DECODE_WAIT_FRAC,
            },
            blocks_read: reads,
            undecodable: 0,
        }
    }

    fn encode_numeric(
        &self,
        _backend: &dyn ComputeBackend,
        a_blocks: &[BlockBuf],
        b_blocks: &[BlockBuf],
    ) -> (Vec<BlockBuf>, Vec<BlockBuf>) {
        let threads = num_threads();
        (
            self.code.row_code.encode_parallel(a_blocks, threads),
            self.code.col_code.encode_parallel(b_blocks, threads),
        )
    }

    fn decode_numeric(
        &self,
        _backend: &dyn ComputeBackend,
        grid: Vec<Option<BlockBuf>>,
        _arrival_order: &[usize],
    ) -> anyhow::Result<Vec<BlockBuf>> {
        // The recovery passes mutate cells in place, so materialize owned
        // matrices; the scheme never stages blocks, so every handle is
        // sole-owned and `into_matrix` is a move, not a copy.
        let mut grid: Vec<Option<Matrix>> = grid
            .into_iter()
            .map(|slot| slot.map(BlockBuf::into_matrix))
            .collect();
        Ok(self
            .code
            .decode(&mut grid)?
            .systematic
            .into_iter()
            .map(BlockBuf::new)
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul_bt;
    use crate::util::rng::Pcg64;

    fn random_blocks(s: usize, rows: usize, cols: usize, seed: u64) -> Vec<Matrix> {
        let mut rng = Pcg64::new(seed);
        (0..s).map(|_| Matrix::randn(rows, cols, &mut rng, 0.0, 1.0)).collect()
    }

    fn build_grid(pc: &ProductCode, a: &[Matrix], b: &[Matrix]) -> Vec<Option<Matrix>> {
        let (ac, bc) = pc.encode_sides(a, b);
        let (ra, rb) = pc.coded_grid();
        let mut grid = Vec::with_capacity(ra * rb);
        for i in 0..ra {
            for j in 0..rb {
                grid.push(Some(matmul_bt(&ac[i], &bc[j])));
            }
        }
        grid
    }

    #[test]
    fn axis_recover_single_missing() {
        let code = MdsAxisCode::new(4, 2);
        let blocks = random_blocks(4, 3, 4, 1);
        let coded = code.encode(&blocks);
        for missing in 0..4 {
            let mut line: Vec<Option<Matrix>> = coded.iter().cloned().map(Some).collect();
            line[missing] = None;
            let rec = code.recover_line(&line).unwrap();
            assert!(rec[missing].rel_err(&blocks[missing]) < 1e-3);
        }
    }

    #[test]
    fn axis_recover_two_missing() {
        let code = MdsAxisCode::new(5, 2);
        let blocks = random_blocks(5, 2, 3, 2);
        let coded = code.encode(&blocks);
        let mut line: Vec<Option<Matrix>> = coded.iter().cloned().map(Some).collect();
        line[1] = None;
        line[3] = None;
        let rec = code.recover_line(&line).unwrap();
        for i in 0..5 {
            assert!(rec[i].rel_err(&blocks[i]) < 1e-3, "block {i}");
        }
    }

    #[test]
    fn axis_too_many_missing_fails() {
        let code = MdsAxisCode::new(4, 1);
        let blocks = random_blocks(4, 2, 2, 3);
        let coded = code.encode(&blocks);
        let mut line: Vec<Option<Matrix>> = coded.iter().cloned().map(Some).collect();
        line[0] = None;
        line[1] = None;
        assert!(code.recover_line(&line).is_err());
    }

    #[test]
    fn product_decode_recovers_output() {
        let pc = ProductCode::new(3, 1, 3, 1);
        let a = random_blocks(3, 4, 5, 4);
        let b = random_blocks(3, 4, 5, 5);
        let mut grid = build_grid(&pc, &a, &b);
        // Remove 3 scattered cells.
        let (_, rb) = pc.coded_grid();
        for &(r, c) in &[(0usize, 0usize), (1, 2), (3, 1)] {
            grid[r * rb + c] = None;
        }
        let dec = pc.decode(&mut grid).unwrap();
        assert!(dec.recovered >= 2); // (3,1) is a parity-row cell, may or may not be rebuilt
        assert!(dec.blocks_read > 0);
        // Check systematic output.
        for i in 0..3 {
            for j in 0..3 {
                let truth = matmul_bt(&a[i], &b[j]);
                assert!(dec.systematic[i * 3 + j].rel_err(&truth) < 1e-3, "({i},{j})");
            }
        }
    }

    #[test]
    fn product_decode_reads_entire_lines() {
        // The cost signature vs local product codes: one straggler forces
        // reading a full surviving column (s_a + t_a − 1 blocks here).
        let pc = ProductCode::new(4, 1, 4, 1);
        let a = random_blocks(4, 3, 4, 6);
        let b = random_blocks(4, 3, 4, 7);
        let mut grid = build_grid(&pc, &a, &b);
        let (_, rb) = pc.coded_grid();
        grid[2 * rb + 2] = None; // single straggler
        let dec = pc.decode(&mut grid).unwrap();
        assert_eq!(dec.recovered, 1);
        assert_eq!(dec.blocks_read, 4 + 1 - 1); // whole column minus the missing cell
    }

    #[test]
    fn product_unrecoverable_pattern_errors() {
        // 2×2 square of missing data cells with only 1 parity per axis.
        let pc = ProductCode::new(3, 1, 3, 1);
        let a = random_blocks(3, 2, 3, 8);
        let b = random_blocks(3, 2, 3, 9);
        let mut grid = build_grid(&pc, &a, &b);
        let (_, rb) = pc.coded_grid();
        for &(r, c) in &[(0usize, 0usize), (0, 1), (1, 0), (1, 1)] {
            grid[r * rb + c] = None;
        }
        assert!(pc.decode(&mut grid).is_err());
    }

    #[test]
    fn plan_decode_matches_numeric_decode_accounting() {
        // The mask-level twin must agree with the numeric decoder on
        // reads/recovered for random straggler patterns (and on being
        // stuck).
        let pc = ProductCode::new(4, 2, 3, 2);
        let a = random_blocks(4, 2, 3, 10);
        let b = random_blocks(3, 2, 3, 11);
        let (ra, rb) = pc.coded_grid();
        let mut rng = Pcg64::new(12);
        for _ in 0..60 {
            let drop = rng.index(8);
            let missing = rng.sample_indices(ra * rb, drop);
            let mut grid = build_grid(&pc, &a, &b);
            let mut mask = vec![true; ra * rb];
            for &m in &missing {
                grid[m] = None;
                mask[m] = false;
            }
            match pc.plan_decode(&mask) {
                Some((reads, recovered)) => {
                    let dec = pc.decode(&mut grid).expect("plan says decodable");
                    assert_eq!(dec.blocks_read, reads, "missing {missing:?}");
                    assert_eq!(dec.recovered, recovered, "missing {missing:?}");
                }
                None => {
                    assert!(pc.decode(&mut grid).is_err(), "missing {missing:?}");
                }
            }
        }
    }

    #[test]
    fn decodable_mask_semantics() {
        let pc = ProductCode::new(3, 1, 3, 1);
        let (ra, rb) = pc.coded_grid();
        let all = vec![true; ra * rb];
        assert!(pc.decodable(&all));
        // A 2×2 square of missing data cells with 1 parity per axis is the
        // canonical stuck pattern.
        let mut mask = all.clone();
        for &(r, c) in &[(0usize, 0usize), (0, 1), (1, 0), (1, 1)] {
            mask[r * rb + c] = false;
        }
        assert!(!pc.decodable(&mask));
        // Nothing arrived: undecodable (no parities to work with).
        assert!(!pc.decodable(&vec![false; ra * rb]));
    }

    #[test]
    fn redundancy_matches_fig5_setup() {
        // Fig 5 matches ≥21% redundancy: 10% parities each axis.
        let pc = ProductCode::new(10, 1, 10, 1);
        assert!((pc.redundancy() - 0.21).abs() < 1e-12);
    }
}

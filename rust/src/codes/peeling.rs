//! Peeling decoder over a single local grid.
//!
//! A *local grid* is the `(L_A+1) × (L_B+1)` block of `C_coded` a decoding
//! worker operates on (§II-B): rows `0..L_A` and columns `0..L_B` are
//! systematic, the last row and last column are parities, and every row and
//! every column satisfies "parity cell = Σ systematic cells" (a product
//! code with one parity per axis, minimum distance 4).
//!
//! The decoder here produces a *recovery plan* — the exact order of row/
//! column peels and the number of block reads each costs — which the
//! coordinator's decode phase then executes numerically, and the
//! Monte-Carlo validator uses to check Theorems 1 and 2.

/// Which constraint is used to recover a cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Axis {
    /// Recover via the cell's row: read the other `L_B` cells in the row.
    Row,
    /// Recover via the cell's column: read the other `L_A` cells.
    Col,
}

/// One step of the recovery plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Recovery {
    /// Grid coordinates (r, c) of the recovered cell.
    pub cell: (usize, usize),
    pub axis: Axis,
    /// Blocks read to execute this step under the paper's accounting
    /// (every other cell in the chosen row/column, no caching).
    pub reads: usize,
}

/// Outcome of planning the peeling decode for a grid.
#[derive(Debug, Clone)]
pub struct PeelPlan {
    /// Grid dims: (L_A + 1) rows × (L_B + 1) cols.
    pub rows: usize,
    pub cols: usize,
    /// Recovery steps in execution order.
    pub steps: Vec<Recovery>,
    /// Cells that cannot be recovered (an undecodable set), empty on
    /// success.
    pub undecodable: Vec<(usize, usize)>,
    /// Total block reads under the paper's per-straggler accounting
    /// (Theorem 1's `R`): Σ reads over steps.
    pub total_reads: usize,
    /// Total *distinct* blocks read assuming the decoding worker caches
    /// blocks it has already fetched (the implementation optimization; the
    /// bound still holds since cached ≤ uncached).
    pub distinct_reads: usize,
}

impl PeelPlan {
    pub fn decodable(&self) -> bool {
        self.undecodable.is_empty()
    }

    /// Number of stragglers the plan recovers.
    pub fn recovered(&self) -> usize {
        self.steps.len()
    }
}

/// Plan a peeling decode of a grid with `rows × cols` cells given which
/// cells are present. `present[r][c]` uses row-major `present[r * cols + c]`.
///
/// Strategy: repeatedly find a row or column with exactly one missing cell
/// and peel it. When both axes are available for some cell, prefer the
/// cheaper axis (fewer reads) — this realizes the locality
/// `min(L_A, L_B)` for an isolated straggler.
pub fn plan_peel(rows: usize, cols: usize, present: &[bool]) -> PeelPlan {
    assert_eq!(present.len(), rows * cols);
    let mut have: Vec<bool> = present.to_vec();
    let mut row_missing: Vec<usize> = vec![0; rows];
    let mut col_missing: Vec<usize> = vec![0; cols];
    for r in 0..rows {
        for c in 0..cols {
            if !have[r * cols + c] {
                row_missing[r] += 1;
                col_missing[c] += 1;
            }
        }
    }

    let mut steps = Vec::new();
    let mut read_cells: Vec<bool> = vec![false; rows * cols];
    let mut distinct_reads = 0usize;
    let row_cost = cols - 1; // read the other L_B cells (cols = L_B + 1)
    let col_cost = rows - 1;

    loop {
        // Candidate peels: (cost, r, c, axis). Scan rows and columns with
        // exactly one missing cell; pick the cheapest candidate first so
        // isolated stragglers use the min(L_A, L_B) axis.
        let mut best: Option<(usize, usize, usize, Axis)> = None;
        for r in 0..rows {
            if row_missing[r] == 1 {
                let c = (0..cols).find(|&c| !have[r * cols + c]).unwrap();
                // If this cell's column is also peelable, the column may be
                // cheaper; the column scan below will consider it.
                if best.map(|b| row_cost < b.0).unwrap_or(true) {
                    best = Some((row_cost, r, c, Axis::Row));
                }
            }
        }
        for c in 0..cols {
            if col_missing[c] == 1 {
                let r = (0..rows).find(|&r| !have[r * cols + c]).unwrap();
                if best.map(|b| col_cost < b.0).unwrap_or(true) {
                    best = Some((col_cost, r, c, Axis::Col));
                }
            }
        }
        let Some((cost, r, c, axis)) = best else { break };

        // Count distinct reads for the cached accounting.
        match axis {
            Axis::Row => {
                for cc in 0..cols {
                    if cc != c && !read_cells[r * cols + cc] {
                        read_cells[r * cols + cc] = true;
                        distinct_reads += 1;
                    }
                }
            }
            Axis::Col => {
                for rr in 0..rows {
                    if rr != r && !read_cells[rr * cols + c] {
                        read_cells[rr * cols + c] = true;
                        distinct_reads += 1;
                    }
                }
            }
        }
        steps.push(Recovery { cell: (r, c), axis, reads: cost });
        have[r * cols + c] = true;
        // A recovered cell counts as locally available for later peels at
        // no extra read cost (it is in the worker's memory).
        read_cells[r * cols + c] = true;
        row_missing[r] -= 1;
        col_missing[c] -= 1;
    }

    let undecodable: Vec<(usize, usize)> = (0..rows * cols)
        .filter(|&i| !have[i])
        .map(|i| (i / cols, i % cols))
        .collect();
    let total_reads = steps.iter().map(|s| s.reads).sum();
    PeelPlan {
        rows,
        cols,
        steps,
        undecodable,
        total_reads,
        distinct_reads,
    }
}

/// Partition a plan's steps into **wavefront levels** for parallel
/// numeric execution: a step lands in level `L+1` where `L` is the
/// deepest level among the previously-recovered cells its constraint
/// line reads (steps reading only originally-present cells land in level
/// 0). Steps within one level are mutually independent — each reads only
/// original cells and cells recovered in strictly earlier levels — so a
/// decoder may execute a whole level concurrently and still produce
/// values bit-identical to the serial plan order (the plan itself, and
/// thus every golden peel order, is untouched; only numeric execution is
/// scheduled differently).
///
/// Returns indices into `plan.steps`, grouped by level; within a level
/// the original plan order is preserved. The flattened result is a
/// permutation of `0..plan.steps.len()`.
pub fn wavefront_levels(plan: &PeelPlan) -> Vec<Vec<usize>> {
    let (rows, cols) = (plan.rows, plan.cols);
    // Level at which each cell becomes available; `None` = originally
    // present (every plan step's line cells are either original or
    // recovered by an earlier step — `plan_peel` only emits executable
    // steps).
    let mut recovered_at: Vec<Option<usize>> = vec![None; rows * cols];
    let mut levels: Vec<Vec<usize>> = Vec::new();
    for (si, step) in plan.steps.iter().enumerate() {
        let (r, c) = step.cell;
        let mut lvl = 0usize;
        match step.axis {
            Axis::Row => {
                for cc in 0..cols {
                    if cc != c {
                        if let Some(l) = recovered_at[r * cols + cc] {
                            lvl = lvl.max(l + 1);
                        }
                    }
                }
            }
            Axis::Col => {
                for rr in 0..rows {
                    if rr != r {
                        if let Some(l) = recovered_at[rr * cols + c] {
                            lvl = lvl.max(l + 1);
                        }
                    }
                }
            }
        }
        recovered_at[r * cols + c] = Some(lvl);
        if levels.len() <= lvl {
            levels.resize_with(lvl + 1, Vec::new);
        }
        levels[lvl].push(si);
    }
    levels
}

/// Brute-force decodability oracle for small grids (tests/MC cross-check):
/// a missing set is decodable iff iterating "recover any cell that is the
/// only missing one in its row or column" empties it. Peeling is optimal
/// for product codes with one parity per axis, so this equals `plan_peel`'s
/// verdict — but this implementation is deliberately independent (set-based,
/// no counters) to serve as an oracle.
pub fn decodable_bruteforce(rows: usize, cols: usize, present: &[bool]) -> bool {
    let mut missing: std::collections::BTreeSet<(usize, usize)> = (0..rows * cols)
        .filter(|&i| !present[i])
        .map(|i| (i / cols, i % cols))
        .collect();
    loop {
        let mut progressed = false;
        let snapshot: Vec<(usize, usize)> = missing.iter().copied().collect();
        for &(r, c) in &snapshot {
            let row_others = missing.iter().filter(|&&(rr, _)| rr == r).count();
            let col_others = missing.iter().filter(|&&(_, cc)| cc == c).count();
            if row_others == 1 || col_others == 1 {
                missing.remove(&(r, c));
                progressed = true;
            }
        }
        if missing.is_empty() {
            return true;
        }
        if !progressed {
            return false;
        }
    }
}

/// An individual straggler is undecodable iff there is at least one other
/// straggler in both its row and its column (§III-C). Exposed for tests.
pub fn individually_blocked(rows: usize, cols: usize, present: &[bool], cell: (usize, usize)) -> bool {
    let (r, c) = cell;
    let row_block = (0..cols).any(|cc| cc != c && !present[r * cols + cc]);
    let col_block = (0..rows).any(|rr| rr != r && !present[rr * cols + c]);
    row_block && col_block
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::proptest;

    fn grid(rows: usize, cols: usize, missing: &[(usize, usize)]) -> Vec<bool> {
        let mut p = vec![true; rows * cols];
        for &(r, c) in missing {
            p[r * cols + c] = false;
        }
        p
    }

    #[test]
    fn no_stragglers_no_work() {
        let p = grid(3, 3, &[]);
        let plan = plan_peel(3, 3, &p);
        assert!(plan.decodable());
        assert_eq!(plan.total_reads, 0);
        assert_eq!(plan.recovered(), 0);
    }

    #[test]
    fn single_straggler_uses_min_locality() {
        // 4 rows (L_A=3), 3 cols (L_B=2): min locality = 2 via the row.
        let p = grid(4, 3, &[(1, 1)]);
        let plan = plan_peel(4, 3, &p);
        assert!(plan.decodable());
        assert_eq!(plan.recovered(), 1);
        assert_eq!(plan.steps[0].axis, Axis::Row);
        assert_eq!(plan.total_reads, 2); // = L_B = min(3, 2)
    }

    #[test]
    fn any_three_stragglers_decodable_3x3() {
        // Paper §III-C: local product codes decode ANY 3 stragglers.
        let (rows, cols) = (3, 3);
        let n = rows * cols;
        let mut checked = 0;
        for a in 0..n {
            for b in a + 1..n {
                for c in b + 1..n {
                    let p = grid(
                        rows,
                        cols,
                        &[
                            (a / cols, a % cols),
                            (b / cols, b % cols),
                            (c / cols, c % cols),
                        ],
                    );
                    let plan = plan_peel(rows, cols, &p);
                    assert!(plan.decodable(), "cells {a},{b},{c}");
                    assert_eq!(plan.recovered(), 3);
                    checked += 1;
                }
            }
        }
        assert_eq!(checked, 84); // C(9,3)
    }

    // --- golden-value fixtures: exact peel orders -----------------------

    #[test]
    fn golden_isolated_straggler_3x3() {
        let p = grid(3, 3, &[(1, 1)]);
        let plan = plan_peel(3, 3, &p);
        assert!(plan.decodable());
        assert_eq!(
            plan.steps,
            vec![Recovery { cell: (1, 1), axis: Axis::Row, reads: 2 }]
        );
        assert_eq!(plan.total_reads, 2);
        assert_eq!(plan.distinct_reads, 2);
    }

    #[test]
    fn golden_row_pair_peels_col_then_row() {
        // (0,0) and (0,1) share row 0, so row 0 cannot peel first; the
        // planner peels (0,0) via its column, which unlocks row 0 for
        // (0,1). Costs tie at 2, and the first candidate found wins.
        let p = grid(3, 3, &[(0, 0), (0, 1)]);
        let plan = plan_peel(3, 3, &p);
        assert!(plan.decodable());
        assert_eq!(
            plan.steps,
            vec![
                Recovery { cell: (0, 0), axis: Axis::Col, reads: 2 },
                Recovery { cell: (0, 1), axis: Axis::Row, reads: 2 },
            ]
        );
        assert_eq!(plan.total_reads, 4);
        // Step 2 re-reads the just-recovered (0,0) from worker memory:
        // only (1,0), (2,0) and (0,2) are fetched from the store.
        assert_eq!(plan.distinct_reads, 3);
    }

    #[test]
    fn golden_whole_row_peels_by_columns_in_order() {
        // Entire row 1 of a 3×4 grid: every column has exactly one
        // missing cell and columns are cheaper (2 reads) than the row
        // alternative (3), so the plan is four column peels left→right.
        let missing: Vec<(usize, usize)> = (0..4).map(|c| (1, c)).collect();
        let p = grid(3, 4, &missing);
        let plan = plan_peel(3, 4, &p);
        assert!(plan.decodable());
        let want: Vec<Recovery> = (0..4)
            .map(|c| Recovery { cell: (1, c), axis: Axis::Col, reads: 2 })
            .collect();
        assert_eq!(plan.steps, want);
        assert_eq!(plan.total_reads, 8);
    }

    #[test]
    fn erasures_beyond_local_parities_report_failure() {
        // One parity per row and per column recovers no line with ≥ 2
        // erasures: two full rows (or the whole grid) must be reported
        // undecodable with an empty plan, not silently "recovered".
        let two_rows: Vec<(usize, usize)> =
            (0..2).flat_map(|r| (0..3).map(move |c| (r, c))).collect();
        let p = grid(3, 3, &two_rows);
        let plan = plan_peel(3, 3, &p);
        assert!(!plan.decodable());
        assert!(plan.steps.is_empty());
        assert_eq!(plan.recovered(), 0);
        assert_eq!(plan.total_reads, 0);
        assert_eq!(plan.undecodable.len(), 6);

        let all = grid(3, 3, &(0..3).flat_map(|r| (0..3).map(move |c| (r, c))).collect::<Vec<_>>());
        let plan = plan_peel(3, 3, &all);
        assert!(!plan.decodable());
        assert_eq!(plan.undecodable.len(), 9);
    }

    #[test]
    fn interlocking_three_decodable() {
        // Fig 8-style interlocking configuration in a 3×3 grid.
        let p = grid(3, 3, &[(0, 0), (0, 1), (1, 0)]);
        let plan = plan_peel(3, 3, &p);
        assert!(plan.decodable());
    }

    #[test]
    fn square_four_undecodable() {
        // Fig 7 middle: 4 stragglers in a 2×2 sub-square cannot be decoded.
        let p = grid(3, 3, &[(0, 0), (0, 2), (2, 0), (2, 2)]);
        let plan = plan_peel(3, 3, &p);
        assert!(!plan.decodable());
        assert_eq!(plan.undecodable.len(), 4);
    }

    #[test]
    fn partial_decode_before_stall() {
        // A 4-square plus one isolated straggler: the isolated one peels,
        // the square remains.
        let p = grid(4, 4, &[(0, 0), (0, 1), (1, 0), (1, 1), (3, 3)]);
        let plan = plan_peel(4, 4, &p);
        assert!(!plan.decodable());
        assert_eq!(plan.recovered(), 1);
        assert_eq!(plan.undecodable.len(), 4);
    }

    #[test]
    fn whole_row_missing_recoverable_by_columns() {
        // Entire row missing: each cell is the only one missing in its
        // column, so column peels recover everything.
        let missing: Vec<(usize, usize)> = (0..4).map(|c| (1, c)).collect();
        let p = grid(3, 4, &missing);
        let plan = plan_peel(3, 4, &p);
        assert!(plan.decodable());
        assert_eq!(plan.recovered(), 4);
        assert!(plan.steps.iter().all(|s| s.axis == Axis::Col));
    }

    #[test]
    fn reads_bounded_by_sl() {
        // Theorem 1 accounting: R ≤ S·L with L = max(L_A, L_B).
        proptest(300, 0x5EED, |g| {
            let rows = g.usize_in(2, 8);
            let cols = g.usize_in(2, 8);
            let n = rows * cols;
            let s = g.usize_in(0, n);
            let missing = g.subset(n, s);
            let mut p = vec![true; n];
            for &i in &missing {
                p[i] = false;
            }
            let plan = plan_peel(rows, cols, &p);
            let l = (rows - 1).max(cols - 1);
            assert!(
                plan.total_reads <= plan.recovered() * l,
                "reads {} > {} * {}",
                plan.total_reads,
                plan.recovered(),
                l
            );
            assert!(plan.distinct_reads <= plan.total_reads);
        });
    }

    #[test]
    fn peel_matches_bruteforce_oracle() {
        proptest(500, 0xACE, |g| {
            let rows = g.usize_in(2, 6);
            let cols = g.usize_in(2, 6);
            let n = rows * cols;
            let s = g.usize_in(0, n.min(10));
            let missing = g.subset(n, s);
            let mut p = vec![true; n];
            for &i in &missing {
                p[i] = false;
            }
            let plan = plan_peel(rows, cols, &p);
            assert_eq!(
                plan.decodable(),
                decodable_bruteforce(rows, cols, &p),
                "rows={rows} cols={cols} missing={missing:?}"
            );
        });
    }

    #[test]
    fn le_three_always_decodable_prop() {
        // Property: any ≤3 stragglers decode, for any grid ≥ 2×2.
        proptest(400, 0xD00D, |g| {
            let rows = g.usize_in(2, 9);
            let cols = g.usize_in(2, 9);
            let n = rows * cols;
            let s = g.usize_in(0, 3.min(n));
            let missing = g.subset(n, s);
            let mut p = vec![true; n];
            for &i in &missing {
                p[i] = false;
            }
            let plan = plan_peel(rows, cols, &p);
            assert!(plan.decodable(), "rows={rows} cols={cols} missing={missing:?}");
        });
    }

    #[test]
    fn wavefront_levels_respect_dependencies() {
        // Property: every step's constraint line reads only cells that are
        // original or recovered in a strictly earlier level, and the
        // flattened levels are a permutation of the plan steps.
        proptest(300, 0xFACADE, |g| {
            let rows = g.usize_in(2, 8);
            let cols = g.usize_in(2, 8);
            let n = rows * cols;
            let s = g.usize_in(0, n);
            let missing = g.subset(n, s);
            let mut p = vec![true; n];
            for &i in &missing {
                p[i] = false;
            }
            let plan = plan_peel(rows, cols, &p);
            let levels = wavefront_levels(&plan);
            let flat: Vec<usize> = levels.iter().flatten().copied().collect();
            let mut sorted = flat.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..plan.steps.len()).collect::<Vec<_>>());

            // Replay level by level: at each step every other cell of its
            // line must already be available.
            let mut have = p.clone();
            for level in &levels {
                // Check all of a level against the state BEFORE the level
                // executes (intra-level steps must not depend on each
                // other).
                for &si in level {
                    let (r, c) = plan.steps[si].cell;
                    match plan.steps[si].axis {
                        Axis::Row => {
                            for cc in 0..cols {
                                assert!(
                                    cc == c || have[r * cols + cc],
                                    "step {si} level-peer dependency at ({r},{cc})"
                                );
                            }
                        }
                        Axis::Col => {
                            for rr in 0..rows {
                                assert!(
                                    rr == r || have[rr * cols + c],
                                    "step {si} level-peer dependency at ({rr},{c})"
                                );
                            }
                        }
                    }
                }
                for &si in level {
                    let (r, c) = plan.steps[si].cell;
                    have[r * cols + c] = true;
                }
            }
        });
    }

    #[test]
    fn wavefront_level_shapes() {
        // Isolated stragglers are all level 0; a dependent chain spreads
        // across levels.
        let p = grid(3, 4, &(0..4).map(|c| (1, c)).collect::<Vec<_>>());
        let plan = plan_peel(3, 4, &p);
        let levels = wavefront_levels(&plan);
        assert_eq!(levels.len(), 1, "independent column peels are one wave");
        assert_eq!(levels[0].len(), 4);

        // (0,0) peels via its column first, then (0,1) via row 0 — the row
        // read includes the just-recovered (0,0), so it must wait a level.
        let p = grid(3, 3, &[(0, 0), (0, 1)]);
        let plan = plan_peel(3, 3, &p);
        let levels = wavefront_levels(&plan);
        assert_eq!(levels.len(), 2);
        assert_eq!(levels[0], vec![0]);
        assert_eq!(levels[1], vec![1]);

        // Empty plan ⇒ no levels.
        let p = grid(2, 2, &[]);
        assert!(wavefront_levels(&plan_peel(2, 2, &p)).is_empty());
    }

    #[test]
    fn individually_blocked_matches_definition() {
        let p = grid(3, 3, &[(0, 0), (0, 1), (1, 0)]);
        assert!(individually_blocked(3, 3, &p, (0, 0)));
        assert!(!individually_blocked(3, 3, &p, (0, 1)));
        assert!(!individually_blocked(3, 3, &p, (1, 0)));
    }

    #[test]
    fn recovered_cells_usable_for_later_peels() {
        // Chain: (0,0),(0,1),(1,0),(1,2),(2,1) — needs multiple rounds.
        let p = grid(3, 3, &[(0, 0), (0, 1), (1, 0), (1, 2), (2, 1)]);
        let plan = plan_peel(3, 3, &p);
        // Whether or not fully decodable, verify the plan is executable:
        // each step's constraint must have all other cells available at
        // execution time.
        let mut have = p.clone();
        for step in &plan.steps {
            let (r, c) = step.cell;
            match step.axis {
                Axis::Row => {
                    for cc in 0..3 {
                        if cc != c {
                            assert!(have[r * 3 + cc], "step {:?} needs ({r},{cc})", step);
                        }
                    }
                }
                Axis::Col => {
                    for rr in 0..3 {
                        if rr != r {
                            assert!(have[rr * 3 + c], "step {:?} needs ({rr},{c})", step);
                        }
                    }
                }
            }
            have[r * 3 + c] = true;
        }
    }
}

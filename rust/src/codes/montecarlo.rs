//! Monte-Carlo validation of Theorems 1 and 2: sample i.i.d. straggler
//! patterns on an `(L_A+1)×(L_B+1)` grid, run the actual peeling decoder,
//! and compare the empirical statistics against the closed-form bounds.
//! Regenerates the empirical overlays for Figs. 6 and 9.

use crate::codes::peeling::plan_peel;
use crate::util::rng::Pcg64;
use crate::util::threadpool::{num_threads, parallel_map};

/// Result of a Monte-Carlo study of one (L_A, L_B, p) design point.
#[derive(Debug, Clone)]
pub struct McResult {
    pub l_a: usize,
    pub l_b: usize,
    pub p: f64,
    pub trials: usize,
    /// Empirical Pr(grid not decodable by peeling alone).
    pub pr_undecodable: f64,
    /// Empirical distribution of R (total reads, Theorem-1 accounting):
    /// sorted sample.
    pub reads: Vec<usize>,
    /// Mean stragglers per grid observed.
    pub mean_stragglers: f64,
}

impl McResult {
    /// Empirical Pr(R ≥ x).
    pub fn pr_reads_ge(&self, x: usize) -> f64 {
        let cnt = self.reads.iter().filter(|&&r| r >= x).count();
        cnt as f64 / self.reads.len() as f64
    }

    /// Empirical mean of R.
    pub fn mean_reads(&self) -> f64 {
        self.reads.iter().sum::<usize>() as f64 / self.reads.len() as f64
    }
}

/// Run `trials` independent grids with per-block straggle probability `p`,
/// fanned out over the host pool (it is the dominant serial loop of
/// `bench_theory_bounds`). See [`simulate_with_threads`] for the
/// determinism contract.
pub fn simulate(l_a: usize, l_b: usize, p: f64, trials: usize, seed: u64) -> McResult {
    simulate_with_threads(l_a, l_b, p, trials, seed, num_threads())
}

/// [`simulate`] with an explicit thread count.
///
/// Every trial draws from its own RNG stream, forked from the root seed
/// in trial order *before* the fan-out, and per-trial outcomes are
/// collected in trial index order — so the result is bit-identical at
/// every `threads` value (pinned by the `thread_count_invariance` test)
/// and the aggregation is order-independent by construction.
pub fn simulate_with_threads(
    l_a: usize,
    l_b: usize,
    p: f64,
    trials: usize,
    seed: u64,
    threads: usize,
) -> McResult {
    let rows = l_a + 1;
    let cols = l_b + 1;
    let n = rows * cols;
    let mut root = Pcg64::new(seed);
    let streams: Vec<Pcg64> = (0..trials).map(|t| root.fork(t as u64)).collect();
    // (stragglers, undecodable, total_reads) per trial, in trial order.
    let outcomes: Vec<(usize, bool, usize)> = parallel_map(threads, trials, |t| {
        let mut rng = streams[t].clone();
        let mut present = vec![true; n];
        let mut s = 0usize;
        for cell in present.iter_mut() {
            let straggle = rng.bernoulli(p);
            *cell = !straggle;
            s += straggle as usize;
        }
        let plan = plan_peel(rows, cols, &present);
        (s, !plan.decodable(), plan.total_reads)
    });
    let straggler_total: usize = outcomes.iter().map(|o| o.0).sum();
    let undecodable = outcomes.iter().filter(|o| o.1).count();
    let mut reads: Vec<usize> = outcomes.iter().map(|o| o.2).collect();
    reads.sort_unstable();
    McResult {
        l_a,
        l_b,
        p,
        trials,
        pr_undecodable: undecodable as f64 / trials as f64,
        reads,
        mean_stragglers: straggler_total as f64 / trials as f64,
    }
}

/// Sweep L = L_A = L_B over a range (Fig 9's x-axis), returning
/// (L, empirical Pr(D̄), Theorem-2 bound) triples. L starts at the smallest
/// value satisfying Theorem 2's n ≥ 8 requirement.
pub fn sweep_l(p: f64, ls: &[usize], trials: usize, seed: u64) -> Vec<(usize, f64, f64)> {
    ls.iter()
        .map(|&l| {
            let mc = simulate(l, l, p, trials, seed.wrapping_add(l as u64));
            let bound = if (l + 1) * (l + 1) >= 8 {
                crate::codes::theory::thm2_bound(l, l, p)
            } else {
                f64::NAN
            };
            (l, mc.pr_undecodable, bound)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::theory;

    #[test]
    fn empirical_undecodability_below_thm2_bound() {
        // The bound must dominate the empirical rate (up to MC noise).
        for &(la, lb) in &[(3usize, 3usize), (5, 5), (10, 10)] {
            let p = 0.05; // higher p than the paper's 0.02 to get signal
            let mc = simulate(la, lb, p, 20_000, 42);
            let bound = theory::thm2_bound(la, lb, p);
            // Allow 3-sigma MC slack.
            let sigma = (bound * (1.0 - bound) / mc.trials as f64).sqrt();
            assert!(
                mc.pr_undecodable <= bound + 3.0 * sigma.max(1e-4),
                "L=({la},{lb}): empirical {} > bound {bound}",
                mc.pr_undecodable
            );
        }
    }

    #[test]
    fn empirical_reads_below_thm1_bound() {
        let (l, p) = (6usize, 0.05);
        let n = (l + 1) * (l + 1);
        let mc = simulate(l, l, p, 20_000, 7);
        for x in [10usize, 20, 30, 40] {
            let emp = mc.pr_reads_ge(x);
            let bound = theory::thm1_bound(x as f64, n, p, l);
            let sigma = (bound.max(1e-6) / mc.trials as f64).sqrt();
            assert!(
                emp <= bound + 5.0 * sigma.max(1e-4),
                "x={x}: empirical {emp} > bound {bound}"
            );
        }
    }

    #[test]
    fn mean_reads_close_to_npl_scale() {
        // E[R] ≤ npL with equality when every straggler costs exactly L.
        // Our decoder uses the cheaper axis when possible, so the mean
        // should be positive but below npL.
        let (l, p) = (10usize, 0.02);
        let n = (l + 1) * (l + 1);
        let mc = simulate(l, l, p, 30_000, 11);
        let npl = theory::expected_reads(n, p, l);
        let mean = mc.mean_reads();
        assert!(mean > 0.2 * npl, "mean reads {mean} vs npL {npl}");
        assert!(mean <= npl * 1.05, "mean reads {mean} vs npL {npl}");
    }

    #[test]
    fn mean_stragglers_matches_np() {
        let (l, p) = (9usize, 0.03);
        let n = (l + 1) * (l + 1);
        let mc = simulate(l, l, p, 30_000, 13);
        let expect = n as f64 * p;
        assert!(
            (mc.mean_stragglers - expect).abs() < 0.1 * expect,
            "{} vs {expect}",
            mc.mean_stragglers
        );
    }

    #[test]
    fn thread_count_invariance() {
        // Per-trial forked streams + index-ordered aggregation: the study
        // is bit-identical at every thread count.
        let serial = simulate_with_threads(5, 5, 0.05, 3_000, 99, 1);
        for threads in [2usize, 4, 8] {
            let par = simulate_with_threads(5, 5, 0.05, 3_000, 99, threads);
            assert_eq!(par.pr_undecodable, serial.pr_undecodable, "t={threads}");
            assert_eq!(par.reads, serial.reads, "t={threads}");
            assert_eq!(par.mean_stragglers, serial.mean_stragglers, "t={threads}");
        }
    }

    #[test]
    fn sweep_produces_bounds() {
        let rows = sweep_l(0.02, &[2, 5, 10], 2_000, 3);
        assert_eq!(rows.len(), 3);
        for (l, emp, bound) in rows {
            assert!(emp >= 0.0 && emp <= 1.0);
            assert!(bound.is_finite(), "L={l}");
        }
    }
}

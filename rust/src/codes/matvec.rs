//! Coded matrix-vector multiplication (§II-A), the primitive behind power
//! iteration and KRR-PCG.
//!
//! Follows the [17]-style construction the paper uses for its matvec
//! experiments: the row-blocks of A carry local parities (the same
//! [`LocalLayout`] as the matmul scheme's A side), so
//! `y_coded = A_coded · x` satisfies, per group, `y_parity = Σ y_i`.
//! Decoding over *vector* blocks is inexpensive — the reason §II-A notes
//! existing matvec schemes port directly to serverless.

use crate::codes::layout::LocalLayout;
use crate::linalg::matrix::Matrix;

/// Coded matvec scheme over `s` row-blocks with group size `l`.
#[derive(Debug, Clone, Copy)]
pub struct CodedMatvec {
    pub layout: LocalLayout,
}

/// Decode outcome for one matvec.
#[derive(Debug, Clone)]
pub struct MatvecDecode {
    /// Recovered systematic result blocks in original order.
    pub blocks: Vec<Vec<f32>>,
    /// Vector blocks read during recovery.
    pub blocks_read: usize,
    /// Stragglers recovered.
    pub recovered: usize,
}

impl CodedMatvec {
    pub fn new(s: usize, l: usize) -> CodedMatvec {
        CodedMatvec {
            layout: LocalLayout::new(s, l),
        }
    }

    /// Encode the row-blocks of A (done once; amortized over iterations).
    pub fn encode(&self, blocks: &[Matrix]) -> Vec<Matrix> {
        crate::codes::local_product::LocalProductCode::encode_side(self.layout, blocks)
    }

    /// Redundant computation fraction (1/L).
    pub fn redundancy(&self) -> f64 {
        self.layout.redundancy()
    }

    /// Decode coded result blocks (`None` = straggled worker). At most one
    /// straggler per group is recoverable; a second one in the same group
    /// makes that group undecodable (returns Err with the group index so
    /// the coordinator can recompute).
    pub fn decode(&self, coded: &[Option<Vec<f32>>]) -> Result<MatvecDecode, Vec<usize>> {
        assert_eq!(coded.len(), self.layout.coded_len());
        let mut out: Vec<Option<Vec<f32>>> = vec![None; self.layout.systematic];
        let mut blocks_read = 0usize;
        let mut recovered = 0usize;
        let mut stuck_groups = Vec::new();

        for g in 0..self.layout.groups() {
            let member_pos: Vec<usize> = self
                .layout
                .group_members(g)
                .map(|orig| self.layout.systematic_pos(orig))
                .collect();
            let parity_pos = self.layout.parity_pos(g);
            let missing_members: Vec<usize> = member_pos
                .iter()
                .enumerate()
                .filter(|(_, &pos)| coded[pos].is_none())
                .map(|(idx, _)| idx)
                .collect();

            match missing_members.len() {
                0 => {
                    // All systematic results arrived; parity unused.
                    for (idx, &pos) in member_pos.iter().enumerate() {
                        out[g * self.layout.l + idx] = coded[pos].clone();
                    }
                }
                1 if coded[parity_pos].is_some() => {
                    // Recover the missing block: y_miss = parity − Σ others.
                    let miss_idx = missing_members[0];
                    let mut rec = coded[parity_pos].clone().unwrap();
                    blocks_read += 1; // the parity block
                    for (idx, &pos) in member_pos.iter().enumerate() {
                        if idx == miss_idx {
                            continue;
                        }
                        let y = coded[pos].as_ref().unwrap();
                        blocks_read += 1;
                        for (r, &v) in rec.iter_mut().zip(y) {
                            *r -= v;
                        }
                    }
                    for (idx, &pos) in member_pos.iter().enumerate() {
                        out[g * self.layout.l + idx] = if idx == miss_idx {
                            Some(rec.clone())
                        } else {
                            coded[pos].clone()
                        };
                    }
                    recovered += 1;
                }
                _ => stuck_groups.push(g),
            }
        }

        if !stuck_groups.is_empty() {
            return Err(stuck_groups);
        }
        Ok(MatvecDecode {
            blocks: out.into_iter().map(Option::unwrap).collect(),
            blocks_read,
            recovered,
        })
    }

    /// Smallest number of arrived coded blocks that *guarantees*
    /// decodability in every group: all but one block per group.
    pub fn worst_case_threshold(&self) -> usize {
        self.layout.coded_len() - self.layout.groups()
    }
}

// ---------------------------------------------------------------------------
// 2-D product-coded matvec — the scheme the paper actually deploys for
// power iteration and KRR ("a 2D product code similar to [17]", §IV-A):
// the `s = grids·l²` systematic row-blocks are arranged into `grids`
// local (l+1)×(l+1) grids with one parity per row, per column, and a
// corner parity. Each grid tolerates ANY 3 stragglers via peeling (and
// most 4+ patterns), so a single slow group no longer stalls the
// iteration the way the 1-D scheme above does.
// ---------------------------------------------------------------------------

use crate::codes::peeling::{plan_peel, Axis, PeelPlan};

/// 2-D product-coded matvec layout.
#[derive(Debug, Clone, Copy)]
pub struct CodedMatvec2D {
    /// Side length of each systematic sub-grid.
    pub l: usize,
    /// Number of local grids.
    pub grids: usize,
}

impl CodedMatvec2D {
    /// `s` systematic blocks must equal `grids · l²`.
    pub fn new(s: usize, l: usize) -> anyhow::Result<CodedMatvec2D> {
        anyhow::ensure!(l > 0, "l must be positive");
        anyhow::ensure!(
            s % (l * l) == 0,
            "systematic blocks ({s}) must be a multiple of l² ({})",
            l * l
        );
        Ok(CodedMatvec2D { l, grids: s / (l * l) })
    }

    pub fn systematic(&self) -> usize {
        self.grids * self.l * self.l
    }

    /// Coded blocks: grids × (l+1)².
    pub fn coded_len(&self) -> usize {
        self.grids * (self.l + 1) * (self.l + 1)
    }

    /// Redundancy (21% for l = 10).
    pub fn redundancy(&self) -> f64 {
        self.coded_len() as f64 / self.systematic() as f64 - 1.0
    }

    /// Identify coded position `k` → (grid, r, c) in its (l+1)×(l+1) grid.
    pub fn cell(&self, k: usize) -> (usize, usize, usize) {
        let per = (self.l + 1) * (self.l + 1);
        let g = k / per;
        let w = k % per;
        (g, w / (self.l + 1), w % (self.l + 1))
    }

    /// Coded position of (grid, r, c).
    pub fn pos(&self, g: usize, r: usize, c: usize) -> usize {
        g * (self.l + 1) * (self.l + 1) + r * (self.l + 1) + c
    }

    /// Original systematic index of a systematic cell.
    pub fn orig(&self, g: usize, r: usize, c: usize) -> usize {
        debug_assert!(r < self.l && c < self.l);
        g * self.l * self.l + r * self.l + c
    }

    /// Encode the systematic row-blocks (any `Clone + AddAssign`-style
    /// payload via closures): returns coded blocks in coded order.
    pub fn encode(&self, blocks: &[Matrix], sum: impl Fn(&[&Matrix]) -> Matrix) -> Vec<Matrix> {
        assert_eq!(blocks.len(), self.systematic());
        let l = self.l;
        let mut out = Vec::with_capacity(self.coded_len());
        for g in 0..self.grids {
            // Row-major over the (l+1)×(l+1) grid.
            for r in 0..=l {
                for c in 0..=l {
                    let cellv = if r < l && c < l {
                        blocks[self.orig(g, r, c)].clone()
                    } else if r < l {
                        // Row parity: Σ_c blocks[g, r, ·]
                        let members: Vec<&Matrix> =
                            (0..l).map(|cc| &blocks[self.orig(g, r, cc)]).collect();
                        sum(&members)
                    } else if c < l {
                        // Column parity: Σ_r blocks[g, ·, c]
                        let members: Vec<&Matrix> =
                            (0..l).map(|rr| &blocks[self.orig(g, rr, c)]).collect();
                        sum(&members)
                    } else {
                        // Corner: Σ over the whole grid.
                        let members: Vec<&Matrix> = (0..l * l)
                            .map(|i| &blocks[g * l * l + i])
                            .collect();
                        sum(&members)
                    };
                    out.push(cellv);
                }
            }
        }
        out
    }

    /// Peel-decodability of grid `g` under an arrival mask over coded
    /// positions.
    pub fn grid_decodable(&self, g: usize, arrived: &[bool]) -> bool {
        let side = self.l + 1;
        let mut present = Vec::with_capacity(side * side);
        for r in 0..side {
            for c in 0..side {
                present.push(arrived[self.pos(g, r, c)]);
            }
        }
        plan_peel(side, side, &present).decodable()
    }

    /// Decode coded vector-block results (None = straggler). Returns the
    /// systematic result blocks plus total vector-blocks read; undecodable
    /// grid indices are returned as Err for the coordinator's recompute
    /// fallback.
    pub fn decode(
        &self,
        coded: &[Option<Vec<f32>>],
    ) -> Result<(Vec<Vec<f32>>, usize, Vec<PeelPlan>), Vec<usize>> {
        assert_eq!(coded.len(), self.coded_len());
        let side = self.l + 1;
        let mut cells: Vec<Option<Vec<f32>>> = coded.to_vec();
        let mut plans = Vec::with_capacity(self.grids);
        let mut stuck = Vec::new();
        for g in 0..self.grids {
            let present: Vec<bool> = (0..side * side)
                .map(|w| cells[g * side * side + w].is_some())
                .collect();
            let plan = plan_peel(side, side, &present);
            if !plan.decodable() {
                stuck.push(g);
            }
            // Execute the recoveries we can (vector arithmetic).
            for step in &plan.steps {
                let (r, c) = step.cell;
                let line: Vec<usize> = match step.axis {
                    Axis::Row => (0..side).map(|cc| self.pos(g, r, cc)).collect(),
                    Axis::Col => (0..side).map(|rr| self.pos(g, rr, c)).collect(),
                };
                let target = self.pos(g, r, c);
                let parity_idx = *line.last().unwrap();
                let value = if target == parity_idx {
                    let mut acc: Option<Vec<f32>> = None;
                    for &i in line.iter().take(line.len() - 1) {
                        let v = cells[i].as_ref().expect("plan order");
                        match &mut acc {
                            None => acc = Some(v.clone()),
                            Some(a) => {
                                for (x, y) in a.iter_mut().zip(v) {
                                    *x += y;
                                }
                            }
                        }
                    }
                    acc.unwrap()
                } else {
                    let mut acc = cells[parity_idx].as_ref().expect("plan order").clone();
                    for &i in line.iter().take(line.len() - 1) {
                        if i == target {
                            continue;
                        }
                        let v = cells[i].as_ref().expect("plan order");
                        for (x, y) in acc.iter_mut().zip(v) {
                            *x -= y;
                        }
                    }
                    acc
                };
                cells[target] = Some(value);
            }
            plans.push(plan);
        }
        if !stuck.is_empty() {
            return Err(stuck);
        }
        let total_reads = plans.iter().map(|p| p.total_reads).sum();
        let mut out = Vec::with_capacity(self.systematic());
        for g in 0..self.grids {
            for r in 0..self.l {
                for c in 0..self.l {
                    out.push(cells[self.pos(g, r, c)].clone().expect("decoded"));
                }
            }
        }
        Ok((out, total_reads, plans))
    }
}

// ---------------------------------------------------------------------------
// ComputePolicy impls — matvec compute phases through the generic driver
// ---------------------------------------------------------------------------

use crate::codes::scheme::{ComputePolicy, DecodeProbe};
use crate::platform::event::Termination;

/// Compute-phase policy of the 2-D product-coded matvec: earliest virtual
/// time every local grid is peeling-decodable, as an event-driven cutoff.
#[derive(Debug, Clone, Copy)]
pub struct Matvec2DPolicy {
    pub code: CodedMatvec2D,
}

impl ComputePolicy for Matvec2DPolicy {
    fn compute_tasks(&self) -> usize {
        self.code.coded_len()
    }

    fn compute_termination(&self) -> Termination {
        Termination::EarliestDecodable
    }

    fn decode_probe(&self) -> DecodeProbe {
        // Only the arriving block's grid can newly decode. A `None` hint
        // is a pure feasibility query — answer without mutating the
        // pending set.
        let code = self.code;
        let mut pending: std::collections::BTreeSet<usize> = (0..code.grids).collect();
        Box::new(move |mask: &[bool], newly: Option<usize>| match newly {
            Some(i) => {
                let (g, _, _) = code.cell(i);
                if pending.contains(&g) && code.grid_decodable(g, mask) {
                    pending.remove(&g);
                }
                pending.is_empty()
            }
            None => pending.iter().all(|&g| code.grid_decodable(g, mask)),
        })
    }

    fn partial_credit(&self) -> bool {
        true
    }
}

/// Compute-phase policy of the uncoded / speculative matvec baselines.
#[derive(Debug, Clone, Copy)]
pub struct PlainMatvecPolicy {
    pub tasks: usize,
    pub termination: Termination,
}

impl ComputePolicy for PlainMatvecPolicy {
    fn compute_tasks(&self) -> usize {
        self.tasks
    }

    fn compute_termination(&self) -> Termination {
        self.termination
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matvec;
    use crate::linalg::Partition;
    use crate::util::prop::proptest;
    use crate::util::rng::Pcg64;

    fn setup(s: usize, l: usize, rows: usize, cols: usize, seed: u64) -> (CodedMatvec, Matrix, Vec<f32>) {
        let mut rng = Pcg64::new(seed);
        let a = Matrix::randn(rows, cols, &mut rng, 0.0, 1.0);
        let x: Vec<f32> = (0..cols).map(|i| ((i * 7 + 3) as f32).sin()).collect();
        (CodedMatvec::new(s, l), a, x)
    }

    fn coded_results(cm: &CodedMatvec, a: &Matrix, x: &[f32], s: usize) -> Vec<Option<Vec<f32>>> {
        let p = Partition::new(a.rows, a.cols, s);
        let blocks = p.split(a);
        let coded = cm.encode(&blocks);
        coded.iter().map(|blk| Some(matvec(blk, x))).collect()
    }

    #[test]
    fn no_stragglers_roundtrip() {
        let (cm, a, x) = setup(6, 3, 24, 10, 1);
        let results = coded_results(&cm, &a, &x, 6);
        let dec = cm.decode(&results).unwrap();
        assert_eq!(dec.recovered, 0);
        assert_eq!(dec.blocks_read, 0);
        let y: Vec<f32> = dec.blocks.concat();
        let truth = matvec(&a, &x);
        for (a, b) in y.iter().zip(&truth) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn one_straggler_per_group_recovers() {
        let (cm, a, x) = setup(6, 3, 24, 10, 2);
        let mut results = coded_results(&cm, &a, &x, 6);
        // Kill one systematic block in group 0 and the parity of group 1.
        results[cm.layout.systematic_pos(1)] = None;
        results[cm.layout.parity_pos(1)] = None; // parity loss: nothing to recover
        let dec = cm.decode(&results).unwrap();
        assert_eq!(dec.recovered, 1);
        assert_eq!(dec.blocks_read, 3); // parity + 2 surviving members
        let y: Vec<f32> = dec.blocks.concat();
        let truth = matvec(&a, &x);
        for (a, b) in y.iter().zip(&truth) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn two_stragglers_same_group_stuck() {
        let (cm, a, x) = setup(4, 2, 16, 8, 3);
        let mut results = coded_results(&cm, &a, &x, 4);
        results[cm.layout.systematic_pos(0)] = None;
        results[cm.layout.systematic_pos(1)] = None;
        let err = cm.decode(&results).unwrap_err();
        assert_eq!(err, vec![0]);
    }

    #[test]
    fn threshold_guarantees_decode() {
        let cm = CodedMatvec::new(8, 4);
        assert_eq!(cm.worst_case_threshold(), 8);
        assert!((cm.redundancy() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn decode_property_random_single_losses() {
        proptest(60, 0xFEED, |g| {
            let l = g.usize_in(2, 5);
            let groups = g.usize_in(1, 4);
            let s = l * groups;
            let rows_per = g.usize_in(2, 4);
            let cols = g.usize_in(3, 8);
            let (cm, a, x) = setup(s, l, s * rows_per, cols, g.case as u64 + 50);
            let mut results = coded_results(&cm, &a, &x, s);
            // Drop at most one coded block per group.
            for grp in 0..groups {
                if g.bool() {
                    let within = g.usize_in(0, l); // l ⇒ parity
                    let pos = grp * (l + 1) + within;
                    results[pos] = None;
                }
            }
            let dec = cm.decode(&results).expect("≤1 loss per group decodes");
            let y: Vec<f32> = dec.blocks.concat();
            let truth = matvec(&a, &x);
            for (got, want) in y.iter().zip(&truth) {
                assert!((got - want).abs() < 1e-2, "{got} vs {want}");
            }
        });
    }
}

#[cfg(test)]
mod tests_2d {
    use super::*;
    use crate::linalg::gemm::matvec;
    use crate::linalg::matrix::Matrix;
    use crate::linalg::Partition;
    use crate::util::prop::proptest;
    use crate::util::rng::Pcg64;

    fn host_sum(blocks: &[&Matrix]) -> Matrix {
        let mut acc = blocks[0].clone();
        for b in &blocks[1..] {
            acc.add_assign(b);
        }
        acc
    }

    fn setup(s: usize, l: usize, rows: usize, cols: usize, seed: u64) -> (CodedMatvec2D, Matrix, Vec<f32>) {
        let mut rng = Pcg64::new(seed);
        let a = Matrix::randn(rows, cols, &mut rng, 0.0, 1.0);
        let x: Vec<f32> = (0..cols).map(|i| ((i * 3 + 1) as f32).cos()).collect();
        (CodedMatvec2D::new(s, l).unwrap(), a, x)
    }

    fn coded_results(code: &CodedMatvec2D, a: &Matrix, x: &[f32]) -> Vec<Option<Vec<f32>>> {
        let p = Partition::new(a.rows, a.cols, code.systematic());
        let blocks = p.split(a);
        let coded = code.encode(&blocks, host_sum);
        coded.iter().map(|blk| Some(matvec(blk, x))).collect()
    }

    #[test]
    fn layout_counts() {
        let code = CodedMatvec2D::new(500, 10).unwrap();
        assert_eq!(code.grids, 5);
        assert_eq!(code.coded_len(), 5 * 121);
        assert!((code.redundancy() - 0.21).abs() < 1e-12);
        assert!(CodedMatvec2D::new(500, 7).is_err());
    }

    #[test]
    fn no_stragglers_roundtrip() {
        let (code, a, x) = setup(8, 2, 32, 10, 1);
        let results = coded_results(&code, &a, &x);
        let (blocks, reads, _) = code.decode(&results).unwrap();
        assert_eq!(reads, 0);
        let y: Vec<f32> = blocks.concat();
        let truth = matvec(&a, &x);
        for (g, w) in y.iter().zip(&truth) {
            assert!((g - w).abs() < 1e-3);
        }
    }

    #[test]
    fn any_three_stragglers_per_grid_recover() {
        let (code, a, x) = setup(8, 2, 32, 10, 2);
        let truth = matvec(&a, &x);
        proptest(100, 0x2D, |g| {
            let mut results = coded_results(&code, &a, &x);
            for grid in 0..code.grids {
                let n_kills = g.usize_in(0, 3);
                let kills = g.subset(9, n_kills);
                for w in kills {
                    let (r, c) = (w / 3, w % 3);
                    results[code.pos(grid, r, c)] = None;
                }
            }
            let (blocks, _, _) = code.decode(&results).expect("≤3 per grid decodes");
            let y: Vec<f32> = blocks.concat();
            for (got, want) in y.iter().zip(&truth) {
                assert!((got - want).abs() < 1e-2);
            }
        });
    }

    #[test]
    fn square_pattern_reports_stuck_grid() {
        let (code, a, x) = setup(8, 2, 32, 10, 3);
        let mut results = coded_results(&code, &a, &x);
        // 4-square in grid 1.
        for &(r, c) in &[(0usize, 0usize), (0, 1), (1, 0), (1, 1)] {
            results[code.pos(1, r, c)] = None;
        }
        let err = code.decode(&results).unwrap_err();
        assert_eq!(err, vec![1]);
    }

    #[test]
    fn parity_structure_is_product_code() {
        // Row/col/corner parities satisfy the product-code constraints.
        let (code, a, x) = setup(4, 2, 16, 6, 4);
        let _ = x;
        let p = Partition::new(16, 6, 4);
        let blocks = p.split(&a);
        let coded = code.encode(&blocks, host_sum);
        let l = 2;
        // Row parity of row 0 = b(0,0)+b(0,1).
        let want = blocks[0].add(&blocks[1]);
        assert!(coded[code.pos(0, 0, l)].rel_err(&want) < 1e-6);
        // Corner = sum of all four.
        let corner = blocks[0].add(&blocks[1]).add(&blocks[2]).add(&blocks[3]);
        assert!(coded[code.pos(0, l, l)].rel_err(&corner) < 1e-6);
        // Corner also equals sum of row parities (consistency).
        let via_rows = coded[code.pos(0, 0, l)].add(&coded[code.pos(0, 1, l)]);
        assert!(coded[code.pos(0, l, l)].rel_err(&via_rows) < 1e-6);
    }
}

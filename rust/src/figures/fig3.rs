//! Fig 3: power iteration on a 0.5M-dim matrix, 500 workers, 20
//! iterations — coded ≈200 s/iter (low variance) vs speculative 340–470 s;
//! ≈2× end-to-end speedup.

use crate::codes::Scheme;
use crate::config::Config;
use crate::coordinator::matvec::MatvecEngine;
use crate::figures::{banner, savings_pct, RunScale};
use crate::linalg::matrix::vecops;
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg64;
use crate::util::stats::{render_table, Summary};

pub fn run(cfg: &Config, scale: RunScale) -> anyhow::Result<Json> {
    banner(
        "Fig 3",
        "power iteration, 0.5M dim, 500 workers, 20 iters (paper: coded ~200s/iter, spec 340–470s, 2× total)",
    );
    // Calibration override: the 0.5M row-block objects are read in a
    // single S3 stream; measured Lambda→S3 single-stream GET throughput
    // is ~10 MB/s at these object sizes (vs the multi-part ~100 MB/s used
    // elsewhere). Documented in EXPERIMENTS.md §fig3.
    let mut fig_cfg = cfg.clone();
    fig_cfg.set("platform.s3_bandwidth_bps", "10e6")?;
    let (env, _rt) = fig_cfg.build_env()?;

    let iters = scale.pick(8, 20);
    let s_workers = 500; // paper's worker count
    let numeric_n = scale.pick(1000, 2000); // lab-scale numerics
    let virtual_n = 500_000; // paper-scale virtual dims
    let mut rng = Pcg64::new(cfg.seed);
    let a = crate::apps::power_iteration::planted_matrix(numeric_n, 100.0, &mut rng);

    let mut run_scheme = |scheme: Scheme, seed: u64| -> anyhow::Result<(Vec<f64>, f64, f64)> {
        let mut rng = Pcg64::new(seed);
        let engine = MatvecEngine::with_virtual_dims(
            &env,
            &a,
            s_workers,
            scheme,
            Some((virtual_n, virtual_n)),
            &mut rng,
        )?;
        let mut x: Vec<f32> = (0..numeric_n).map(|i| ((i + 1) as f32).sin()).collect();
        let norm = vecops::norm2(&x) as f32;
        vecops::scale(&mut x, 1.0 / norm);
        let mut times = Vec::with_capacity(iters);
        let mut lambda = 0.0;
        for _ in 0..iters {
            let (y, rep) = engine.multiply(&env, &x, &mut rng)?;
            lambda = vecops::dot(&x, &y);
            let ynorm = vecops::norm2(&y) as f32;
            x = y;
            vecops::scale(&mut x, 1.0 / ynorm);
            times.push(rep.total_secs());
        }
        Ok((times, engine.encode_report.virtual_secs, lambda))
    };

    let (coded_times, coded_enc, lambda_c) =
        run_scheme(Scheme::LocalProduct { l_a: 10, l_b: 10 }, cfg.seed + 1)?;
    let (spec_times, _, lambda_s) =
        run_scheme(Scheme::Speculative { wait_frac: 0.90 }, cfg.seed + 2)?;

    let coded_total = coded_enc + coded_times.iter().sum::<f64>();
    let spec_total: f64 = spec_times.iter().sum();
    let cs = Summary::of(&coded_times);
    let ss = Summary::of(&spec_times);

    let mut rows = Vec::new();
    for i in 0..iters {
        rows.push(vec![
            format!("{}", i + 1),
            format!("{:.1}", coded_times[i]),
            format!("{:.1}", spec_times[i]),
        ]);
    }
    println!(
        "{}",
        render_table(&["iter", "coded (s)", "speculative (s)"], &rows)
    );
    println!(
        "coded: {:.1}s/iter (std {:.1})  spec: {:.1}s/iter (range {:.0}–{:.0})",
        cs.mean, cs.std, ss.mean, ss.min, ss.max
    );
    println!(
        "total: coded {:.0}s (incl. encode {:.0}s) vs spec {:.0}s → {:.1}% savings (paper: ~2× ⇒ 50%)",
        coded_total,
        coded_enc,
        spec_total,
        savings_pct(coded_total, spec_total)
    );
    // Eigenvalue agreement = universality check.
    anyhow::ensure!(
        ((lambda_c - lambda_s) / lambda_s).abs() < 1e-3,
        "schemes disagree numerically: {lambda_c} vs {lambda_s}"
    );

    Ok(obj()
        .field("figure", "fig3")
        .field("iters", iters)
        .field("workers", s_workers)
        .field("virtual_dim", virtual_n)
        .field("coded_per_iter", Json::Arr(coded_times.iter().map(|&t| t.into()).collect()))
        .field("spec_per_iter", Json::Arr(spec_times.iter().map(|&t| t.into()).collect()))
        .field("coded_encode_s", coded_enc)
        .field("coded_total_s", coded_total)
        .field("spec_total_s", spec_total)
        .field("savings_pct", savings_pct(coded_total, spec_total))
        .field("coded_iter_summary", cs.to_json())
        .field("spec_iter_summary", ss.to_json())
        .field("eigenvalue", lambda_c)
        .build())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_coded_beats_speculative() {
        let cfg = Config {
            results_dir: std::env::temp_dir().join("slec-test-results"),
            ..Default::default()
        };
        let j = run(&cfg, RunScale::Quick).unwrap();
        // Fig 3a's claim is per-iteration: coded ≈200s vs spec 340–470s.
        let cs = j.get_path("coded_iter_summary.mean").unwrap().as_f64().unwrap();
        let ss = j.get_path("spec_iter_summary.mean").unwrap().as_f64().unwrap();
        assert!(ss / cs > 1.4, "per-iter speedup {:.2} (want ≳2×)", ss / cs);
        // Reliability: coded iteration times are much steadier.
        let cstd = j.get_path("coded_iter_summary.std").unwrap().as_f64().unwrap();
        let sstd = j.get_path("spec_iter_summary.std").unwrap().as_f64().unwrap();
        assert!(cstd < sstd, "coded std {cstd} vs spec std {sstd}");
        // Totals including the one-time encode still favor coded.
        let coded = j.get("coded_total_s").unwrap().as_f64().unwrap();
        let spec = j.get("spec_total_s").unwrap().as_f64().unwrap();
        assert!(coded < spec, "coded {coded} should beat spec {spec}");
    }
}

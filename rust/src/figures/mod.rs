//! Figure/table reproduction harness: one module per paper artifact.
//!
//! Every module regenerates its figure's series: it prints a paper-style
//! table (and ASCII plot where useful), writes machine-readable JSON to
//! `results/`, and returns the JSON for tests. Figures simulate at the
//! PAPER's scale via virtual dims (DESIGN.md §Virtual-time model) while
//! the verified numerics run at lab scale; per-figure calibration
//! overrides are documented inline and in EXPERIMENTS.md.

pub mod fig1;
pub mod fig3;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig9;
pub mod fig10_11;
pub mod fig12;
pub mod svd_table;

use crate::config::Config;
use crate::util::json::Json;

/// Scale of a figure run: `quick` for CI-speed, `full` for paper-scale
/// statistics (more trials / bigger numerics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunScale {
    Quick,
    Full,
}

impl RunScale {
    pub fn pick<T>(self, quick: T, full: T) -> T {
        match self {
            RunScale::Quick => quick,
            RunScale::Full => full,
        }
    }
}

/// All figure ids, in paper order.
pub const ALL: [&str; 9] = [
    "fig1", "fig3", "fig5", "fig6", "fig7", "fig9", "fig10", "fig11", "svd",
];

/// Run one figure by id; returns its JSON result document.
pub fn run(id: &str, cfg: &Config, scale: RunScale) -> anyhow::Result<Json> {
    let result = match id {
        "fig1" => fig1::run(cfg, scale)?,
        "fig3" => fig3::run(cfg, scale)?,
        "fig5" => fig5::run(cfg, scale)?,
        "fig6" => fig6::run(cfg, scale)?,
        "fig7" | "fig8" => fig7::run(cfg, scale)?,
        "fig9" => fig9::run(cfg, scale)?,
        "fig10" => fig10_11::run(cfg, scale, fig10_11::Dataset::AdultLike)?,
        "fig11" => fig10_11::run(cfg, scale, fig10_11::Dataset::EpsilonLike)?,
        "fig12" => fig12::run(cfg, scale)?,
        "svd" => svd_table::run(cfg, scale)?,
        other => anyhow::bail!("unknown figure '{other}' (available: {ALL:?}, fig12)"),
    };
    let path = cfg.write_result(id, &result)?;
    println!("[results] wrote {}", path.display());
    Ok(result)
}

/// Header printed by each figure.
pub fn banner(id: &str, claim: &str) {
    println!("\n{}", "=".repeat(78));
    println!("{id} — {claim}");
    println!("{}", "=".repeat(78));
}

/// Savings of `coded` relative to `baseline` in percent.
pub fn savings_pct(coded: f64, baseline: f64) -> f64 {
    (1.0 - coded / baseline) * 100.0
}

#[cfg(test)]
mod tests {
    #[test]
    fn savings_math() {
        assert!((super::savings_pct(75.0, 100.0) - 25.0).abs() < 1e-12);
        assert!(super::savings_pct(120.0, 100.0) < 0.0);
    }

    #[test]
    fn scale_pick() {
        use super::RunScale;
        assert_eq!(RunScale::Quick.pick(1, 2), 1);
        assert_eq!(RunScale::Full.pick(1, 2), 2);
    }
}

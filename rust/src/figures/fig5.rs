//! Fig 5: coded matmul scheme comparison vs matrix dimension.
//!
//! Paper setup: A = B square, L_A = L_B = 10 (21% redundancy); product
//! and polynomial codes at matched ≥21% redundancy; speculative execution
//! waits for 79% then recomputes. Expected shape: local product code wins
//! by ≥25% over speculative at large dims; product/polynomial codes do
//! WORSE than speculative (decode read overhead); polynomial decoding is
//! infeasible at large dims.

use crate::codes::Scheme;
use crate::config::Config;
use crate::coordinator::matmul::{run_matmul, MatmulJob};
use crate::coordinator::metrics::REPORT_HEADERS;
use crate::figures::{banner, savings_pct, RunScale};

use crate::linalg::matrix::Matrix;
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg64;
use crate::util::stats::render_table;

/// One design point: virtual (paper) dim ↔ numeric (lab) dims.
struct Point {
    virtual_dim: usize,
    numeric_rows: usize,
    numeric_k: usize,
}

pub fn run(cfg: &Config, scale: RunScale) -> anyhow::Result<Json> {
    banner(
        "Fig 5",
        "matmul schemes vs dim (paper: local-product ≥25% over spec-exec; product/poly worse; poly infeasible at scale)",
    );
    let (env, _rt) = cfg.build_env()?;
    let points = match scale {
        // Numeric dims match the AOT artifact shapes so the PJRT backend
        // exercises the compiled kernels.
        RunScale::Quick => vec![
            Point { virtual_dim: 10_000, numeric_rows: 1280, numeric_k: 256 },
            Point { virtual_dim: 20_000, numeric_rows: 1280, numeric_k: 256 },
        ],
        RunScale::Full => vec![
            Point { virtual_dim: 10_000, numeric_rows: 1280, numeric_k: 256 },
            Point { virtual_dim: 20_000, numeric_rows: 2560, numeric_k: 512 },
            Point { virtual_dim: 30_000, numeric_rows: 2560, numeric_k: 512 },
        ],
    };
    let trials = scale.pick(3, 5);
    // 20 systematic row-blocks per side: the local scheme forms 2×2 local
    // grids of (10+1)² (locality 10, paper's L_A=L_B=10), while the
    // product-code baseline at the SAME ~21% redundancy must lay its
    // parities globally (22×22 grid, locality 20 — its Fig-5 handicap).
    let s = 20;

    // The four contenders, resolved through the scheme registry (one
    // table shared with the CLI and scenario JSON).
    let schemes: Vec<(&'static str, Scheme)> =
        ["local-product:10x10", "speculative:0.79", "product:2x2", "polynomial:0.21"]
            .iter()
            .map(|spec| {
                let scheme = Scheme::parse(spec)?;
                Ok((scheme.name(), scheme))
            })
            .collect::<anyhow::Result<_>>()?;

    let mut dims_out = Vec::new();
    for point in &points {
        let mut rng = Pcg64::new(cfg.seed ^ point.virtual_dim as u64);
        let a = Matrix::randn(point.numeric_rows, point.numeric_k, &mut rng, 0.0, 1.0);
        let b = Matrix::randn(point.numeric_rows, point.numeric_k, &mut rng, 0.0, 1.0);
        println!(
            "\n-- dim {} (numeric {}×{}) --",
            point.virtual_dim, point.numeric_rows, point.numeric_k
        );
        let mut rows = Vec::new();
        let mut scheme_json = Vec::new();
        let mut totals = std::collections::BTreeMap::new();
        for (name, scheme) in &schemes {
            let mut total = 0.0;
            let mut last = None;
            let mut rel_err = f64::NAN;
            for t in 0..trials {
                let job = MatmulJob {
                    s_a: s,
                    s_b: s,
                    scheme: *scheme,
                    decode_workers: 5,
                    verify: t == 0, // verify once per point
                    seed: cfg.seed + t as u64 * 101 + point.virtual_dim as u64,
                    job_id: format!("fig5-{name}-{}-{t}", point.virtual_dim),
                    virtual_dims: Some((point.virtual_dim, point.virtual_dim, point.virtual_dim)),
                    encode_workers: 0,
                };
                let (_, report) = run_matmul(&env, &a, &b, &job)?;
                total += report.total_secs();
                if t == 0 {
                    rel_err = report.rel_err;
                }
                last = Some(report);
            }
            let mut report = last.unwrap();
            report.rel_err = rel_err;
            let mean = total / trials as f64;
            totals.insert(name.to_string(), mean);
            let mut row = report.row();
            row[4] = format!("{mean:.1}");
            if !report.numerics_ok {
                row[5] = "infeasible".into();
            }
            rows.push(row);
            scheme_json.push(
                obj()
                    .field("scheme", *name)
                    .field("mean_total_s", mean)
                    .field("t_enc", report.enc.virtual_secs)
                    .field("t_comp", report.comp.virtual_secs)
                    .field("t_dec", report.dec.virtual_secs)
                    .field("dec_blocks_read", report.dec.blocks_read)
                    .field("redundancy", report.redundancy)
                    .field("rel_err", report.rel_err)
                    .field("numerics_ok", report.numerics_ok)
                    .build(),
            );
        }
        println!("{}", render_table(&REPORT_HEADERS, &rows));
        let lp = totals["local-product"];
        let sp = totals["speculative"];
        println!(
            "local-product vs speculative: {:.1}% savings (paper ≥25%); product {}, polynomial {} vs spec",
            savings_pct(lp, sp),
            if totals["product"] > sp { "worse ✓" } else { "better ✗" },
            if totals["polynomial"] > sp { "worse ✓" } else { "better ✗" },
        );
        dims_out.push(
            obj()
                .field("virtual_dim", point.virtual_dim)
                .field("numeric_rows", point.numeric_rows)
                .field("numeric_k", point.numeric_k)
                .field("savings_vs_spec_pct", savings_pct(lp, sp))
                .field("schemes", Json::Arr(scheme_json))
                .build(),
        );
    }

    Ok(obj()
        .field("figure", "fig5")
        .field("trials", trials)
        .field("points", Json::Arr(dims_out))
        .build())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_shape_holds() {
        let cfg = Config {
            results_dir: std::env::temp_dir().join("slec-test-results"),
            ..Default::default()
        };
        let j = run(&cfg, RunScale::Quick).unwrap();
        let points = j.get("points").unwrap().as_arr().unwrap();
        // At the largest dim: local-product beats speculative, and the
        // MDS baselines lose to speculative (the paper's crossover).
        let last = points.last().unwrap();
        let schemes = last.get("schemes").unwrap().as_arr().unwrap();
        let total = |name: &str| -> f64 {
            schemes
                .iter()
                .find(|s| s.get("scheme").unwrap().as_str() == Some(name))
                .unwrap()
                .get("mean_total_s")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        assert!(total("local-product") < total("speculative"));
        assert!(total("product") > total("speculative"));
        assert!(total("polynomial") > total("speculative"));
        // Local product decode reads ≪ product decode reads.
        let reads = |name: &str| -> f64 {
            schemes
                .iter()
                .find(|s| s.get("scheme").unwrap().as_str() == Some(name))
                .unwrap()
                .get("dec_blocks_read")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        // Polynomial always reads K per decode worker.
        assert!(reads("polynomial") >= 400.0);
    }
}

//! Fig 6: Theorem 1's bound on decode reads, Pr(R ≥ x) for L = 10,
//! n = 121, p = 0.02 — plus our Monte-Carlo ground truth and the
//! corrected bound (the printed theorem has a sign typo; see
//! `codes::theory::thm1_bound_paper`).

use crate::codes::{montecarlo, theory};
use crate::config::Config;
use crate::figures::{banner, RunScale};
use crate::util::json::{obj, Json};
use crate::util::stats::render_table;

pub fn run(cfg: &Config, scale: RunScale) -> anyhow::Result<Json> {
    banner(
        "Fig 6",
        "Pr(R ≥ x) bounds, L=10, n=121, p=0.02 (paper caption: Pr(R≥2E[R]) ≤ 3.1e−3)",
    );
    let (l, p) = (10usize, 0.02);
    let n = (l + 1) * (l + 1);
    let er = theory::expected_reads(n, p, l);
    let trials = scale.pick(50_000, 400_000);
    let mc = montecarlo::simulate(l, l, p, trials, cfg.seed);

    let xs: Vec<f64> = (1..=12).map(|i| i as f64 * 10.0).collect();
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for &x in &xs {
        let paper = theory::thm1_bound_paper(x, n, p, l);
        let corrected = theory::thm1_bound(x, n, p, l);
        let emp = mc.pr_reads_ge(x as usize);
        rows.push(vec![
            format!("{x:.0}"),
            format!("{paper:.3e}"),
            format!("{corrected:.3e}"),
            format!("{emp:.3e}"),
        ]);
        out.push(
            obj()
                .field("x", x)
                .field("paper_bound", paper)
                .field("corrected_bound", corrected)
                .field("empirical", emp)
                .build(),
        );
    }
    println!(
        "{}",
        render_table(
            &["x (blocks)", "paper bound", "corrected bound", "MC empirical"],
            &rows
        )
    );
    println!("E[R] = npL = {er:.1}; MC mean R = {:.1}", mc.mean_reads());
    println!(
        "paper Pr(R≥2E[R]) = {:.2e} (caption: 3.1e−3); MC truth = {:.2e} → printed bound is NOT an upper bound (sign typo, see theory.rs)",
        theory::thm1_bound_paper(2.0 * er, n, p, l),
        mc.pr_reads_ge((2.0 * er) as usize)
    );

    Ok(obj()
        .field("figure", "fig6")
        .field("l", l)
        .field("n", n)
        .field("p", p)
        .field("expected_reads", er)
        .field("mc_trials", trials)
        .field("mc_mean_reads", mc.mean_reads())
        .field("series", Json::Arr(out))
        .build())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_corrected_bound_dominates_mc() {
        let cfg = Config {
            results_dir: std::env::temp_dir().join("slec-test-results"),
            ..Default::default()
        };
        let j = run(&cfg, RunScale::Quick).unwrap();
        for point in j.get("series").unwrap().as_arr().unwrap() {
            let emp = point.get("empirical").unwrap().as_f64().unwrap();
            let corr = point.get("corrected_bound").unwrap().as_f64().unwrap();
            assert!(
                emp <= corr + 5e-3,
                "x={:?}: empirical {emp} > corrected {corr}",
                point.get("x")
            );
        }
    }
}

//! Fig 1: job-completion-time distribution for distributed matmul over
//! 3600 Lambda workers — median ≈ 135 s, ~2% stragglers far in the tail.

use crate::config::Config;
use crate::figures::{banner, RunScale};
use crate::platform::WorkProfile;
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg64;
use crate::util::stats::{Histogram, Summary};

/// The Fig-1 workload: a worker's block product sized so the median job
/// lands at the paper's ≈135 s under the default calibration.
pub fn fig1_profile() -> WorkProfile {
    WorkProfile::block_product(2048, 16384, 2048)
}

pub fn run(cfg: &Config, scale: RunScale) -> anyhow::Result<Json> {
    banner(
        "Fig 1",
        "job time distribution, 3600 workers × 10 trials (paper: median ≈135 s, ~2% stragglers)",
    );
    let model = cfg.model();
    let trials = scale.pick(3, 10);
    let workers = 3600;
    let mut rng = Pcg64::new(cfg.seed);
    let mut all = Vec::with_capacity(trials * workers);
    for _ in 0..trials {
        all.extend(model.sample_fleet(&fig1_profile(), workers, &mut rng));
    }
    let s = Summary::of(&all);
    let tail2x = all.iter().filter(|&&t| t >= 2.0 * s.p50).count() as f64 / all.len() as f64;

    let mut hist = Histogram::new(0.0, 4.0 * s.p50, 40);
    hist.add_all(&all);
    println!("{}", hist.render(48));
    println!("summary: {}", s.line());
    println!(
        "stragglers ≥2×median: {:.2}% (paper: ~2%) | median {:.1}s (paper ≈135s)",
        tail2x * 100.0,
        s.p50
    );

    Ok(obj()
        .field("figure", "fig1")
        .field("workers", workers)
        .field("trials", trials)
        .field("median_s", s.p50)
        .field("paper_median_s", 135.0)
        .field("straggler_frac_2x", tail2x)
        .field("paper_straggler_frac", 0.02)
        .field("summary", s.to_json())
        .field("histogram", hist.to_json())
        .build())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_matches_paper_shape() {
        let cfg = Config {
            results_dir: std::env::temp_dir().join("slec-test-results"),
            ..Default::default()
        };
        let j = run(&cfg, RunScale::Quick).unwrap();
        let median = j.get("median_s").unwrap().as_f64().unwrap();
        let tail = j.get("straggler_frac_2x").unwrap().as_f64().unwrap();
        assert!((median - 135.0).abs() < 20.0, "median {median}");
        assert!(tail > 0.005 && tail < 0.04, "tail {tail}");
    }
}

//! §IV-C table: tall-skinny SVD of a 300k×30k matrix, 400 workers, 21%
//! redundancy — paper: coded 270.9 s vs speculative 368.75 s (26.5%
//! reduction), averaged over 5 trials.

use crate::apps::svd::{reconstruction_error, tall_skinny_svd, SvdConfig};
use crate::codes::Scheme;
use crate::config::Config;
use crate::figures::{banner, savings_pct, RunScale};
use crate::linalg::matrix::Matrix;
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg64;
use crate::util::stats::{render_table, Summary};

pub fn run(cfg: &Config, scale: RunScale) -> anyhow::Result<Json> {
    banner(
        "SVD (§IV-C)",
        "tall-skinny SVD 300k×30k, 400 workers, 21% redundancy (paper: 270.9s coded vs 368.75s spec, 26.5%)",
    );
    // Same BLAS-3 calibration as Fig 12 (dense block products).
    let mut fig_cfg = cfg.clone();
    fig_cfg.set("platform.flops_per_s", "6e9")?;
    let (env, _rt) = fig_cfg.build_env()?;

    let virtual_dims = (300_000, 30_000);
    let s_blocks = 20; // 20×20 = 400 computation workers
    let (numeric_m, numeric_p) = scale.pick((600, 60), (1200, 120));
    let trials = scale.pick(2, 5);
    let mut rng = Pcg64::new(cfg.seed);
    let a = Matrix::randn(numeric_m, numeric_p, &mut rng, 0.0, 1.0);

    let mut run_scheme = |scheme: Scheme, seed_base: u64| -> anyhow::Result<(Vec<f64>, f64)> {
        let mut times = Vec::new();
        let mut err = 0.0;
        for t in 0..trials {
            let mut rng = Pcg64::new(seed_base + t as u64);
            let res = tall_skinny_svd(
                &env,
                &a,
                &SvdConfig {
                    s_blocks,
                    scheme,
                    virtual_dims: Some(virtual_dims),
                    ..Default::default()
                },
                &mut rng,
            )?;
            times.push(res.total_secs());
            if t == 0 {
                err = reconstruction_error(&a, &res);
            }
        }
        Ok((times, err))
    };

    let (coded_times, coded_err) =
        run_scheme(Scheme::LocalProduct { l_a: 10, l_b: 10 }, cfg.seed + 1)?;
    let (spec_times, spec_err) =
        run_scheme(Scheme::Speculative { wait_frac: 0.79 }, cfg.seed + 100)?;
    let cs = Summary::of(&coded_times);
    let ss = Summary::of(&spec_times);
    let savings = savings_pct(cs.mean, ss.mean);

    println!(
        "{}",
        render_table(
            &["scheme", "mean total (s)", "paper (s)", "recon err"],
            &[
                vec![
                    "local-product".into(),
                    format!("{:.1}", cs.mean),
                    "270.9".into(),
                    format!("{coded_err:.2e}"),
                ],
                vec![
                    "speculative".into(),
                    format!("{:.1}", ss.mean),
                    "368.75".into(),
                    format!("{spec_err:.2e}"),
                ],
            ],
        )
    );
    println!("reduction: {savings:.1}% (paper: 26.5%), {trials} trials");
    anyhow::ensure!(coded_err < 1e-2, "SVD reconstruction error {coded_err}");

    Ok(obj()
        .field("figure", "svd")
        .field("virtual_dims", Json::Arr(vec![300_000usize.into(), 30_000usize.into()]))
        .field("workers", s_blocks * s_blocks)
        .field("trials", trials)
        .field("coded_mean_s", cs.mean)
        .field("spec_mean_s", ss.mean)
        .field("paper_coded_s", 270.9)
        .field("paper_spec_s", 368.75)
        .field("savings_pct", savings)
        .field("paper_savings_pct", 26.5)
        .field("reconstruction_error", coded_err)
        .build())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn svd_table_reduction_matches_shape() {
        let cfg = Config {
            results_dir: std::env::temp_dir().join("slec-test-results"),
            ..Default::default()
        };
        let j = run(&cfg, RunScale::Quick).unwrap();
        let savings = j.get("savings_pct").unwrap().as_f64().unwrap();
        assert!(savings > 5.0, "savings {savings}%");
        let err = j.get("reconstruction_error").unwrap().as_f64().unwrap();
        assert!(err < 1e-2);
    }
}

//! Fig 12: ALS matrix completion, coded vs speculative — paper:
//! u = i = 102400, f = 20480, 500 compute workers, 5 decode workers,
//! 7 iterations, ≈150 s/iter coded with low variance, 20% total savings.

use crate::apps::als::{als, synthetic_ratings, AlsConfig};
use crate::codes::Scheme;
use crate::config::Config;
use crate::figures::{banner, savings_pct, RunScale};
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg64;
use crate::util::stats::{render_table, Summary};

pub fn run(cfg: &Config, scale: RunScale) -> anyhow::Result<Json> {
    banner(
        "Fig 12",
        "ALS completion, u=i=102400, f=20480, 500 workers (paper: ~150s/iter coded, 20% savings over spec-exec)",
    );
    // Calibration: ALS block products are large dense BLAS-3 ops; a
    // Lambda core sustains ~6 GFLOP/s there (vs ~1 GFLOP/s on the
    // bandwidth-starved Fig-1 profile). Documented in EXPERIMENTS.md.
    let mut fig_cfg = cfg.clone();
    fig_cfg.set("platform.flops_per_s", "6e9")?;
    let (env, _rt) = fig_cfg.build_env()?;

    // Paper-scale virtual dims; lab-scale numerics.
    let virtual_dims = (102_400, 102_400, 20_480);
    let (numeric_u, numeric_f) = scale.pick((200, 20), (400, 40));
    let iters = scale.pick(4, 7);
    let mut rng = Pcg64::new(cfg.seed);
    let ratings = synthetic_ratings(numeric_u, numeric_u, &mut rng);

    let mut run_one = |scheme: Scheme, seed: u64| -> anyhow::Result<crate::apps::als::AlsResult> {
        let mut rng = Pcg64::new(seed);
        let acfg = AlsConfig {
            factors: numeric_f,
            iters,
            s_rows: 50,
            s_factors: 10,
            scheme,
            virtual_dims: Some(virtual_dims),
            ..Default::default()
        };
        als(&env, &ratings, &acfg, &mut rng)
    };

    let coded = run_one(Scheme::LocalProduct { l_a: 10, l_b: 10 }, cfg.seed + 1)?;
    let spec = run_one(Scheme::Speculative { wait_frac: 0.9 }, cfg.seed + 2)?;

    let mut rows = Vec::new();
    for i in 0..iters {
        rows.push(vec![
            format!("{}", i + 1),
            format!("{:.1}", coded.iterations[i].virtual_secs),
            format!("{:.1}", spec.iterations[i].virtual_secs),
            format!("{:.3e}", coded.iterations[i].loss),
        ]);
    }
    println!(
        "{}",
        render_table(&["iter", "coded (s)", "speculative (s)", "coded loss"], &rows)
    );
    let ct: Vec<f64> = coded.iterations.iter().map(|i| i.virtual_secs).collect();
    let st: Vec<f64> = spec.iterations.iter().map(|i| i.virtual_secs).collect();
    let cs = Summary::of(&ct);
    let ss = Summary::of(&st);
    let savings = savings_pct(coded.total_secs(), spec.total_secs());
    println!(
        "coded {:.1}±{:.1}s/iter (paper ~150s), spec {:.1}±{:.1}s/iter; total savings {savings:.1}% (paper: 20%)",
        cs.mean, cs.std, ss.mean, ss.std
    );

    Ok(obj()
        .field("figure", "fig12")
        .field("iters", iters)
        .field("virtual_dims", Json::Arr(vec![102_400usize.into(), 102_400usize.into(), 20_480usize.into()]))
        .field("coded_per_iter", Json::Arr(ct.iter().map(|&t| t.into()).collect()))
        .field("spec_per_iter", Json::Arr(st.iter().map(|&t| t.into()).collect()))
        .field("coded_total_s", coded.total_secs())
        .field("spec_total_s", spec.total_secs())
        .field("savings_pct", savings)
        .field("paper_savings_pct", 20.0)
        .field("coded_iter_mean_s", cs.mean)
        .field("coded_iter_std_s", cs.std)
        .field("spec_iter_std_s", ss.std)
        .field(
            "loss_curve",
            Json::Arr(coded.iterations.iter().map(|i| i.loss.into()).collect()),
        )
        .build())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig12_savings_and_reliability() {
        let cfg = Config {
            results_dir: std::env::temp_dir().join("slec-test-results"),
            ..Default::default()
        };
        let j = run(&cfg, RunScale::Quick).unwrap();
        let savings = j.get("savings_pct").unwrap().as_f64().unwrap();
        assert!(savings > 5.0, "savings {savings}%");
        // Reliability claim: coded per-iteration variance ≪ speculative's.
        let cstd = j.get("coded_iter_std_s").unwrap().as_f64().unwrap();
        let sstd = j.get("spec_iter_std_s").unwrap().as_f64().unwrap();
        assert!(cstd < sstd, "coded std {cstd} vs spec std {sstd}");
        // Loss decreases.
        let losses = j.get("loss_curve").unwrap().as_arr().unwrap();
        let first = losses.first().unwrap().as_f64().unwrap();
        let last = losses.last().unwrap().as_f64().unwrap();
        assert!(last < first);
    }
}

//! Fig 9: Theorem 2's bound on Pr(decode worker cannot decode) vs
//! L = L_A = L_B, p = 0.02 — sweet spot at L = 10 (n = 121), decode
//! probability ≥ 99.64% — with Monte-Carlo overlay.

use crate::codes::{montecarlo, theory};
use crate::config::Config;
use crate::figures::{banner, RunScale};
use crate::util::json::{obj, Json};
use crate::util::stats::render_table;

pub fn run(cfg: &Config, scale: RunScale) -> anyhow::Result<Json> {
    banner(
        "Fig 9",
        "Pr(undecodable) vs L, p=0.02 (paper: sweet spot n=121 ⇒ L=10, decode prob ≥ 99.64%)",
    );
    let p = 0.02;
    let ls: Vec<usize> = match scale {
        RunScale::Quick => vec![2, 3, 5, 8, 10, 15, 20, 25],
        RunScale::Full => (2..=25).collect(),
    };
    let trials = scale.pick(20_000, 100_000);
    let mut rows = Vec::new();
    let mut out = Vec::new();
    let mut best = (0usize, f64::INFINITY);
    for &l in &ls {
        let bound = theory::thm2_bound(l, l, p);
        let mc = montecarlo::simulate(l, l, p, trials, cfg.seed ^ l as u64);
        if bound < best.1 {
            best = (l, bound);
        }
        rows.push(vec![
            format!("{l}"),
            format!("{}", (l + 1) * (l + 1)),
            format!("{bound:.3e}"),
            format!("{:.3e}", mc.pr_undecodable),
        ]);
        out.push(
            obj()
                .field("l", l)
                .field("n", (l + 1) * (l + 1))
                .field("thm2_bound", bound)
                .field("mc_empirical", mc.pr_undecodable)
                .build(),
        );
    }
    println!(
        "{}",
        render_table(&["L", "n blocks", "Thm-2 bound", "MC empirical"], &rows)
    );
    let b10 = theory::thm2_bound(10, 10, p);
    println!(
        "minimum of the bound at L={} ({:.2e}); L=10 decode prob ≥ {:.2}% (paper: ≥99.64%)",
        best.0,
        best.1,
        (1.0 - b10) * 100.0
    );

    Ok(obj()
        .field("figure", "fig9")
        .field("p", p)
        .field("trials", trials)
        .field("series", Json::Arr(out))
        .field("bound_at_10", b10)
        .field("paper_decode_prob", 0.9964)
        .build())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_bound_dominates_mc_and_matches_caption() {
        let cfg = Config {
            results_dir: std::env::temp_dir().join("slec-test-results"),
            ..Default::default()
        };
        let j = run(&cfg, RunScale::Quick).unwrap();
        for point in j.get("series").unwrap().as_arr().unwrap() {
            let emp = point.get("mc_empirical").unwrap().as_f64().unwrap();
            let bound = point.get("thm2_bound").unwrap().as_f64().unwrap();
            assert!(emp <= bound + 5e-3, "L={:?}", point.get("l"));
        }
        let b10 = j.get("bound_at_10").unwrap().as_f64().unwrap();
        assert!(
            (1.0 - b10 - 0.9964).abs() < 2e-3,
            "decode prob {:.4} should be ≈0.9964",
            1.0 - b10
        );
    }
}

//! Figs 7–8: undecodable vs interlocking straggler configurations, plus
//! exhaustive verification of the §III-C structure theorems on small
//! grids: any ≤3 stragglers decode; all 4-undecodable sets are "squares"
//! (α₄ = C(L_A+1,2)·C(L_B+1,2)).

use crate::codes::peeling::plan_peel;
use crate::codes::theory;
use crate::config::Config;
use crate::figures::{banner, RunScale};
use crate::util::json::{obj, Json};

/// Exhaustively count undecodable straggler sets of size `s` on an
/// (rows × cols) grid.
pub fn count_undecodable(rows: usize, cols: usize, s: usize) -> usize {
    let n = rows * cols;
    let mut count = 0;
    // Enumerate all C(n, s) subsets via lexicographic combinations.
    let mut idx: Vec<usize> = (0..s).collect();
    if s > n {
        return 0;
    }
    loop {
        let mut present = vec![true; n];
        for &i in &idx {
            present[i] = false;
        }
        if !plan_peel(rows, cols, &present).decodable() {
            count += 1;
        }
        // Next combination.
        let mut i = s;
        loop {
            if i == 0 {
                return count;
            }
            i -= 1;
            if idx[i] != i + n - s {
                idx[i] += 1;
                for j in i + 1..s {
                    idx[j] = idx[j - 1] + 1;
                }
                break;
            }
        }
    }
}

pub fn run(_cfg: &Config, scale: RunScale) -> anyhow::Result<Json> {
    banner(
        "Figs 7–8",
        "undecodable-set structure: any ≤3 stragglers decode; 4-undecodable sets are squares (α₄ exact)",
    );
    let grids: Vec<(usize, usize)> = match scale {
        RunScale::Quick => vec![(2, 2), (3, 3), (3, 4)],
        RunScale::Full => vec![(2, 2), (3, 3), (3, 4), (4, 4), (4, 5)],
    };
    let mut rows_out = Vec::new();
    for &(la, lb) in &grids {
        let (rows, cols) = (la + 1, lb + 1);
        let u3 = count_undecodable(rows, cols, 3);
        let u4 = count_undecodable(rows, cols, 4);
        let alpha4 = theory::alpha_counts(la, lb)[0].round() as usize;
        println!(
            "grid {}×{}: 3-straggler undecodable = {} (must be 0); 4-undecodable = {} (α₄ = {})",
            rows, cols, u3, u4, alpha4
        );
        anyhow::ensure!(u3 == 0, "found a 3-undecodable set on {rows}×{cols}");
        anyhow::ensure!(u4 == alpha4, "α₄ mismatch: {u4} vs {alpha4}");
        rows_out.push(
            obj()
                .field("l_a", la)
                .field("l_b", lb)
                .field("undecodable_3", u3)
                .field("undecodable_4", u4)
                .field("alpha4_formula", alpha4)
                .build(),
        );
    }
    // α₅ exact check on the smallest grid (α₅ = α₄·(n−4)).
    let u5 = count_undecodable(3, 3, 5);
    let alpha5 = theory::alpha_counts(2, 2)[1].round() as usize;
    println!("grid 3×3: 5-undecodable = {u5} (α₅ = {alpha5})");
    anyhow::ensure!(u5 == alpha5, "α₅ mismatch: {u5} vs {alpha5}");

    println!("verified: peeling decodes every ≤3-straggler pattern; Fig-7 squares are exactly the 4-undecodable sets.");
    Ok(obj()
        .field("figure", "fig7_8")
        .field("grids", Json::Arr(rows_out))
        .field("alpha5_3x3", u5)
        .build())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exhaustive_counts_match_theory() {
        let cfg = Config {
            results_dir: std::env::temp_dir().join("slec-test-results"),
            ..Default::default()
        };
        run(&cfg, RunScale::Quick).unwrap();
    }

    #[test]
    fn four_squares_on_3x3() {
        // C(3,2)² = 9 four-undecodable squares on a 3×3 grid.
        assert_eq!(count_undecodable(3, 3, 4), 9);
        assert_eq!(count_undecodable(3, 3, 3), 0);
    }
}

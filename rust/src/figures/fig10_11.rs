//! Figs 10–11: KRR with PCG, coded vs speculative execution.
//!
//! Fig 10: ADULT-like (32k×32k kernel over 64 workers; paper: 42.1%
//! total-time reduction, 11% test error). Fig 11: EPSILON-like (400k×400k
//! over 400 workers; paper: 44.5% reduction, 8% test error).

use crate::codes::Scheme;
use crate::config::Config;
use crate::apps::krr::{krr_pcg, synthetic_dataset, KrrConfig};
use crate::figures::{banner, savings_pct, RunScale};
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg64;
use crate::util::stats::render_table;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    AdultLike,
    EpsilonLike,
}

pub fn run(cfg: &Config, scale: RunScale, which: Dataset) -> anyhow::Result<Json> {
    let (fig, virtual_n, s_blocks, l_a, paper_savings, paper_err) = match which {
        Dataset::AdultLike => ("fig10", 32_000, 64, 4, 42.1, 0.11),
        Dataset::EpsilonLike => ("fig11", 400_000, 400, 10, 44.5, 0.08),
    };
    banner(
        fig,
        &format!(
            "KRR-PCG {which:?}: kernel {virtual_n}² over {s_blocks} workers (paper: {paper_savings}% reduction)"
        ),
    );
    // Calibration: the KRR row-block objects are large single-stream S3
    // reads (see fig3 note); 25 MB/s effective GET throughput.
    let mut fig_cfg = cfg.clone();
    fig_cfg.set("platform.s3_bandwidth_bps", "25e6")?;
    let (env, _rt) = fig_cfg.build_env()?;

    // Lab-scale numerics: n must divide s_blocks.
    let numeric_n = match which {
        Dataset::AdultLike => scale.pick(512, 1024),
        Dataset::EpsilonLike => scale.pick(800, 1200),
    };
    let mut rng = Pcg64::new(cfg.seed);
    let data = synthetic_dataset(numeric_n, numeric_n / 2, 10, &mut rng);

    let mut run_one = |scheme: Scheme, seed: u64| -> anyhow::Result<crate::apps::krr::KrrResult> {
        let mut rng = Pcg64::new(seed);
        let kcfg = KrrConfig {
            s_blocks,
            scheme,
            virtual_n: Some(virtual_n),
            max_iters: 25,
            ..Default::default()
        };
        krr_pcg(&env, &data, &kcfg, &mut rng)
    };

    let coded = run_one(Scheme::LocalProduct { l_a, l_b: l_a }, cfg.seed + 1)?;
    let spec = run_one(Scheme::Speculative { wait_frac: 0.9 }, cfg.seed + 2)?;

    let iters = coded.iterations.len().max(spec.iterations.len());
    let mut rows = Vec::new();
    for i in 0..iters {
        let c = coded.iterations.get(i);
        let s = spec.iterations.get(i);
        rows.push(vec![
            format!("{}", i + 1),
            c.map(|x| format!("{:.1}", x.virtual_secs)).unwrap_or_default(),
            s.map(|x| format!("{:.1}", x.virtual_secs)).unwrap_or_default(),
            c.map(|x| format!("{:.1e}", x.residual)).unwrap_or_default(),
        ]);
    }
    println!(
        "{}",
        render_table(&["iter", "coded (s)", "speculative (s)", "residual"], &rows)
    );
    let savings = savings_pct(coded.total_secs(), spec.total_secs());
    println!(
        "total: coded {:.0}s (encode {:.0}s) vs spec {:.0}s → {savings:.1}% savings (paper: {paper_savings}%)",
        coded.total_secs(),
        coded.encode_secs,
        spec.total_secs()
    );
    println!(
        "converged: coded={} spec={}; test error {:.1}% (paper: {:.0}%)",
        coded.converged,
        spec.converged,
        coded.test_error * 100.0,
        paper_err * 100.0
    );

    Ok(obj()
        .field("figure", fig)
        .field("virtual_n", virtual_n)
        .field("workers", s_blocks)
        .field("numeric_n", numeric_n)
        .field(
            "coded_per_iter",
            Json::Arr(coded.iterations.iter().map(|i| i.virtual_secs.into()).collect()),
        )
        .field(
            "spec_per_iter",
            Json::Arr(spec.iterations.iter().map(|i| i.virtual_secs.into()).collect()),
        )
        .field("coded_total_s", coded.total_secs())
        .field("coded_encode_s", coded.encode_secs)
        .field("spec_total_s", spec.total_secs())
        .field("savings_pct", savings)
        .field("paper_savings_pct", paper_savings)
        .field("coded_converged", coded.converged)
        .field("spec_converged", spec.converged)
        .field("test_error", coded.test_error)
        .field("paper_test_error", paper_err)
        .build())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10_coded_saves_vs_speculative() {
        let cfg = Config {
            results_dir: std::env::temp_dir().join("slec-test-results"),
            ..Default::default()
        };
        let j = run(&cfg, RunScale::Quick, Dataset::AdultLike).unwrap();
        let savings = j.get("savings_pct").unwrap().as_f64().unwrap();
        assert!(savings > 10.0, "savings {savings}% too small");
        assert_eq!(j.get("coded_converged").unwrap().as_bool(), Some(true));
        let err = j.get("test_error").unwrap().as_f64().unwrap();
        assert!(err < 0.45, "test error {err}");
    }
}

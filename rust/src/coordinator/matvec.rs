//! The coded matrix-vector workflow (§II-A) — the per-iteration primitive
//! of power iteration and KRR-PCG.
//!
//! Encoding happens ONCE (criterion 1 of §I-B: the cost is amortized over
//! iterations); each iteration runs the compute phase over the coded
//! row-blocks and a cheap vector-decode. The speculative baseline runs the
//! same row-blocks uncoded with wait-for-q% + relaunch.
//!
//! Every phase executes through the same generic driver as the matmul
//! workload ([`crate::coordinator::driver`]): the scheme's
//! [`ComputePolicy`] supplies the termination rule and decodability
//! probe, so `multiply` carries no per-scheme dispatch. Earliest-
//! decodable cutoffs cancel straggling tasks (freeing workers on bounded
//! pools), and a recompute round for an undecodable grid runs as a fresh
//! event-driven phase on the same virtual clock.

use crate::codes::matvec::CodedMatvec2D;
use crate::codes::scheme::{instantiate_matvec, ComputePolicy};
use crate::codes::Scheme;
use crate::coordinator::driver::{drive_phase, drive_policy_phase};
use crate::coordinator::matmul::Env;
use crate::coordinator::metrics::{JobReport, PhaseMetrics};
use crate::linalg::blocked::Partition;
use crate::linalg::matrix::Matrix;
use crate::platform::event::Termination;
use crate::platform::WorkProfile;
use crate::util::rng::Pcg64;
use crate::util::threadpool::parallel_map;

/// A matvec engine bound to one matrix: pays the encode once, then serves
/// `y = A·x` per iteration.
pub struct MatvecEngine {
    /// Coded blocks of A (systematic + parities) or plain blocks when
    /// uncoded/speculative.
    blocks: Vec<Matrix>,
    code: Option<CodedMatvec2D>,
    /// Compute-phase policy (termination + decodability probe) from the
    /// scheme registry.
    policy: Box<dyn ComputePolicy>,
    scheme: Scheme,
    s: usize,
    cols: usize,
    /// Virtual-time dims (rows, cols) used for work profiles — the paper-
    /// scale dims when the figure harness simulates at paper scale.
    v_rows: usize,
    v_cols: usize,
    /// Encode-phase report (paid once).
    pub encode_report: PhaseMetrics,
}

/// Per-iteration outcome.
#[derive(Debug, Clone)]
pub struct IterationReport {
    pub comp: PhaseMetrics,
    pub dec: PhaseMetrics,
}

impl IterationReport {
    pub fn total_secs(&self) -> f64 {
        self.comp.virtual_secs + self.dec.virtual_secs
    }
}

impl MatvecEngine {
    /// Build the engine: partition A into `s` row-blocks and (for coded
    /// schemes) encode with group size from the scheme.
    pub fn new(
        env: &Env,
        a: &Matrix,
        s: usize,
        scheme: Scheme,
        rng: &mut Pcg64,
    ) -> anyhow::Result<MatvecEngine> {
        Self::with_virtual_dims(env, a, s, scheme, None, rng)
    }

    /// Like [`MatvecEngine::new`] but with explicit virtual-time dims
    /// `(rows, cols)` for the work profiles (paper-scale simulation over
    /// lab-scale numerics).
    pub fn with_virtual_dims(
        env: &Env,
        a: &Matrix,
        s: usize,
        scheme: Scheme,
        virtual_dims: Option<(usize, usize)>,
        rng: &mut Pcg64,
    ) -> anyhow::Result<MatvecEngine> {
        anyhow::ensure!(a.rows % s == 0, "rows must divide s");
        let (v_rows, v_cols) = virtual_dims.unwrap_or((a.rows, a.cols));
        anyhow::ensure!(v_rows % s == 0, "virtual rows must divide s");
        let (code, policy) = instantiate_matvec(scheme, s)?;
        let p = Partition::new(a.rows, a.cols, s);
        let plain = p.split(a);
        let mut encode_report = PhaseMetrics::default();

        let blocks = match &code {
            Some(code) => {
                // 2-D product-coded matvec ("2D product code similar to
                // [17]", §IV-A): s = grids·l² systematic blocks.
                //
                // Encode volume: every systematic block is read twice
                // (row parity + column parity); the corner is built from
                // the already-written row parities (l extra reads per
                // grid). The fleet matches the compute width, so encoding
                // costs about one iteration (amortized per §I-B).
                let fleet = code.coded_len();
                let parities = code.coded_len() - code.systematic();
                let blocks_read_total = 2 * code.systematic() + code.grids * code.l;
                let total_read = (blocks_read_total * (v_rows / s) * v_cols * 4) as u64;
                let enc_profile = WorkProfile {
                    bytes_read: total_read / fleet as u64,
                    read_ops: blocks_read_total.div_ceil(fleet) as u64,
                    flops: (2 * code.systematic() * (v_rows / s) * v_cols) as f64
                        / fleet as f64,
                    bytes_written: (parities * (v_rows / s) * v_cols * 4) as u64 / fleet as u64,
                    write_ops: parities.div_ceil(fleet).max(1) as u64,
                };
                let mut sim = env.sim();
                let enc = drive_phase(
                    &mut sim,
                    &env.model,
                    &vec![enc_profile; fleet],
                    Termination::Speculative {
                        wait_frac: crate::codes::scheme::ENCODE_WAIT_FRAC,
                    },
                    &mut |_, _| false,
                    rng,
                );
                encode_report.tasks = fleet;
                encode_report.virtual_secs = enc.duration();
                encode_report.blocks_read = blocks_read_total;
                // Numerics through the backend.
                let backend = env.backend.as_ref();
                code.encode(&plain, |members| backend.stack_sum(members))
            }
            None => plain,
        };

        Ok(MatvecEngine {
            blocks,
            code,
            policy,
            scheme,
            s,
            cols: a.cols,
            v_rows,
            v_cols,
            encode_report,
        })
    }

    pub fn redundancy(&self) -> f64 {
        self.code.map(|c| c.redundancy()).unwrap_or(0.0)
    }

    /// One iteration: `y = A·x` under the engine's scheme. The compute
    /// phase is policy-driven (no scheme dispatch); only the numeric
    /// decode distinguishes coded from plain engines.
    pub fn multiply(
        &self,
        env: &Env,
        x: &[f32],
        rng: &mut Pcg64,
    ) -> anyhow::Result<(Vec<f32>, IterationReport)> {
        anyhow::ensure!(x.len() == self.cols, "x length {} != {}", x.len(), self.cols);
        let mut rep = IterationReport {
            comp: PhaseMetrics::default(),
            dec: PhaseMetrics::default(),
        };
        let profile = WorkProfile::block_matvec(self.v_rows / self.s, self.v_cols);
        let n = self.blocks.len();
        let mut sim = env.sim();

        let comp = drive_policy_phase(
            &mut sim,
            &env.model,
            &vec![profile; n],
            self.policy.as_ref(),
            rng,
        );
        rep.comp.tasks = n;
        rep.comp.stragglers = comp.stragglers();
        rep.comp.relaunched = comp.relaunched;
        rep.comp.virtual_secs = comp.duration();

        let Some(code) = &self.code else {
            let y = self.multiply_all(env, x);
            return Ok((y, rep));
        };

        // Numerics on arrived blocks.
        let arrived = comp.arrived_mask();
        let mut results: Vec<Option<Vec<f32>>> = {
            let arrived_ref = &arrived;
            let blocks = &self.blocks;
            parallel_map(env.threads, n, move |i| {
                if arrived_ref[i] {
                    Some(env.backend.gemv(&blocks[i], x))
                } else {
                    None
                }
            })
        };
        let decoded = match code.decode(&results) {
            Ok(d) => d,
            Err(stuck) => {
                // Undecodable grid(s) (Thm-2 tail): recompute the
                // missing cells on fresh workers — a fresh
                // event-driven round on the same clock; numerics
                // are direct gemvs.
                let mut missing = 0usize;
                for &g in &stuck {
                    for r in 0..=code.l {
                        for c in 0..=code.l {
                            let posn = code.pos(g, r, c);
                            if results[posn].is_none() {
                                results[posn] =
                                    Some(env.backend.gemv(&self.blocks[posn], x));
                                missing += 1;
                            }
                        }
                    }
                }
                rep.dec.relaunched = missing;
                let rec = drive_phase(
                    &mut sim,
                    &env.model,
                    &vec![profile; missing],
                    Termination::WaitAll,
                    &mut |_, _| false,
                    rng,
                );
                rep.dec.virtual_secs += rec.duration();
                code.decode(&results)
                    .map_err(|g| anyhow::anyhow!("still undecodable: {g:?}"))?
            }
        };
        let (blocks, reads, plans) = decoded;
        rep.dec.blocks_read = reads;
        // Decode work exists only when something straggled; the
        // all-arrived common case needs no decode worker at all.
        if reads > 0 {
            // Vector-block decode is "inexpensive ... performed
            // over a vector" (§II-A): the long-lived master does
            // it while assembling y — no worker invocation, just
            // the block reads.
            rep.dec.tasks = 1;
            let v_block = self.v_rows / self.s;
            let _recovered: usize = _plans_len(&plans);
            rep.dec.virtual_secs += env.model.rates.cost.read_many_parallel(
                reads as u64,
                (reads * v_block * 4) as u64,
                32,
            );
        }
        Ok((blocks.concat(), rep))
    }

    fn multiply_all(&self, env: &Env, x: &[f32]) -> Vec<f32> {
        let blocks = &self.blocks;
        let parts: Vec<Vec<f32>> = parallel_map(env.threads, self.s, move |i| {
            env.backend.gemv(&blocks[i], x)
        });
        parts.concat()
    }

    /// Aggregate a full job report over `iters` iterations. `decode_ok`
    /// is false when any iteration needed a recompute round (matvec's
    /// decode phase never relaunches speculatively, so `dec.relaunched`
    /// is exactly the recompute count).
    pub fn job_report(&self, iters: &[IterationReport]) -> JobReport {
        let mut rep = JobReport::new(self.scheme.name());
        rep.redundancy = self.redundancy();
        rep.enc = self.encode_report.clone();
        for it in iters {
            rep.comp.virtual_secs += it.comp.virtual_secs;
            rep.comp.tasks += it.comp.tasks;
            rep.comp.stragglers += it.comp.stragglers;
            rep.comp.relaunched += it.comp.relaunched;
            rep.dec.virtual_secs += it.dec.virtual_secs;
            rep.dec.blocks_read += it.dec.blocks_read;
            if it.dec.relaunched > 0 {
                rep.decode_ok = false;
            }
        }
        rep
    }
}

fn _plans_len(plans: &[crate::codes::peeling::PeelPlan]) -> usize {
    plans.iter().map(|p| p.recovered()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm;

    fn setup(seed: u64) -> (Env, Matrix, Vec<f32>) {
        let env = Env::host();
        let mut rng = Pcg64::new(seed);
        let a = Matrix::randn(64, 40, &mut rng, 0.0, 1.0);
        let x: Vec<f32> = (0..40).map(|i| (i as f32 * 0.3).cos()).collect();
        (env, a, x)
    }

    #[test]
    fn coded_matvec_exact_across_seeds() {
        let (env, a, x) = setup(1);
        let truth = gemm::matvec(&a, &x);
        for seed in 0..10 {
            let mut rng = Pcg64::new(seed);
            let eng = MatvecEngine::new(
                &env,
                &a,
                8,
                Scheme::LocalProduct { l_a: 2, l_b: 2 },
                &mut rng,
            )
            .unwrap();
            let (y, rep) = eng.multiply(&env, &x, &mut rng).unwrap();
            for (got, want) in y.iter().zip(&truth) {
                assert!((got - want).abs() < 1e-3, "seed {seed}");
            }
            assert!(rep.comp.virtual_secs > 0.0);
        }
    }

    #[test]
    fn speculative_matvec_correct() {
        let (env, a, x) = setup(2);
        let truth = gemm::matvec(&a, &x);
        let mut rng = Pcg64::new(3);
        let eng =
            MatvecEngine::new(&env, &a, 8, Scheme::Speculative { wait_frac: 0.9 }, &mut rng)
                .unwrap();
        assert_eq!(eng.encode_report.virtual_secs, 0.0);
        let (y, _) = eng.multiply(&env, &x, &mut rng).unwrap();
        for (got, want) in y.iter().zip(&truth) {
            assert!((got - want).abs() < 1e-3);
        }
    }

    #[test]
    fn encode_paid_once() {
        let (env, a, x) = setup(4);
        let mut rng = Pcg64::new(5);
        let eng = MatvecEngine::new(
            &env,
            &a,
            8,
            Scheme::LocalProduct { l_a: 2, l_b: 2 },
            &mut rng,
        )
        .unwrap();
        let enc_t = eng.encode_report.virtual_secs;
        assert!(enc_t > 0.0);
        let mut iters = Vec::new();
        for _ in 0..3 {
            let (_, rep) = eng.multiply(&env, &x, &mut rng).unwrap();
            iters.push(rep);
        }
        let job = eng.job_report(&iters);
        // Encode counted once, not per iteration.
        assert!((job.enc.virtual_secs - enc_t).abs() < 1e-12);
        // 2 grids × (2+1)² = 18 coded tasks per iteration.
        assert_eq!(job.comp.tasks, 3 * 18);
        // 2-D redundancy: (l+1)²/l² − 1 = 1.25 for l = 2.
        assert!((eng.redundancy() - 1.25).abs() < 1e-12);
    }

    #[test]
    fn coded_matvec_exact_on_bounded_pool() {
        // Worker reuse must not change the numerics, only the clock.
        let (_, a, x) = setup(7);
        let env = Env::builder().pool(3).build();
        let truth = gemm::matvec(&a, &x);
        let mut rng = Pcg64::new(8);
        let eng = MatvecEngine::new(
            &env,
            &a,
            8,
            Scheme::LocalProduct { l_a: 2, l_b: 2 },
            &mut rng,
        )
        .unwrap();
        let (y, rep) = eng.multiply(&env, &x, &mut rng).unwrap();
        for (got, want) in y.iter().zip(&truth) {
            assert!((got - want).abs() < 1e-3);
        }
        assert!(rep.comp.virtual_secs > 0.0);
    }

    #[test]
    fn rejects_unsupported_scheme() {
        let (env, a, _) = setup(6);
        let mut rng = Pcg64::new(7);
        assert!(MatvecEngine::new(&env, &a, 8, Scheme::Polynomial { redundancy: 0.2 }, &mut rng)
            .is_err());
    }
}

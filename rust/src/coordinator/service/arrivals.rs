//! Open-loop Poisson arrival generation for the coordinator service.
//!
//! The arrival process draws from its own salted RNG stream
//! (`Pcg64::new(seed ^ ARRIVAL_SALT)`), fully separate from the per-job
//! simulation streams (which [`super::run_service`] forks from
//! `Pcg64::new(seed)` in arrival order, exactly like the
//! explicit-`jobs` runner forks them in job order). Consequently the
//! offered-job list is a pure function of the scenario seed, and a
//! job's simulated timeline is a pure function of `(seed, arrival
//! seq)` — pool size, admission outcomes and autoscaling never shift
//! either draw sequence.

use crate::platform::scenario::{ArrivalSpec, JobSpec, Scenario};
use crate::util::rng::Pcg64;

/// Salt separating the arrival process's RNG stream from the per-job
/// simulation streams.
const ARRIVAL_SALT: u64 = 0x5345_5256_4a51_5545; // "SERVJQUE"

/// One offered job: a sampled template billed to a sampled (or
/// template-pinned) tenant, arriving at a Poisson instant.
#[derive(Debug, Clone)]
pub struct Offered {
    /// Arrival sequence number — also the job's sim-stream fork index
    /// and its `JobRun` index.
    pub seq: usize,
    pub arrival: f64,
    /// Index into `Scenario::tenants`; `None` = anonymous (no tenants
    /// section).
    pub tenant: Option<usize>,
    /// Index into `ArrivalSpec::templates` this job was sampled from;
    /// `None` for ad-hoc submissions (the daemon path). Lets the
    /// submission log reference the template instead of serializing the
    /// whole spec, so a replay reconstructs it loss-free.
    pub template: Option<usize>,
    pub spec: JobSpec,
}

/// Materialize the full offered-job list of a service scenario.
///
/// Draw order per arrival: interarrival gap `Exp(rate_per_s)`, then the
/// template (categorical over template weights), then — only when the
/// scenario has tenants *and* the drawn template does not pin one — the
/// tenant (categorical over tenant weights).
pub fn offered_jobs(sc: &Scenario, arr: &ArrivalSpec) -> Vec<Offered> {
    let mut rng = Pcg64::new(sc.seed ^ ARRIVAL_SALT);
    let weights: Vec<f64> = arr.templates.iter().map(|(w, _)| *w).collect();
    let tweights: Vec<f64> = sc.tenants.iter().map(|t| t.weight).collect();
    let mut clock = 0.0;
    let mut out = Vec::with_capacity(arr.jobs);
    for seq in 0..arr.jobs {
        clock += rng.exponential(arr.rate_per_s);
        let ti = rng.categorical(&weights);
        let (_, template) = &arr.templates[ti];
        let tenant = match &template.tenant {
            // Parse-time validation guarantees pinned tenants exist.
            Some(name) => Some(
                sc.tenants
                    .iter()
                    .position(|t| &t.name == name)
                    .expect("pinned tenant validated at parse time"),
            ),
            None if !sc.tenants.is_empty() => Some(rng.categorical(&tweights)),
            None => None,
        };
        let mut spec = template.clone();
        spec.arrival = clock;
        if let Some(i) = tenant {
            spec.tenant = Some(sc.tenants[i].name.clone());
        }
        out.push(Offered {
            seq,
            arrival: clock,
            tenant,
            template: Some(ti),
            spec,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::scenario::parse_scenario;
    use crate::util::json::parse;

    fn service_scenario(seed: u64) -> Scenario {
        parse_scenario(
            &parse(&format!(
                r#"{{
                    "name": "arr-test",
                    "seed": {seed},
                    "workers": 8,
                    "tenants": [
                        {{"name": "a", "weight": 3.0}},
                        {{"name": "b", "weight": 1.0}}
                    ],
                    "arrivals": {{
                        "jobs": 400,
                        "rate_per_s": 0.5,
                        "templates": [
                            {{"scheme": "uncoded", "s_a": 2, "s_b": 2, "dims": 1000, "weight": 3.0}},
                            {{"scheme": "local-product:2x2", "s_a": 4, "s_b": 4, "dims": 1000,
                              "weight": 1.0, "tenant": "b"}}
                        ]
                    }}
                }}"#
            ))
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn arrivals_are_deterministic_sorted_and_seeded() {
        let sc = service_scenario(11);
        let arr = sc.arrivals.as_ref().unwrap();
        let a = offered_jobs(&sc, arr);
        let b = offered_jobs(&sc, arr);
        assert_eq!(a.len(), 400);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert_eq!(x.tenant, y.tenant);
        }
        assert!(a.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert!(a.iter().all(|o| o.arrival > 0.0));
        // A different seed shifts the whole process.
        let c = offered_jobs(&service_scenario(12), arr);
        assert_ne!(a[0].arrival.to_bits(), c[0].arrival.to_bits());
    }

    #[test]
    fn pinned_templates_bill_their_tenant_and_weights_bias_the_rest() {
        let sc = service_scenario(11);
        let arr = sc.arrivals.as_ref().unwrap();
        let offered = offered_jobs(&sc, arr);
        let mut counts = [0usize; 2];
        for o in &offered {
            let i = o.tenant.expect("tenant scenarios bill every arrival");
            counts[i] += 1;
            assert_eq!(o.spec.tenant.as_deref(), Some(sc.tenants[i].name.as_str()));
            // The pinned template always lands on tenant "b".
            if o.spec.scheme.name() == "local-product" {
                assert_eq!(i, 1);
            }
        }
        // Tenant "a" carries 3× weight over the unpinned (~75%) share:
        // it must dominate despite every pinned arrival going to "b".
        assert!(counts[0] > counts[1], "{counts:?}");
        // The mean interarrival gap is 1/rate = 2s: the 400th arrival
        // lands in the right order of magnitude, not at zero.
        let last = offered.last().unwrap().arrival;
        assert!((400.0..3200.0).contains(&last), "{last}");
    }
}

//! The coordinator service: a long-lived, multi-tenant job queue over
//! one shared worker fleet.
//!
//! Where `run_scenario`'s historical path executes a fixed `jobs` list,
//! the service accepts an *open-loop* stream of [`Offered`] jobs (a
//! Poisson arrival process over weighted templates, see
//! [`offered_jobs`]),
//! pushes each through admission control ([`AdmissionController`]:
//! queue-depth backpressure, then per-tenant in-flight quotas),
//! dispatches admitted jobs best-priority-first into a bounded number
//! of concurrent in-flight slots, and optionally drives a pluggable
//! [`AutoscalePolicy`] that resizes the shared fleet from the observed
//! dispatch backlog and fault rates.
//!
//! Admitted jobs run the *identical* `JobRun` pipeline state machine
//! as explicit-`jobs` scenarios — encode → compute → decode →
//! recompute — over one shared [`EventSim`]. The RNG contract also
//! carries over unchanged (DESIGN.md §Coordinator service): per-job
//! simulation streams are forked from `Pcg64::new(seed)` in arrival
//! order, task durations are sampled at submission, and the arrival
//! process draws from a separately salted stream — so every job's
//! timeline is a pure function of `(seed, arrival seq)`, and admission
//! outcomes, pool size and autoscaling can never shift a draw.
//!
//! Since the API redesign the run loop lives in `ServiceCore`, an
//! *incremental* engine: arrivals are fed one at a time (batch `serve`
//! runs, replayed submission logs and the wall-clock `slec daemon` all
//! push through the same `arrive`/`drain` methods), so a replayed
//! submission log is bit-identical to the batch run that logged it.
//!
//! When the scenario has a `storage` section, all concurrent service
//! jobs additionally share one [`ObjectStore`]: every finished job's
//! report manifest is written under its tenant's key prefix
//! (`keys::tenant_report`), and the service report gains per-tenant
//! [`StorageMetrics`] rollups — real manifest writes plus the job's
//! modeled coded-block read demand from the contention overlay.

mod admission;
mod arrivals;
mod autoscale;

pub use admission::{AdmissionController, Rejection};
pub use arrivals::{offered_jobs, Offered};
pub use autoscale::{
    make_policy, AutoscalePolicy, Autoscaler, FaultAwarePolicy, FleetObservation,
    QueueDepthPolicy, POLICIES,
};

use std::collections::{BTreeMap, BinaryHeap};
use std::sync::Arc;

use crate::coordinator::metrics::{LatencyStats, StorageMetrics};
use crate::platform::event::{EventSim, Pool};
use crate::platform::scenario::{ArrivalSpec, JobRun, JobSpec, Scenario};
use crate::platform::straggler::{SlowdownDist, StragglerModel, StragglerParams, WorkerRates};
use crate::storage::faults::StorageFaultMetrics;
use crate::storage::{keys, MemStore, ObjectStore};
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg64;

/// Run a service scenario (one with an `arrivals` section): one service
/// lifetime per `workers` sweep entry, summarized in the same
/// golden-comparable document shape as `run_scenario`.
pub fn run_service(sc: &Scenario) -> anyhow::Result<Json> {
    let arr = sc
        .arrivals
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("run_service needs an 'arrivals' section"))?;
    let offered = offered_jobs(sc, arr);
    run_service_with(sc, &offered)
}

/// [`run_service`] over an explicit offered-job list instead of the
/// scenario's Poisson process — the replay path: feeding back the
/// arrivals recorded in a submission log reproduces the original run's
/// document byte for byte.
pub fn run_service_with(sc: &Scenario, offered: &[Offered]) -> anyhow::Result<Json> {
    let arr = sc
        .arrivals
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("run_service needs an 'arrivals' section"))?;
    let mut runs = Vec::with_capacity(sc.workers.len());
    for &workers in &sc.workers {
        let mut core = ServiceCore::new(sc, workers)?;
        for o in offered {
            core.arrive(o.clone())?;
        }
        core.drain()?;
        core.check_drained()?;
        runs.push(core.summary());
    }
    Ok(obj()
        .field("scenario", sc.name.as_str())
        .field("seed", sc.seed)
        .field(
            "straggler",
            obj()
                .field(
                    "dist",
                    match sc.straggler.slow_dist {
                        SlowdownDist::LogNormal => "lognormal",
                        SlowdownDist::Pareto { .. } => "pareto",
                    },
                )
                .field("p", sc.straggler.p)
                .build(),
        )
        .field(
            "arrivals",
            obj()
                .field("jobs", arr.jobs)
                .field("rate_per_s", arr.rate_per_s)
                .build(),
        )
        .field("runs", Json::Arr(runs))
        .build())
}

/// Run one ad-hoc job through the service's single-job path (the
/// `slec submit` backend): a fresh bounded fleet, the default straggler
/// calibration unless overridden, and the standard report document.
pub fn submit_one(
    spec: &JobSpec,
    workers: usize,
    seed: u64,
    straggler: StragglerParams,
) -> anyhow::Result<Json> {
    let model = StragglerModel::new(straggler, WorkerRates::default());
    let mut sim = EventSim::new(Pool::from_option(Some(workers)));
    let mut root = Pcg64::new(seed);
    let mut run = JobRun::new(0, spec.clone(), None, None, None, None, seed, root.fork(0))?;
    run.start(&mut sim, &model);
    while let Some(c) = sim.step() {
        run.on_completion(&mut sim, &model, &c);
    }
    anyhow::ensure!(run.done, "submitted job did not run to completion");
    let mut doc = run.report.to_json();
    doc.set("finish", Json::from(run.finish));
    Ok(doc)
}

/// Admission-queue entry: max-heap by priority, FIFO within a priority
/// level (smaller arrival seq pops first).
#[derive(PartialEq, Eq)]
struct Pending {
    priority: u32,
    seq: usize,
}

impl Ord for Pending {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.priority.cmp(&o.priority).then(o.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Pending {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}

#[derive(Default, Clone)]
struct TenantCounters {
    offered: u64,
    admitted: u64,
    rejected_queue: u64,
    rejected_quota: u64,
}

#[derive(Default)]
struct FaultAgg {
    deaths: u64,
    retries: u64,
    exhausted: u64,
    absorbed: u64,
    degraded_jobs: u64,
    any: bool,
}

struct Counters {
    admitted: u64,
    rejected_queue: u64,
    rejected_quota: u64,
    tenant: Vec<TenantCounters>,
    schemes: BTreeMap<String, u64>,
    latency: LatencyStats,
    queue_wait: LatencyStats,
    service_time: LatencyStats,
    deadline_offered: u64,
    deadline_met: u64,
    total_tasks: u64,
    total_stragglers: u64,
    faults: FaultAgg,
    /// Storage-fault rollup; reported only when some job observed one.
    storage_faults: StorageFaultMetrics,
    storage_faults_any: bool,
}

impl Counters {
    fn new(tenants: usize) -> Counters {
        Counters {
            admitted: 0,
            rejected_queue: 0,
            rejected_quota: 0,
            tenant: vec![TenantCounters::default(); tenants],
            schemes: BTreeMap::new(),
            latency: LatencyStats::new(),
            queue_wait: LatencyStats::new(),
            service_time: LatencyStats::new(),
            deadline_offered: 0,
            deadline_met: 0,
            total_tasks: 0,
            total_stragglers: 0,
            faults: FaultAgg::default(),
            storage_faults: StorageFaultMetrics::default(),
            storage_faults_any: false,
        }
    }

    fn rate(num: u64, den: u64) -> f64 {
        if den == 0 {
            0.0
        } else {
            num as f64 / den as f64
        }
    }

    fn straggle_rate(&self) -> f64 {
        Counters::rate(self.total_stragglers, self.total_tasks)
    }

    fn death_rate(&self) -> f64 {
        Counters::rate(self.faults.deaths, self.total_tasks)
    }
}

/// Where one offered job currently is in the service lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum JobState {
    /// Turned away at admission.
    Rejected(Rejection),
    /// Admitted, waiting in the priority queue for an in-flight slot.
    Queued,
    /// Dispatched; phases in flight on the shared fleet.
    Running,
    /// Finished and folded into the run counters.
    Done,
}

impl JobState {
    /// Wire name used by the daemon's status endpoint.
    pub(crate) fn wire(&self) -> &'static str {
        match self {
            JobState::Rejected(Rejection::QueueFull) => "rejected:queue_full",
            JobState::Rejected(Rejection::TenantQuota) => "rejected:tenant_quota",
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
        }
    }
}

/// Fold one finished job into the run counters and free its admission
/// slot.
fn finalize_job(
    run: &JobRun,
    o: &Offered,
    started: f64,
    c: &mut Counters,
    admission: &mut AdmissionController,
) {
    admission.release(o.tenant);
    let latency = run.finish - o.arrival;
    c.latency.record(latency);
    c.service_time.record(run.finish - started);
    *c.schemes.entry(run.report.scheme.clone()).or_insert(0) += 1;
    if let Some(d) = run.spec.deadline_s {
        c.deadline_offered += 1;
        if latency <= d {
            c.deadline_met += 1;
        }
    }
    let r = &run.report;
    c.total_tasks += (r.enc.tasks + r.comp.tasks + r.dec.tasks) as u64;
    c.total_stragglers += (r.enc.stragglers + r.comp.stragglers + r.dec.stragglers) as u64;
    if let Some(f) = &r.faults {
        c.faults.any = true;
        c.faults.deaths += f.deaths;
        c.faults.retries += f.retries;
        c.faults.exhausted += f.exhausted;
        c.faults.absorbed += f.absorbed;
        c.faults.degraded_jobs += f.degraded as u64;
    }
    if let Some(sf) = &r.storage_faults {
        c.storage_faults_any = true;
        c.storage_faults.add(sf);
    }
}

/// One service lifetime over one initial fleet size, fed arrivals
/// incrementally.
///
/// The engine behind both `run_service_with` (batch: all arrivals
/// pushed back to back) and the wall-clock daemon (arrivals pushed as
/// sockets deliver them, with [`ServiceCore::pump_to`] advancing the
/// virtual clock between submissions). The event ordering is exactly
/// the historical batch loop's: before every processed event, admitted
/// jobs are dispatched into free in-flight slots; arrivals win ties
/// with completions; the autoscaler ticks once after every arrival or
/// completion. Because dispatch never advances the clock and always
/// runs before the next event is popped, slicing the same arrival
/// sequence differently across calls cannot move any timestamp — the
/// bit-identity guarantee the replay path rests on.
pub(crate) struct ServiceCore {
    sc: Scenario,
    arr: ArrivalSpec,
    model: StragglerModel,
    workers: usize,
    sim: EventSim,
    /// Per-job stream root; forked once per arrival, in seq order —
    /// identical streams to the historical up-front forking. Rejected
    /// jobs' forks are discarded, so admission outcomes cannot shift
    /// any other job's draws.
    root: Pcg64,
    admission: AdmissionController,
    autoscaler: Option<Autoscaler>,
    /// All indexed by arrival seq.
    meta: Vec<Offered>,
    jobs: Vec<Option<JobRun>>,
    state: Vec<JobState>,
    streams: Vec<Option<Pcg64>>,
    started: Vec<f64>,
    pending: BinaryHeap<Pending>,
    inflight: usize,
    c: Counters,
    /// Shared across every concurrent job of this service lifetime
    /// (present exactly when the scenario has a `storage` section).
    store: Option<Arc<dyn ObjectStore>>,
    /// Per-tenant storage rollups; anonymous jobs bill to `"-"`.
    tenant_storage: BTreeMap<String, StorageMetrics>,
}

impl ServiceCore {
    pub(crate) fn new(sc: &Scenario, workers: usize) -> anyhow::Result<ServiceCore> {
        let arr = sc
            .arrivals
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("service core needs an 'arrivals' section"))?
            .clone();
        let autoscaler = match &sc.autoscale {
            Some(a) => Some(Autoscaler::new(a, workers)?),
            None => None,
        };
        let store: Option<Arc<dyn ObjectStore>> = sc
            .storage
            .as_ref()
            .map(|sp| Arc::new(MemStore::with_config(sp.shards, 0)) as Arc<dyn ObjectStore>);
        Ok(ServiceCore {
            model: StragglerModel::new(sc.straggler, sc.rates),
            workers,
            sim: EventSim::new(Pool::from_option(Some(workers))),
            root: Pcg64::new(sc.seed),
            admission: AdmissionController::new(&arr, &sc.tenants),
            autoscaler,
            meta: Vec::new(),
            jobs: Vec::new(),
            state: Vec::new(),
            streams: Vec::new(),
            started: Vec::new(),
            pending: BinaryHeap::new(),
            inflight: 0,
            c: Counters::new(sc.tenants.len()),
            store,
            tenant_storage: BTreeMap::new(),
            arr,
            sc: sc.clone(),
        })
    }

    /// Dispatch admitted jobs into free in-flight slots, best priority
    /// first.
    fn dispatch(&mut self) -> anyhow::Result<()> {
        while (self.arr.max_inflight == 0 || self.inflight < self.arr.max_inflight)
            && !self.pending.is_empty()
        {
            let seq = self.pending.pop().expect("checked non-empty").seq;
            let rng = self.streams[seq].take().expect("admitted job keeps its stream");
            let (arrival, spec) = {
                let o = &self.meta[seq];
                (o.arrival, o.spec.clone())
            };
            let mut run = JobRun::new(
                seq,
                spec,
                self.sc.storage.as_ref(),
                self.sc.failures.as_ref(),
                self.sc.progress.as_ref(),
                self.sc.storage_faults.as_ref(),
                self.sc.seed,
                rng,
            )?;
            self.started[seq] = self.sim.now();
            self.c.queue_wait.record(self.sim.now() - arrival);
            self.inflight += 1;
            run.start(&mut self.sim, &self.model);
            let done = run.done;
            self.jobs[seq] = Some(run);
            self.state[seq] = JobState::Running;
            if done {
                self.inflight -= 1;
                self.finalize(seq);
            }
        }
        Ok(())
    }

    /// Fold a finished job into the counters, free its admission slot,
    /// and — when the service has a shared store — persist its report
    /// manifest under the tenant's key prefix and roll its storage
    /// traffic into the tenant's metrics.
    fn finalize(&mut self, seq: usize) {
        self.state[seq] = JobState::Done;
        let run = self.jobs[seq].as_ref().expect("finalized job ran");
        let o = &self.meta[seq];
        finalize_job(run, o, self.started[seq], &mut self.c, &mut self.admission);
        if let Some(store) = &self.store {
            let tenant = o.spec.tenant.as_deref().unwrap_or("-");
            let body = run.report.to_json().to_string_compact().into_bytes();
            let m = self.tenant_storage.entry(tenant.to_string()).or_default();
            m.puts += 1;
            m.bytes_in += body.len() as u64;
            if let Some(load) = run.storage_load() {
                m.gets += load.shard_reads.iter().sum::<u64>();
                m.bytes_out += load.shard_bytes.iter().sum::<u64>();
            }
            store.put(&keys::tenant_report(tenant, seq), body);
        }
    }

    /// Process every simulated event strictly before `cutoff`
    /// (`None` = all of them), dispatching before each and ticking the
    /// autoscaler after each. Strict `<` implements the arrival-first
    /// tie rule: an event at exactly the next arrival's time is handled
    /// *after* that arrival is admitted.
    fn advance_before(&mut self, cutoff: Option<f64>) -> anyhow::Result<()> {
        loop {
            self.dispatch()?;
            match self.sim.peek_time() {
                Some(e) if cutoff.is_none_or(|v| e < v) => {
                    let comp = self.sim.step().expect("peeked event must pop");
                    let j = comp.job;
                    let run = self.jobs[j].as_mut().expect("completion routed to a live job");
                    run.on_completion(&mut self.sim, &self.model, &comp);
                    if run.done && self.state[j] != JobState::Done {
                        self.inflight -= 1;
                        self.finalize(j);
                    }
                    self.tick();
                }
                _ => return Ok(()),
            }
        }
    }

    fn tick(&mut self) {
        if let Some(az) = &mut self.autoscaler {
            let observation = FleetObservation {
                time: self.sim.now(),
                busy: self.sim.busy_workers(),
                queued_tasks: self.sim.queued_tasks(),
                queued_jobs: self.pending.len(),
                inflight_jobs: self.inflight,
                straggle_rate: self.c.straggle_rate(),
                death_rate: self.c.death_rate(),
            };
            az.tick(&mut self.sim, &observation);
        }
    }

    /// Feed the next arrival. Arrivals must come in seq order with
    /// non-decreasing times; `o.seq` is also the job's sim-stream fork
    /// index and its `JobRun` index.
    pub(crate) fn arrive(&mut self, o: Offered) -> anyhow::Result<()> {
        anyhow::ensure!(
            o.seq == self.meta.len(),
            "arrival out of order: got seq {}, expected {}",
            o.seq,
            self.meta.len()
        );
        self.advance_before(Some(o.arrival))?;
        let stream = self.root.fork(o.seq as u64);
        self.sim.advance_to(o.arrival);
        if let Some(i) = o.tenant {
            self.c.tenant[i].offered += 1;
        }
        let outcome = self.admission.admit(self.pending.len(), o.tenant);
        let state = match outcome {
            Ok(()) => {
                self.c.admitted += 1;
                if let Some(i) = o.tenant {
                    self.c.tenant[i].admitted += 1;
                }
                self.pending.push(Pending {
                    priority: o.spec.priority,
                    seq: o.seq,
                });
                JobState::Queued
            }
            Err(r @ Rejection::QueueFull) => {
                self.c.rejected_queue += 1;
                if let Some(i) = o.tenant {
                    self.c.tenant[i].rejected_queue += 1;
                }
                JobState::Rejected(r)
            }
            Err(r @ Rejection::TenantQuota) => {
                self.c.rejected_quota += 1;
                if let Some(i) = o.tenant {
                    self.c.tenant[i].rejected_quota += 1;
                }
                JobState::Rejected(r)
            }
        };
        self.streams.push(match state {
            JobState::Rejected(_) => None,
            _ => Some(stream),
        });
        self.meta.push(o);
        self.jobs.push(None);
        self.state.push(state);
        self.started.push(f64::NAN);
        self.tick();
        Ok(())
    }

    /// Advance the virtual clock through every event strictly before
    /// `v` — the daemon's between-submissions pump. A no-op for batch
    /// runs (the next `arrive` performs the same catch-up).
    pub(crate) fn pump_to(&mut self, v: f64) -> anyhow::Result<()> {
        self.advance_before(Some(v))
    }

    /// Run every remaining queued and in-flight job to completion.
    pub(crate) fn drain(&mut self) -> anyhow::Result<()> {
        self.advance_before(None)
    }

    /// After a drain, no job may be stranded.
    pub(crate) fn check_drained(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.pending.is_empty() && self.inflight == 0,
            "service '{}' stranded {} queued and {} running job(s)",
            self.sc.name,
            self.pending.len(),
            self.inflight
        );
        Ok(())
    }

    /// Current lifecycle state of one offered job (`None` = unknown
    /// seq).
    pub(crate) fn job_state(&self, seq: usize) -> Option<JobState> {
        self.state.get(seq).copied()
    }

    /// Status document of one offered job for the daemon's
    /// `GET /v1/jobs/<id>`: seq, state, arrival, tenant, and — once
    /// done — the full report with its finish time.
    pub(crate) fn job_json(&self, seq: usize) -> Option<Json> {
        let state = *self.state.get(seq)?;
        let o = &self.meta[seq];
        let mut doc = obj()
            .field("seq", seq)
            .field("status", state.wire())
            .field("arrival", o.arrival)
            .build();
        if let Some(t) = &o.spec.tenant {
            doc.set("tenant", Json::from(t.as_str()));
        }
        if state == JobState::Done {
            let run = self.jobs[seq].as_ref().expect("done job ran");
            let mut report = run.report.to_json();
            report.set("finish", Json::from(run.finish));
            doc.set("report", report);
        }
        doc
    }

    /// Quick counters for the daemon's `/metrics` endpoint.
    pub(crate) fn stats(&self) -> CoreStats {
        CoreStats {
            now: self.sim.now(),
            offered: self.meta.len() as u64,
            admitted: self.c.admitted,
            rejected_queue: self.c.rejected_queue,
            rejected_quota: self.c.rejected_quota,
            done: self.c.latency.count() as u64,
            queued: self.pending.len(),
            inflight: self.inflight,
            workers: self.sim.effective_capacity().unwrap_or(0),
            storage_faults: self.c.storage_faults,
        }
    }

    /// The run summary document (one entry of the service report's
    /// `runs` array). Callable mid-flight — the daemon's `/v1/report`
    /// summarizes whatever has finished so far; after `drain` it is the
    /// final batch-identical document.
    pub(crate) fn summary(&mut self) -> Json {
        let offered_total = self.meta.len() as u64;
        debug_assert_eq!(
            offered_total,
            self.c.admitted + self.c.rejected_queue + self.c.rejected_quota
        );
        let c = &mut self.c;
        let mut run = obj()
            .field("workers", self.workers)
            .field("offered", offered_total)
            .field("admitted", c.admitted)
            .field(
                "rejected",
                obj()
                    .field("queue_full", c.rejected_queue)
                    .field("tenant_quota", c.rejected_quota)
                    .build(),
            )
            .build();
        if !self.sc.tenants.is_empty() {
            let mut tenants = obj().build();
            for (t, tc) in self.sc.tenants.iter().zip(&c.tenant) {
                tenants.set(
                    &t.name,
                    obj()
                        .field("offered", tc.offered)
                        .field("admitted", tc.admitted)
                        .field("rejected_queue", tc.rejected_queue)
                        .field("rejected_quota", tc.rejected_quota)
                        .build(),
                );
            }
            run.set("tenants", tenants);
        }
        let mut schemes = obj().build();
        for (name, count) in &c.schemes {
            schemes.set(name, Json::from(*count));
        }
        run.set("schemes", schemes);
        run.set("latency", c.latency.to_json());
        run.set("queue_wait", c.queue_wait.to_json());
        run.set("service", c.service_time.to_json());
        if c.deadline_offered > 0 {
            run.set(
                "deadlines",
                obj()
                    .field("offered", c.deadline_offered)
                    .field("met", c.deadline_met)
                    .field("missed", c.deadline_offered - c.deadline_met)
                    .build(),
            );
        }
        if let Some(az) = &self.autoscaler {
            let spec = self.sc.autoscale.as_ref().expect("autoscaler implies spec");
            run.set(
                "fleet",
                obj()
                    .field("policy", az.policy_name())
                    .field("min_workers", spec.min_workers)
                    .field("max_workers", spec.max_workers)
                    .field("final", self.sim.effective_capacity().unwrap_or(0))
                    .field("scale_ups", az.scale_ups)
                    .field("scale_downs", az.scale_downs)
                    .field(
                        "trace",
                        Json::Arr(
                            az.trace
                                .iter()
                                .map(|&(t, n)| Json::Arr(vec![Json::from(t), Json::from(n)]))
                                .collect(),
                        ),
                    )
                    .build(),
            );
        }
        if c.faults.any {
            run.set(
                "faults",
                obj()
                    .field("deaths", c.faults.deaths)
                    .field("retries", c.faults.retries)
                    .field("exhausted", c.faults.exhausted)
                    .field("absorbed", c.faults.absorbed)
                    .field("degraded_jobs", c.faults.degraded_jobs)
                    .field("lost_workers", self.sim.lost_workers())
                    .build(),
            );
        }
        // Storage-fault rollup — appended, and only when some job
        // actually observed a fault event, so fault-free runs keep
        // their historical byte shape.
        if c.storage_faults_any {
            run.set("storage_faults", c.storage_faults.to_json());
        }
        // Shared-store rollup — appended, and only when the scenario
        // configures storage, so storage-less service goldens (the
        // whole pre-existing suite) keep their historical byte shape.
        if let (Some(store), Some(sp)) = (&self.store, &self.sc.storage) {
            let s = store.stats();
            let mut tenants = obj().build();
            for (name, m) in &self.tenant_storage {
                tenants.set(name, m.to_json());
            }
            run.set(
                "storage",
                obj()
                    .field("shards", sp.shards)
                    .field("objects", store.list("").len())
                    .field("puts", s.puts)
                    .field("gets", s.gets)
                    .field("bytes_in", s.bytes_in)
                    .field("bytes_out", s.bytes_out)
                    .field("tenants", tenants)
                    .build(),
            );
        }
        run
    }
}

/// Snapshot of a [`ServiceCore`]'s admission and fleet counters.
pub(crate) struct CoreStats {
    pub(crate) now: f64,
    pub(crate) offered: u64,
    pub(crate) admitted: u64,
    pub(crate) rejected_queue: u64,
    pub(crate) rejected_quota: u64,
    pub(crate) done: u64,
    pub(crate) queued: usize,
    pub(crate) inflight: usize,
    pub(crate) workers: usize,
    pub(crate) storage_faults: StorageFaultMetrics,
}

//! The coordinator service: a long-lived, multi-tenant job queue over
//! one shared worker fleet.
//!
//! Where `run_scenario`'s historical path executes a fixed `jobs` list,
//! the service accepts an *open-loop* stream of [`Offered`] jobs (a
//! Poisson arrival process over weighted templates, see
//! [`offered_jobs`]),
//! pushes each through admission control ([`AdmissionController`]:
//! queue-depth backpressure, then per-tenant in-flight quotas),
//! dispatches admitted jobs best-priority-first into a bounded number
//! of concurrent in-flight slots, and optionally drives a pluggable
//! [`AutoscalePolicy`] that resizes the shared fleet from the observed
//! dispatch backlog and fault rates.
//!
//! Admitted jobs run the *identical* `JobRun` pipeline state machine
//! as explicit-`jobs` scenarios — encode → compute → decode →
//! recompute — over one shared [`EventSim`]. The RNG contract also
//! carries over unchanged (DESIGN.md §Coordinator service): per-job
//! simulation streams are forked from `Pcg64::new(seed)` in arrival
//! order before anything runs, task durations are sampled at
//! submission, and the arrival process draws from a separately salted
//! stream — so every job's timeline is a pure function of `(seed,
//! arrival seq)`, and admission outcomes, pool size and autoscaling can
//! never shift a draw.

mod admission;
mod arrivals;
mod autoscale;

pub use admission::{AdmissionController, Rejection};
pub use arrivals::{offered_jobs, Offered};
pub use autoscale::{
    make_policy, AutoscalePolicy, Autoscaler, FaultAwarePolicy, FleetObservation,
    QueueDepthPolicy, POLICIES,
};

use std::collections::{BTreeMap, BinaryHeap};

use crate::coordinator::metrics::LatencyStats;
use crate::platform::event::{EventSim, Pool};
use crate::platform::scenario::{ArrivalSpec, JobRun, JobSpec, Scenario};
use crate::platform::straggler::{SlowdownDist, StragglerModel, StragglerParams, WorkerRates};
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg64;

/// Run a service scenario (one with an `arrivals` section): one service
/// lifetime per `workers` sweep entry, summarized in the same
/// golden-comparable document shape as `run_scenario`.
pub fn run_service(sc: &Scenario) -> anyhow::Result<Json> {
    let arr = sc
        .arrivals
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("run_service needs an 'arrivals' section"))?;
    let model = StragglerModel::new(sc.straggler, sc.rates);
    let offered = offered_jobs(sc, arr);
    let mut runs = Vec::with_capacity(sc.workers.len());
    for &workers in &sc.workers {
        runs.push(run_one(sc, arr, &offered, workers, &model)?);
    }
    Ok(obj()
        .field("scenario", sc.name.as_str())
        .field("seed", sc.seed)
        .field(
            "straggler",
            obj()
                .field(
                    "dist",
                    match sc.straggler.slow_dist {
                        SlowdownDist::LogNormal => "lognormal",
                        SlowdownDist::Pareto { .. } => "pareto",
                    },
                )
                .field("p", sc.straggler.p)
                .build(),
        )
        .field(
            "arrivals",
            obj()
                .field("jobs", arr.jobs)
                .field("rate_per_s", arr.rate_per_s)
                .build(),
        )
        .field("runs", Json::Arr(runs))
        .build())
}

/// Run one ad-hoc job through the service's single-job path (the
/// `slec submit` backend): a fresh bounded fleet, the default straggler
/// calibration unless overridden, and the standard report document.
pub fn submit_one(
    spec: &JobSpec,
    workers: usize,
    seed: u64,
    straggler: StragglerParams,
) -> anyhow::Result<Json> {
    let model = StragglerModel::new(straggler, WorkerRates::default());
    let mut sim = EventSim::new(Pool::from_option(Some(workers)));
    let mut root = Pcg64::new(seed);
    let mut run = JobRun::new(0, spec.clone(), None, None, None, root.fork(0))?;
    run.start(&mut sim, &model);
    while let Some(c) = sim.step() {
        run.on_completion(&mut sim, &model, &c);
    }
    anyhow::ensure!(run.done, "submitted job did not run to completion");
    let mut doc = run.report.to_json();
    doc.set("finish", Json::from(run.finish));
    Ok(doc)
}

/// Admission-queue entry: max-heap by priority, FIFO within a priority
/// level (smaller arrival seq pops first).
#[derive(PartialEq, Eq)]
struct Pending {
    priority: u32,
    seq: usize,
}

impl Ord for Pending {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.priority.cmp(&o.priority).then(o.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Pending {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}

#[derive(Default, Clone)]
struct TenantCounters {
    offered: u64,
    admitted: u64,
    rejected_queue: u64,
    rejected_quota: u64,
}

#[derive(Default)]
struct FaultAgg {
    deaths: u64,
    retries: u64,
    exhausted: u64,
    absorbed: u64,
    degraded_jobs: u64,
    any: bool,
}

struct Counters {
    admitted: u64,
    rejected_queue: u64,
    rejected_quota: u64,
    tenant: Vec<TenantCounters>,
    schemes: BTreeMap<String, u64>,
    latency: LatencyStats,
    queue_wait: LatencyStats,
    service_time: LatencyStats,
    deadline_offered: u64,
    deadline_met: u64,
    total_tasks: u64,
    total_stragglers: u64,
    faults: FaultAgg,
}

impl Counters {
    fn new(tenants: usize) -> Counters {
        Counters {
            admitted: 0,
            rejected_queue: 0,
            rejected_quota: 0,
            tenant: vec![TenantCounters::default(); tenants],
            schemes: BTreeMap::new(),
            latency: LatencyStats::new(),
            queue_wait: LatencyStats::new(),
            service_time: LatencyStats::new(),
            deadline_offered: 0,
            deadline_met: 0,
            total_tasks: 0,
            total_stragglers: 0,
            faults: FaultAgg::default(),
        }
    }

    fn rate(num: u64, den: u64) -> f64 {
        if den == 0 {
            0.0
        } else {
            num as f64 / den as f64
        }
    }

    fn straggle_rate(&self) -> f64 {
        Counters::rate(self.total_stragglers, self.total_tasks)
    }

    fn death_rate(&self) -> f64 {
        Counters::rate(self.faults.deaths, self.total_tasks)
    }
}

/// Fold one finished job into the run counters and free its admission
/// slot.
fn finalize_job(
    run: &JobRun,
    o: &Offered,
    started: f64,
    c: &mut Counters,
    admission: &mut AdmissionController,
) {
    admission.release(o.tenant);
    let latency = run.finish - o.arrival;
    c.latency.record(latency);
    c.service_time.record(run.finish - started);
    *c.schemes.entry(run.report.scheme.clone()).or_insert(0) += 1;
    if let Some(d) = run.spec.deadline_s {
        c.deadline_offered += 1;
        if latency <= d {
            c.deadline_met += 1;
        }
    }
    let r = &run.report;
    c.total_tasks += (r.enc.tasks + r.comp.tasks + r.dec.tasks) as u64;
    c.total_stragglers += (r.enc.stragglers + r.comp.stragglers + r.dec.stragglers) as u64;
    if let Some(f) = &r.faults {
        c.faults.any = true;
        c.faults.deaths += f.deaths;
        c.faults.retries += f.retries;
        c.faults.exhausted += f.exhausted;
        c.faults.absorbed += f.absorbed;
        c.faults.degraded_jobs += f.degraded as u64;
    }
}

/// One service lifetime over one initial fleet size.
fn run_one(
    sc: &Scenario,
    arr: &ArrivalSpec,
    offered: &[Offered],
    workers: usize,
    model: &StragglerModel,
) -> anyhow::Result<Json> {
    let mut sim = EventSim::new(Pool::from_option(Some(workers)));
    // Per-job sim streams, forked in arrival order before anything runs
    // — the explicit-`jobs` runner's rule with "job index" read as
    // "arrival seq". Rejected jobs' streams are forked and discarded,
    // so admission outcomes cannot shift any other job's draws.
    let mut root = Pcg64::new(sc.seed);
    let mut streams: Vec<Option<Pcg64>> =
        (0..offered.len()).map(|i| Some(root.fork(i as u64))).collect();
    let mut admission = AdmissionController::new(arr, &sc.tenants);
    let mut autoscaler = match &sc.autoscale {
        Some(a) => Some(Autoscaler::new(a, workers)?),
        None => None,
    };
    let mut jobs: Vec<Option<JobRun>> = Vec::new();
    jobs.resize_with(offered.len(), || None);
    let mut finalized = vec![false; offered.len()];
    let mut started = vec![f64::NAN; offered.len()];
    let mut pending: BinaryHeap<Pending> = BinaryHeap::new();
    let mut inflight = 0usize;
    let mut next_arrival = 0usize;
    let mut c = Counters::new(sc.tenants.len());

    loop {
        // Dispatch admitted jobs into free in-flight slots, best
        // priority first.
        while (arr.max_inflight == 0 || inflight < arr.max_inflight) && !pending.is_empty() {
            let seq = pending.pop().expect("checked non-empty").seq;
            let o = &offered[seq];
            let rng = streams[seq].take().expect("admitted job keeps its stream");
            let mut run = JobRun::new(
                seq,
                o.spec.clone(),
                sc.storage.as_ref(),
                sc.failures.as_ref(),
                sc.progress.as_ref(),
                rng,
            )?;
            started[seq] = sim.now();
            c.queue_wait.record(sim.now() - o.arrival);
            inflight += 1;
            run.start(&mut sim, model);
            let done = run.done;
            jobs[seq] = Some(run);
            if done {
                finalized[seq] = true;
                inflight -= 1;
                finalize_job(
                    jobs[seq].as_ref().expect("just stored"),
                    o,
                    started[seq],
                    &mut c,
                    &mut admission,
                );
            }
        }

        // Next cause: arrival or completion, arrival-first on ties —
        // the same merge rule as the explicit-`jobs` runner.
        let next_ev = sim.peek_time();
        let next_arr = (next_arrival < offered.len()).then(|| offered[next_arrival].arrival);
        match (next_arr, next_ev) {
            (Some(a), e) if e.is_none_or(|e| a <= e) => {
                let o = &offered[next_arrival];
                next_arrival += 1;
                sim.advance_to(a);
                if let Some(i) = o.tenant {
                    c.tenant[i].offered += 1;
                }
                match admission.admit(pending.len(), o.tenant) {
                    Ok(()) => {
                        c.admitted += 1;
                        if let Some(i) = o.tenant {
                            c.tenant[i].admitted += 1;
                        }
                        pending.push(Pending {
                            priority: o.spec.priority,
                            seq: o.seq,
                        });
                    }
                    Err(Rejection::QueueFull) => {
                        c.rejected_queue += 1;
                        if let Some(i) = o.tenant {
                            c.tenant[i].rejected_queue += 1;
                        }
                        streams[o.seq] = None;
                    }
                    Err(Rejection::TenantQuota) => {
                        c.rejected_quota += 1;
                        if let Some(i) = o.tenant {
                            c.tenant[i].rejected_quota += 1;
                        }
                        streams[o.seq] = None;
                    }
                }
            }
            (_, Some(_)) => {
                let comp = sim.step().expect("peeked event must pop");
                let j = comp.job;
                let run = jobs[j].as_mut().expect("completion routed to a live job");
                run.on_completion(&mut sim, model, &comp);
                if run.done && !finalized[j] {
                    finalized[j] = true;
                    inflight -= 1;
                    finalize_job(run, &offered[j], started[j], &mut c, &mut admission);
                }
            }
            (None, None) => break,
        }

        if let Some(az) = &mut autoscaler {
            let observation = FleetObservation {
                time: sim.now(),
                busy: sim.busy_workers(),
                queued_tasks: sim.queued_tasks(),
                queued_jobs: pending.len(),
                inflight_jobs: inflight,
                straggle_rate: c.straggle_rate(),
                death_rate: c.death_rate(),
            };
            az.tick(&mut sim, &observation);
        }
    }

    anyhow::ensure!(
        pending.is_empty() && inflight == 0,
        "service '{}' stranded {} queued and {} running job(s)",
        sc.name,
        pending.len(),
        inflight
    );

    let offered_total = offered.len() as u64;
    debug_assert_eq!(
        offered_total,
        c.admitted + c.rejected_queue + c.rejected_quota
    );
    let mut run = obj()
        .field("workers", workers)
        .field("offered", offered_total)
        .field("admitted", c.admitted)
        .field(
            "rejected",
            obj()
                .field("queue_full", c.rejected_queue)
                .field("tenant_quota", c.rejected_quota)
                .build(),
        )
        .build();
    if !sc.tenants.is_empty() {
        let mut tenants = obj().build();
        for (t, tc) in sc.tenants.iter().zip(&c.tenant) {
            tenants.set(
                &t.name,
                obj()
                    .field("offered", tc.offered)
                    .field("admitted", tc.admitted)
                    .field("rejected_queue", tc.rejected_queue)
                    .field("rejected_quota", tc.rejected_quota)
                    .build(),
            );
        }
        run.set("tenants", tenants);
    }
    let mut schemes = obj().build();
    for (name, count) in &c.schemes {
        schemes.set(name, Json::from(*count));
    }
    run.set("schemes", schemes);
    run.set("latency", c.latency.to_json());
    run.set("queue_wait", c.queue_wait.to_json());
    run.set("service", c.service_time.to_json());
    if c.deadline_offered > 0 {
        run.set(
            "deadlines",
            obj()
                .field("offered", c.deadline_offered)
                .field("met", c.deadline_met)
                .field("missed", c.deadline_offered - c.deadline_met)
                .build(),
        );
    }
    if let Some(az) = &autoscaler {
        let spec = sc.autoscale.as_ref().expect("autoscaler implies spec");
        run.set(
            "fleet",
            obj()
                .field("policy", az.policy_name())
                .field("min_workers", spec.min_workers)
                .field("max_workers", spec.max_workers)
                .field("final", sim.effective_capacity().unwrap_or(0))
                .field("scale_ups", az.scale_ups)
                .field("scale_downs", az.scale_downs)
                .field(
                    "trace",
                    Json::Arr(
                        az.trace
                            .iter()
                            .map(|&(t, n)| Json::Arr(vec![Json::from(t), Json::from(n)]))
                            .collect(),
                    ),
                )
                .build(),
        );
    }
    if c.faults.any {
        run.set(
            "faults",
            obj()
                .field("deaths", c.faults.deaths)
                .field("retries", c.faults.retries)
                .field("exhausted", c.faults.exhausted)
                .field("absorbed", c.faults.absorbed)
                .field("degraded_jobs", c.faults.degraded_jobs)
                .field("lost_workers", sim.lost_workers())
                .build(),
        );
    }
    Ok(run)
}

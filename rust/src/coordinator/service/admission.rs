//! Admission control: queue-depth backpressure and per-tenant
//! in-flight quotas, with typed rejections so the service report can
//! break refusals down by cause.

use crate::platform::scenario::{ArrivalSpec, TenantSpec};

/// Why an offered job was turned away.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rejection {
    /// The admission queue is at `arrivals.queue_depth`.
    QueueFull,
    /// The job's tenant is at its in-flight quota.
    TenantQuota,
}

/// Per-run admission book-keeping. Checks are ordered: queue-depth
/// backpressure first (it protects the coordinator itself), the
/// tenant's quota second — so a full queue never charges a tenant's
/// quota accounting.
#[derive(Debug)]
pub struct AdmissionController {
    queue_depth: usize,
    /// Admitted-but-unfinished jobs per tenant (queued + running).
    load: Vec<usize>,
    quotas: Vec<usize>,
}

impl AdmissionController {
    pub fn new(arr: &ArrivalSpec, tenants: &[TenantSpec]) -> AdmissionController {
        AdmissionController {
            queue_depth: arr.queue_depth,
            load: vec![0; tenants.len()],
            quotas: tenants.iter().map(|t| t.quota).collect(),
        }
    }

    /// Decide one arrival. `queued` is the current admission-queue
    /// length; `tenant` indexes the scenario's tenants. On `Ok` the
    /// tenant's in-flight load is charged — release it with
    /// [`AdmissionController::release`] when the job leaves the system.
    pub fn admit(&mut self, queued: usize, tenant: Option<usize>) -> Result<(), Rejection> {
        if self.queue_depth > 0 && queued >= self.queue_depth {
            return Err(Rejection::QueueFull);
        }
        if let Some(i) = tenant {
            if self.quotas[i] > 0 && self.load[i] >= self.quotas[i] {
                return Err(Rejection::TenantQuota);
            }
            self.load[i] += 1;
        }
        Ok(())
    }

    /// An admitted job finished: free its tenant's quota slot.
    pub fn release(&mut self, tenant: Option<usize>) {
        if let Some(i) = tenant {
            self.load[i] -= 1;
        }
    }

    /// Current in-flight load of one tenant.
    pub fn load(&self, tenant: usize) -> usize {
        self.load[tenant]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arr(queue_depth: usize) -> ArrivalSpec {
        ArrivalSpec {
            jobs: 1,
            rate_per_s: 1.0,
            templates: Vec::new(),
            queue_depth,
            max_inflight: 0,
        }
    }

    fn tenants(quotas: &[usize]) -> Vec<TenantSpec> {
        quotas
            .iter()
            .enumerate()
            .map(|(i, &q)| TenantSpec {
                name: format!("t{i}"),
                weight: 1.0,
                quota: q,
            })
            .collect()
    }

    #[test]
    fn queue_depth_backpressure() {
        let mut ac = AdmissionController::new(&arr(2), &[]);
        assert_eq!(ac.admit(0, None), Ok(()));
        assert_eq!(ac.admit(1, None), Ok(()));
        assert_eq!(ac.admit(2, None), Err(Rejection::QueueFull));
        // 0 = unbounded.
        let mut open = AdmissionController::new(&arr(0), &[]);
        assert_eq!(open.admit(10_000, None), Ok(()));
    }

    #[test]
    fn tenant_quota_charges_and_releases() {
        let mut ac = AdmissionController::new(&arr(0), &tenants(&[2, 0]));
        assert_eq!(ac.admit(0, Some(0)), Ok(()));
        assert_eq!(ac.admit(0, Some(0)), Ok(()));
        assert_eq!(ac.admit(0, Some(0)), Err(Rejection::TenantQuota));
        assert_eq!(ac.load(0), 2, "a rejected arrival is not charged");
        ac.release(Some(0));
        assert_eq!(ac.admit(0, Some(0)), Ok(()));
        // Quota 0 = unlimited.
        for _ in 0..100 {
            assert_eq!(ac.admit(0, Some(1)), Ok(()));
        }
    }

    #[test]
    fn full_queue_outranks_quota() {
        // Check order: with both limits breached, the rejection is
        // QueueFull and the tenant's quota stays untouched.
        let mut ac = AdmissionController::new(&arr(1), &tenants(&[1]));
        assert_eq!(ac.admit(0, Some(0)), Ok(()));
        assert_eq!(ac.admit(1, Some(0)), Err(Rejection::QueueFull));
        assert_eq!(ac.load(0), 1);
    }
}

//! Pluggable fleet autoscaling for the coordinator service.
//!
//! Policies are pure target functions over a [`FleetObservation`]; the
//! [`Autoscaler`] owns the mechanics every policy shares — cooldown,
//! `[min, max]` clamping, per-decision step limiting, the fleet-size
//! trace — and applies decisions through
//! [`EventSim::set_capacity`]. Resizing never touches the RNG (task
//! durations are sampled at submission), so autoscaled runs keep the
//! same draw sequence as fixed-fleet runs and stay bit-reproducible.

use crate::platform::event::EventSim;
use crate::platform::scenario::AutoscaleSpec;

/// What a policy sees at each decision point.
#[derive(Debug, Clone, Copy, Default)]
pub struct FleetObservation {
    /// Current virtual time.
    pub time: f64,
    /// Workers running a task right now.
    pub busy: usize,
    /// Tasks submitted but waiting for a worker.
    pub queued_tasks: usize,
    /// Jobs admitted but not yet dispatched.
    pub queued_jobs: usize,
    /// Jobs currently running phases.
    pub inflight_jobs: usize,
    /// Stragglers per finished task so far (0 until jobs finish).
    pub straggle_rate: f64,
    /// Worker deaths per finished task so far.
    pub death_rate: f64,
}

/// A fleet-sizing policy: given an observation and the current
/// effective fleet, return the desired effective fleet. The caller
/// clamps to `[min_workers, max_workers]` and step-limits.
pub trait AutoscalePolicy {
    fn name(&self) -> &'static str;
    fn target(&self, obs: &FleetObservation, cur: usize, spec: &AutoscaleSpec) -> usize;
}

/// Grow when the dispatch backlog exceeds `scale_up_queue` tasks per
/// worker (to the size that restores that ratio); shrink toward the
/// live demand when busy + queued tasks fall below `scale_down_busy` of
/// the fleet.
pub struct QueueDepthPolicy;

impl AutoscalePolicy for QueueDepthPolicy {
    fn name(&self) -> &'static str {
        "queue-depth"
    }

    fn target(&self, obs: &FleetObservation, cur: usize, spec: &AutoscaleSpec) -> usize {
        let backlog = obs.queued_tasks as f64;
        let demand = obs.busy + obs.queued_tasks;
        if backlog > spec.scale_up_queue * cur as f64 {
            (backlog / spec.scale_up_queue).ceil() as usize
        } else if (demand as f64) < spec.scale_down_busy * cur as f64 {
            demand.max(1)
        } else {
            cur
        }
    }
}

/// [`QueueDepthPolicy`] with fault awareness: growth targets are
/// inflated by the observed straggle and death rates (headroom for
/// re-dispatch), and the fleet refuses to shrink while workers are
/// dying faster than 5 deaths per 100 tasks.
pub struct FaultAwarePolicy;

impl AutoscalePolicy for FaultAwarePolicy {
    fn name(&self) -> &'static str {
        "fault-aware"
    }

    fn target(&self, obs: &FleetObservation, cur: usize, spec: &AutoscaleSpec) -> usize {
        let base = QueueDepthPolicy.target(obs, cur, spec);
        if base > cur {
            (base as f64 * (1.0 + obs.straggle_rate + obs.death_rate)).ceil() as usize
        } else if base < cur && obs.death_rate > 0.05 {
            cur
        } else {
            base
        }
    }
}

/// Policy names accepted by the `autoscale.policy` scenario key, in
/// default-first order — `parse_autoscale` validates against this list
/// so a typo fails at parse time.
pub const POLICIES: [&str; 2] = ["queue-depth", "fault-aware"];

/// Instantiate a policy by registry name.
pub fn make_policy(name: &str) -> anyhow::Result<Box<dyn AutoscalePolicy>> {
    match name {
        "queue-depth" => Ok(Box::new(QueueDepthPolicy)),
        "fault-aware" => Ok(Box::new(FaultAwarePolicy)),
        other => anyhow::bail!(
            "unknown autoscale policy '{other}' (known: {})",
            POLICIES.join(", ")
        ),
    }
}

/// The shared scaling mechanics around a policy.
pub struct Autoscaler {
    spec: AutoscaleSpec,
    policy: Box<dyn AutoscalePolicy>,
    last_decision: f64,
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// `(virtual time, effective fleet size)` after every change,
    /// seeded with the starting size at t = 0.
    pub trace: Vec<(f64, usize)>,
}

impl Autoscaler {
    pub fn new(spec: &AutoscaleSpec, initial: usize) -> anyhow::Result<Autoscaler> {
        Ok(Autoscaler {
            policy: make_policy(&spec.policy)?,
            spec: spec.clone(),
            last_decision: 0.0,
            scale_ups: 0,
            scale_downs: 0,
            trace: vec![(0.0, initial)],
        })
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// One decision point. No-op inside the cooldown window or when the
    /// (clamped, step-limited) target equals the current fleet.
    /// Applies the change as *effective* capacity: injected worker
    /// losses are replaced on top of the target, so a death does not
    /// silently eat a scaling decision.
    pub fn tick(&mut self, sim: &mut EventSim, obs: &FleetObservation) {
        if obs.time - self.last_decision < self.spec.cooldown_s {
            return;
        }
        let cur = sim
            .effective_capacity()
            .expect("autoscale requires a bounded pool");
        let clamped = self
            .policy
            .target(obs, cur, &self.spec)
            .clamp(self.spec.min_workers, self.spec.max_workers);
        let next = if clamped > cur {
            cur + (clamped - cur).min(self.spec.step)
        } else {
            cur - (cur - clamped).min(self.spec.step)
        };
        if next == cur {
            return;
        }
        self.last_decision = obs.time;
        if next > cur {
            self.scale_ups += 1;
        } else {
            self.scale_downs += 1;
        }
        sim.set_capacity(next + sim.lost_workers());
        self.trace.push((obs.time, next));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::event::Pool;

    fn spec() -> AutoscaleSpec {
        AutoscaleSpec {
            policy: "queue-depth".into(),
            min_workers: 2,
            max_workers: 32,
            step: 4,
            cooldown_s: 10.0,
            scale_up_queue: 2.0,
            scale_down_busy: 0.5,
        }
    }

    fn obs(time: f64, busy: usize, queued_tasks: usize) -> FleetObservation {
        FleetObservation {
            time,
            busy,
            queued_tasks,
            ..Default::default()
        }
    }

    #[test]
    fn queue_depth_policy_targets() {
        let s = spec();
        let p = QueueDepthPolicy;
        // Backlog of 20 over 4 workers at 2-per-worker → 10 workers.
        assert_eq!(p.target(&obs(0.0, 4, 20), 4, &s), 10);
        // Backlog within threshold, demand healthy → hold.
        assert_eq!(p.target(&obs(0.0, 4, 6), 4, &s), 4);
        // Demand (1) below half the fleet → shrink to demand.
        assert_eq!(p.target(&obs(0.0, 1, 0), 8, &s), 1);
        // Idle fleet never targets zero.
        assert_eq!(p.target(&obs(0.0, 0, 0), 8, &s), 1);
    }

    #[test]
    fn fault_aware_inflates_growth_and_blocks_shrink_under_churn() {
        let s = spec();
        let p = FaultAwarePolicy;
        let mut o = obs(0.0, 4, 20);
        o.straggle_rate = 0.2;
        o.death_rate = 0.3;
        // queue-depth says 10; inflated by 1.5 → 15.
        assert_eq!(p.target(&o, 4, &s), 15);
        // Shrink blocked while deaths are hot…
        let mut idle = obs(0.0, 1, 0);
        idle.death_rate = 0.2;
        assert_eq!(p.target(&idle, 8, &s), 8);
        // …and allowed once the fleet is calm.
        idle.death_rate = 0.0;
        assert_eq!(p.target(&idle, 8, &s), 1);
    }

    #[test]
    fn autoscaler_clamps_steps_and_cools_down() {
        let s = spec();
        let mut sim = EventSim::new(Pool::Workers(4));
        let mut az = Autoscaler::new(&s, 4).unwrap();
        // Huge backlog: target clamps to 32 but the step caps one
        // decision at +4.
        az.tick(&mut sim, &obs(10.0, 4, 1000));
        assert_eq!(sim.capacity(), Some(8));
        // Inside the cooldown window: no second decision.
        az.tick(&mut sim, &obs(15.0, 8, 1000));
        assert_eq!(sim.capacity(), Some(8));
        // Past the cooldown: next step fires.
        az.tick(&mut sim, &obs(21.0, 8, 1000));
        assert_eq!(sim.capacity(), Some(12));
        assert_eq!(az.scale_ups, 2);
        assert_eq!(az.scale_downs, 0);
        assert_eq!(az.trace, vec![(0.0, 4), (10.0, 8), (21.0, 12)]);
        // Idle fleet shrinks, clamped at min_workers by enough ticks.
        let mut t = 31.0;
        while sim.capacity() != Some(2) && t < 200.0 {
            az.tick(&mut sim, &obs(t, 0, 0));
            t += 10.0;
        }
        assert_eq!(sim.capacity(), Some(2));
        assert!(az.scale_downs >= 2);
    }

    #[test]
    fn unknown_policy_is_an_error_naming_the_registry() {
        let err = make_policy("queue-dpeth").unwrap_err().to_string();
        assert!(err.contains("queue-depth, fault-aware"), "{err}");
    }
}

//! The coded matrix-multiplication workflow — the paper's Fig-2 pipeline
//! (`f_enc → f_comp → f_dec`, all phases on simulated serverless workers)
//! for every scheme: local product codes (the contribution), speculative
//! execution, uncoded, global-parity product codes, polynomial codes.
//!
//! Virtual time and real numerics advance together: the straggler model
//! decides *which* output blocks arrive before the earliest-decodable
//! cutoff, and the decode phase must then *really* reconstruct the missing
//! blocks from parities (through the compute backend, i.e. the PJRT
//! artifacts) — so every simulated run is also an end-to-end numerical
//! test against `A·Bᵀ`.

use std::sync::Arc;

use crate::codes::local_product::LocalProductCode;
use crate::codes::peeling::plan_peel;
use crate::codes::polynomial::PolynomialCode;
use crate::codes::product::ProductCode;
use crate::codes::Scheme;
use crate::coordinator::metrics::JobReport;
use crate::linalg::blocked::{assemble_grid, GridShape, Partition};
use crate::linalg::matrix::Matrix;
use crate::platform::{launch, recompute_round, speculative, StragglerModel, WorkProfile};
use crate::runtime::ComputeBackend;
use crate::storage::{keys, InMemoryStore};
use crate::util::rng::Pcg64;
use crate::util::threadpool::{num_threads, parallel_map};

/// Shared execution environment.
pub struct Env {
    pub backend: Arc<dyn ComputeBackend>,
    pub store: Arc<InMemoryStore>,
    pub model: StragglerModel,
    /// Host threads used to execute the real numerics.
    pub threads: usize,
}

impl Env {
    /// Host-backend environment with default platform calibration.
    pub fn host() -> Env {
        Env {
            backend: Arc::new(crate::runtime::HostBackend),
            store: Arc::new(InMemoryStore::new()),
            model: StragglerModel::new(Default::default(), Default::default()),
            threads: num_threads(),
        }
    }

    /// Environment with an explicit backend (e.g. PJRT).
    pub fn with_backend(backend: Arc<dyn ComputeBackend>) -> Env {
        Env {
            backend,
            store: Arc::new(InMemoryStore::new()),
            model: StragglerModel::new(Default::default(), Default::default()),
            threads: num_threads(),
        }
    }
}

/// A coded matmul job description (`C = A·Bᵀ`).
#[derive(Debug, Clone)]
pub struct MatmulJob {
    /// Systematic row-blocks of A / B.
    pub s_a: usize,
    pub s_b: usize,
    pub scheme: Scheme,
    /// Parallel decoding workers (Remark 3).
    pub decode_workers: usize,
    /// Parallel encoding workers (Remark 1: encoding is column-sliced
    /// across a small worker fleet, <10% of the compute phase; 0 ⇒ auto =
    /// ceil(compute_tasks / 10)).
    pub encode_workers: usize,
    /// Verify the output against the direct product (costs a host GEMM).
    pub verify: bool,
    pub seed: u64,
    /// Unique job id for store keys.
    pub job_id: String,
    /// Full-matrix dims `(rows_a, k, rows_b)` used for the *virtual-time*
    /// work profiles. `None` ⇒ the actual matrix dims. Figure harnesses
    /// set this to the PAPER's scale (e.g. 0.5M) so simulated seconds are
    /// comparable to the paper's plots while the verified numerics run at
    /// lab scale (DESIGN.md §Virtual-time model).
    pub virtual_dims: Option<(usize, usize, usize)>,
}

impl Default for MatmulJob {
    fn default() -> Self {
        MatmulJob {
            s_a: 4,
            s_b: 4,
            scheme: Scheme::LocalProduct { l_a: 2, l_b: 2 },
            decode_workers: 4,
            encode_workers: 0,
            verify: true,
            seed: 0,
            job_id: "job".into(),
            virtual_dims: None,
        }
    }
}

impl MatmulJob {
    /// Virtual-time dims for profile building.
    fn vdims(&self, a: &Matrix, b: &Matrix) -> (usize, usize, usize) {
        self.virtual_dims.unwrap_or((a.rows, a.cols, b.rows))
    }

    /// Encode fleet size (Remark 1): explicit or ~10% of compute tasks.
    fn encode_fleet(&self, compute_tasks: usize) -> usize {
        if self.encode_workers > 0 {
            self.encode_workers
        } else {
            compute_tasks.div_ceil(10).max(1)
        }
    }
}

/// Column-sliced encode-phase profile: the side's parities total
/// `groups·l` block-reads of `block_rows × k` each; `fleet` workers split
/// the columns evenly, each writing its slice of every parity.
fn sliced_encode_profile(
    groups: usize,
    l: usize,
    block_rows: usize,
    k: usize,
    fleet: usize,
) -> WorkProfile {
    let total_read = (groups * l * block_rows * k * 4) as u64;
    let total_write = (groups * block_rows * k * 4) as u64;
    WorkProfile {
        bytes_read: total_read / fleet as u64,
        // Ranged GETs, split across the fleet like the bytes.
        read_ops: (groups * l).div_ceil(fleet) as u64,
        flops: (groups * (l - 1).max(1) * block_rows * k) as f64 / fleet as f64,
        bytes_written: total_write / fleet as u64,
        write_ops: groups.div_ceil(fleet) as u64,
    }
}

/// Run the job; returns the output matrix and the phase report.
pub fn run_matmul(env: &Env, a: &Matrix, b: &Matrix, job: &MatmulJob) -> anyhow::Result<(Matrix, JobReport)> {
    anyhow::ensure!(a.cols == b.cols, "A (m×n) · Bᵀ needs matching n");
    anyhow::ensure!(a.rows % job.s_a == 0, "A rows must divide s_a");
    anyhow::ensure!(b.rows % job.s_b == 0, "B rows must divide s_b");
    let mut rng = Pcg64::new(job.seed);

    let (c, mut report) = match job.scheme {
        Scheme::Uncoded => run_uncoded(env, a, b, job, &mut rng, None)?,
        Scheme::Speculative { wait_frac } => {
            run_uncoded(env, a, b, job, &mut rng, Some(wait_frac))?
        }
        Scheme::LocalProduct { l_a, l_b } => run_local_product(env, a, b, job, l_a, l_b, &mut rng)?,
        Scheme::Product { t_a, t_b } => run_product(env, a, b, job, t_a, t_b, &mut rng)?,
        Scheme::Polynomial { redundancy } => run_polynomial(env, a, b, job, redundancy, &mut rng)?,
    };

    if job.verify && report.numerics_ok {
        let direct = env.backend.block_product(a, b);
        report.rel_err = c.rel_err(&direct);
    }
    Ok((c, report))
}

// ---------------------------------------------------------------------------
// Uncoded / speculative
// ---------------------------------------------------------------------------

fn run_uncoded(
    env: &Env,
    a: &Matrix,
    b: &Matrix,
    job: &MatmulJob,
    rng: &mut Pcg64,
    wait_frac: Option<f64>,
) -> anyhow::Result<(Matrix, JobReport)> {
    let mut report = JobReport::new(if wait_frac.is_some() {
        "speculative"
    } else {
        "uncoded"
    });
    let pa = Partition::new(a.rows, a.cols, job.s_a);
    let pb = Partition::new(b.rows, b.cols, job.s_b);
    let a_blocks = pa.split(a);
    let b_blocks = pb.split(b);

    // Virtual compute phase over s_a × s_b tasks (profiles at virtual dims).
    let (vm, vk, vl) = job.vdims(a, b);
    let profile = WorkProfile::block_product(vm / job.s_a, vk, vl / job.s_b);
    let n_tasks = job.s_a * job.s_b;
    let phase = launch(&env.model, &profile, n_tasks, rng);
    report.comp.tasks = n_tasks;
    report.comp.stragglers = phase.straggled.iter().filter(|&&s| s).count();
    report.comp.virtual_secs = match wait_frac {
        None => phase.wait_all(),
        Some(f) => {
            let out = speculative(&env.model, &profile, &phase, f, rng);
            report.comp.relaunched = out.relaunched;
            out.makespan
        }
    };

    // Numerics: every block is eventually computed.
    let blocks = compute_products(env, &a_blocks, &b_blocks, |_i, _j| true);
    let shape = GridShape { rows: job.s_a, cols: job.s_b };
    let c = assemble_grid(shape, &blocks.into_iter().map(Option::unwrap).collect::<Vec<_>>());
    Ok((c, report))
}

// ---------------------------------------------------------------------------
// Local product code (the paper's scheme)
// ---------------------------------------------------------------------------

fn run_local_product(
    env: &Env,
    a: &Matrix,
    b: &Matrix,
    job: &MatmulJob,
    l_a: usize,
    l_b: usize,
    rng: &mut Pcg64,
) -> anyhow::Result<(Matrix, JobReport)> {
    anyhow::ensure!(job.s_a % l_a == 0, "s_a ({}) % l_a ({l_a}) != 0", job.s_a);
    anyhow::ensure!(job.s_b % l_b == 0, "s_b ({}) % l_b ({l_b}) != 0", job.s_b);
    let mut report = JobReport::new("local-product");
    let code = LocalProductCode::new(job.s_a, l_a, job.s_b, l_b);
    report.redundancy = code.redundancy();

    let pa = Partition::new(a.rows, a.cols, job.s_a);
    let pb = Partition::new(b.rows, b.cols, job.s_b);
    let a_blocks = pa.split(a);
    let b_blocks = pb.split(b);

    // --- Encode phase: column-sliced across a small fleet (Remark 1),
    // straggler-protected by speculative relaunch.
    let (vm, vk, vl) = job.vdims(a, b);
    let (ra, rb) = code.coded_grid();
    let fleet = job.encode_fleet(ra * rb);
    let enc_profile_a = sliced_encode_profile(
        code.a.groups() + code.b.groups(),
        l_a.max(l_b),
        vm / job.s_a,
        vk,
        fleet,
    );
    let enc_phase = launch(&env.model, &enc_profile_a, fleet, rng);
    let enc_out = speculative(&env.model, &enc_profile_a, &enc_phase, 0.95, rng);
    report.enc.tasks = fleet;
    report.enc.stragglers = enc_phase.straggled.iter().filter(|&&s| s).count();
    report.enc.relaunched = enc_out.relaunched;
    report.enc.virtual_secs = enc_out.makespan;
    report.enc.blocks_read = l_a * code.a.groups() + l_b * code.b.groups();

    // Numerics: encode both sides through the backend, stash in the store
    // (the serverless dataflow — workers exchange blocks via storage).
    let backend = &env.backend;
    let a_coded = encode_side_numeric(backend.as_ref(), code.a, &a_blocks);
    let b_coded = encode_side_numeric(backend.as_ref(), code.b, &b_blocks);
    for (i, blk) in a_coded.iter().enumerate() {
        crate::storage::put_matrix(env.store.as_ref(), &keys::coded_block(&job.job_id, "a", i), blk);
    }
    for (j, blk) in b_coded.iter().enumerate() {
        crate::storage::put_matrix(env.store.as_ref(), &keys::coded_block(&job.job_id, "b", j), blk);
    }

    // --- Compute phase: (ra × rb) coded block products; terminate at the
    // earliest virtual time every local grid is peeling-decodable.
    let profile = WorkProfile::block_product(vm / job.s_a, vk, vl / job.s_b);
    let phase = launch(&env.model, &profile, ra * rb, rng);
    report.comp.tasks = ra * rb;
    report.comp.stragglers = phase.straggled.iter().filter(|&&s| s).count();

    let (ga, gb) = code.groups();
    let grid_of = |cell: usize| -> usize {
        let (r, c) = (cell / rb, cell % rb);
        (r / (l_a + 1)) * gb + (c / (l_b + 1))
    };
    let mut arrived = vec![false; ra * rb];
    let mut pending: std::collections::BTreeSet<usize> = (0..ga * gb).collect();
    let mut t_comp = 0.0;
    for &cell in &phase.arrival_order() {
        arrived[cell] = true;
        t_comp = phase.finish[cell];
        let g = grid_of(cell);
        if pending.contains(&g) && grid_decodable(&code, g, &arrived, rb) {
            pending.remove(&g);
        }
        if pending.is_empty() {
            break;
        }
    }
    report.comp.virtual_secs = t_comp;

    // Numerics: compute the arrived products only. The rest are the
    // stragglers decode must reconstruct.
    let mut grid: Vec<Option<Matrix>> = {
        let arrived_ref = &arrived;
        let a_ref = &a_coded;
        let b_ref = &b_coded;
        parallel_map(env.threads, ra * rb, move |cell| {
            if arrived_ref[cell] {
                let (i, j) = (cell / rb, cell % rb);
                Some(env.backend.block_product(&a_ref[i], &b_ref[j]))
            } else {
                None
            }
        })
    };

    // --- Decode phase: decode workers peel their grids in parallel.
    let missing_before = grid.iter().filter(|c| c.is_none()).count();
    let mut plans = Vec::with_capacity(ga * gb);
    for gi in 0..ga {
        for gj in 0..gb {
            // Extract local grid, decode numerically, write back.
            let mut cells: Vec<Option<Matrix>> = Vec::with_capacity((l_a + 1) * (l_b + 1));
            for r in 0..=l_a {
                for c in 0..=l_b {
                    let (cr, cc) = code.grid_cell(gi, gj, r, c);
                    cells.push(grid[cr * rb + cc].take());
                }
            }
            let plan = decode_numeric(env.backend.as_ref(), l_a, l_b, &mut cells);
            let mut it = cells.into_iter();
            for r in 0..=l_a {
                for c in 0..=l_b {
                    let (cr, cc) = code.grid_cell(gi, gj, r, c);
                    grid[cr * rb + cc] = it.next().unwrap();
                }
            }
            plans.push(plan);
        }
    }

    // Virtual decode time: grids round-robin over decode workers; each
    // worker's time is sampled from its aggregate read/write profile.
    let out_bytes = ((vm / job.s_a) * (vl / job.s_b) * 4) as u64;
    let workers = job.decode_workers.max(1);
    // Individual recoveries are (almost always) independent, so decode
    // workers split the recovery *steps*, not whole grids (Remark 3).
    let mut per_worker_reads = vec![0usize; workers];
    let mut per_worker_writes = vec![0usize; workers];
    let mut next = 0usize;
    for plan in plans.iter() {
        for step in &plan.steps {
            per_worker_reads[next % workers] += step.reads;
            per_worker_writes[next % workers] += 1;
            next += 1;
        }
    }
    // Only grids with recovery work need a decode worker; an all-arrived
    // output needs no decode phase at all.
    let dec_profiles: Vec<WorkProfile> = per_worker_reads
        .iter()
        .zip(&per_worker_writes)
        .filter(|(&reads, _)| reads > 0)
        .map(|(&reads, &writes)| WorkProfile {
            bytes_read: reads as u64 * out_bytes,
            read_ops: reads as u64,
            flops: (reads * (vm / job.s_a) * (vl / job.s_b)) as f64,
            bytes_written: writes as u64 * out_bytes,
            write_ops: writes as u64,
        })
        .collect();
    report.dec.tasks = dec_profiles.len();
    report.dec.blocks_read = plans.iter().map(|p| p.total_reads).sum();
    if !dec_profiles.is_empty() {
        let dec_phase = crate::platform::launch_tasks(&env.model, &dec_profiles, rng);
        let dec_out = speculative(&env.model, &dec_profiles[0], &dec_phase, 0.8, rng);
        report.dec.relaunched = dec_out.relaunched;
        report.dec.virtual_secs = dec_out.makespan;
    }

    // Undecodable grids (rare, Thm 2): recompute the still-missing cells.
    let undecodable: usize = plans.iter().map(|p| p.undecodable.len()).sum();
    if undecodable > 0 {
        let t_rec = recompute_round(&env.model, &profile, undecodable, 0.0, rng);
        report.dec.virtual_secs += t_rec;
        report.dec.relaunched += undecodable;
        let grid_slice = &mut grid;
        for cell in 0..ra * rb {
            if grid_slice[cell].is_none() {
                let (i, j) = (cell / rb, cell % rb);
                grid_slice[cell] = Some(env.backend.block_product(&a_coded[i], &b_coded[j]));
            }
        }
    }
    let _ = missing_before;

    // Extract systematic output.
    let sys = crate::codes::local_product::extract_systematic(&code, &grid)?;
    for (idx, blk) in sys.iter().enumerate() {
        let (i, j) = (idx / job.s_b, idx % job.s_b);
        crate::storage::put_matrix(env.store.as_ref(), &keys::result_block(&job.job_id, i, j), blk);
    }
    let c = assemble_grid(GridShape { rows: job.s_a, cols: job.s_b }, &sys);
    Ok((c, report))
}

/// Is local grid `g` decodable given the arrival mask?
fn grid_decodable(code: &LocalProductCode, g: usize, arrived: &[bool], rb: usize) -> bool {
    let (l_a, l_b) = (code.a.l, code.b.l);
    let gb = code.b.groups();
    let (gi, gj) = (g / gb, g % gb);
    let mut present = Vec::with_capacity((l_a + 1) * (l_b + 1));
    for r in 0..=l_a {
        for c in 0..=l_b {
            let (cr, cc) = code.grid_cell(gi, gj, r, c);
            present.push(arrived[cr * rb + cc]);
        }
    }
    plan_peel(l_a + 1, l_b + 1, &present).decodable()
}

/// Backend-routed side encode (each parity via `stack_sum`).
fn encode_side_numeric(
    backend: &dyn ComputeBackend,
    layout: crate::codes::layout::LocalLayout,
    blocks: &[Matrix],
) -> Vec<Matrix> {
    use crate::codes::layout::CodedBlock;
    (0..layout.coded_len())
        .map(|k| match layout.block_at(k) {
            CodedBlock::Systematic { orig } => blocks[orig].clone(),
            CodedBlock::Parity { group } => {
                let members: Vec<&Matrix> =
                    layout.group_members(group).map(|m| &blocks[m]).collect();
                backend.stack_sum(&members)
            }
        })
        .collect()
}

/// Backend-routed peeling decode of one local grid (numeric twin of
/// [`decode_local_grid`], but every recovery runs through the compute
/// backend so the PJRT `parity_residual` / `stack_sum` artifacts are on
/// the decode hot path).
fn decode_numeric(
    backend: &dyn ComputeBackend,
    l_a: usize,
    l_b: usize,
    cells: &mut [Option<Matrix>],
) -> crate::codes::peeling::PeelPlan {
    use crate::codes::peeling::Axis;
    let rows = l_a + 1;
    let cols = l_b + 1;
    let present: Vec<bool> = cells.iter().map(Option::is_some).collect();
    let plan = plan_peel(rows, cols, &present);
    for step in &plan.steps {
        let (r, c) = step.cell;
        let line: Vec<usize> = match step.axis {
            Axis::Row => (0..cols).map(|cc| r * cols + cc).collect(),
            Axis::Col => (0..rows).map(|rr| rr * cols + c).collect(),
        };
        let target = r * cols + c;
        let parity_idx = *line.last().unwrap();
        let value = if target == parity_idx {
            let members: Vec<&Matrix> = line[..line.len() - 1]
                .iter()
                .map(|&i| cells[i].as_ref().expect("plan order"))
                .collect();
            backend.stack_sum(&members)
        } else {
            let parity = cells[parity_idx].as_ref().expect("plan order").clone();
            let survivors: Vec<&Matrix> = line[..line.len() - 1]
                .iter()
                .filter(|&&i| i != target)
                .map(|&i| cells[i].as_ref().expect("plan order"))
                .collect();
            backend.parity_residual(&parity, &survivors)
        };
        cells[target] = Some(value);
    }
    plan
}

// ---------------------------------------------------------------------------
// Product code baseline (global parities)
// ---------------------------------------------------------------------------

fn run_product(
    env: &Env,
    a: &Matrix,
    b: &Matrix,
    job: &MatmulJob,
    t_a: usize,
    t_b: usize,
    rng: &mut Pcg64,
) -> anyhow::Result<(Matrix, JobReport)> {
    let mut report = JobReport::new("product");
    let pc = ProductCode::new(job.s_a, t_a, job.s_b, t_b);
    report.redundancy = pc.redundancy();
    let pa = Partition::new(a.rows, a.cols, job.s_a);
    let pb = Partition::new(b.rows, b.cols, job.s_b);
    let a_blocks = pa.split(a);
    let b_blocks = pb.split(b);

    // Encode: each parity reads ALL s blocks of its side (global parities
    // — the encode-cost handicap vs local codes), column-sliced across
    // the same small fleet.
    let (vm, vk, vl) = job.vdims(a, b);
    let (ra, rb) = pc.coded_grid();
    let fleet = job.encode_fleet(ra * rb);
    let enc_profile = sliced_encode_profile(
        t_a + t_b,
        job.s_a.max(job.s_b),
        vm / job.s_a,
        vk,
        fleet,
    );
    let enc_phase = launch(&env.model, &enc_profile, fleet, rng);
    let enc_out = speculative(&env.model, &enc_profile, &enc_phase, 0.95, rng);
    report.enc.tasks = fleet;
    report.enc.virtual_secs = enc_out.makespan;
    report.enc.blocks_read = t_a * job.s_a + t_b * job.s_b;

    let (ac, bc) = pc.encode_sides(&a_blocks, &b_blocks);

    // Compute phase with earliest-decodable termination.
    let profile = WorkProfile::block_product(vm / job.s_a, vk, vl / job.s_b);
    let phase = launch(&env.model, &profile, ra * rb, rng);
    report.comp.tasks = ra * rb;
    report.comp.stragglers = phase.straggled.iter().filter(|&&s| s).count();
    let mut arrived = vec![false; ra * rb];
    let mut t_comp = 0.0;
    for &cell in &phase.arrival_order() {
        arrived[cell] = true;
        t_comp = phase.finish[cell];
        if product_decodable(&pc, &arrived) {
            break;
        }
    }
    report.comp.virtual_secs = t_comp;

    // Numerics over arrived cells.
    let mut grid: Vec<Option<Matrix>> = {
        let arrived_ref = &arrived;
        let ac_ref = &ac;
        let bc_ref = &bc;
        parallel_map(env.threads, ra * rb, move |cell| {
            if arrived_ref[cell] {
                let (i, j) = (cell / rb, cell % rb);
                Some(env.backend.block_product(&ac_ref[i], &bc_ref[j]))
            } else {
                None
            }
        })
    };

    let dec = pc.decode(&mut grid)?;
    let out_bytes = ((vm / job.s_a) * (vl / job.s_b) * 4) as u64;
    report.dec.blocks_read = dec.blocks_read;
    if dec.blocks_read > 0 {
        // Unlike the local scheme's independent grids, the product code's
        // row/column recovery passes are globally coupled (a column pass
        // feeds the next row pass), so decode does not parallelize across
        // workers — the paper's "huge communication overhead" (§II-B).
        let workers = 1usize;
        let _ = job.decode_workers;
        let per_worker_reads = dec.blocks_read.div_ceil(workers);
        let dec_profile = WorkProfile {
            bytes_read: per_worker_reads as u64 * out_bytes,
            read_ops: per_worker_reads as u64,
            flops: (dec.blocks_read * (vm / job.s_a) * (vl / job.s_b)) as f64 / workers as f64,
            bytes_written: (dec.recovered.max(1) as u64) * out_bytes / workers as u64,
            write_ops: dec.recovered.div_ceil(workers) as u64,
        };
        let dec_phase = launch(&env.model, &dec_profile, workers, rng);
        let dec_out = speculative(&env.model, &dec_profile, &dec_phase, 0.8, rng);
        report.dec.tasks = workers;
        report.dec.virtual_secs = dec_out.makespan;
    }

    let c = assemble_grid(
        GridShape { rows: job.s_a, cols: job.s_b },
        &dec.systematic,
    );
    Ok((c, report))
}

/// Boolean decodability for the product code: iterate axis recoveries on
/// the arrival mask to fixpoint.
fn product_decodable(pc: &ProductCode, arrived: &[bool]) -> bool {
    let (ra, rb) = pc.coded_grid();
    let s_a = pc.row_code.systematic;
    let s_b = pc.col_code.systematic;
    let mut have = arrived.to_vec();
    loop {
        let mut progressed = false;
        for c in 0..rb {
            let miss = (0..s_a).filter(|&r| !have[r * rb + c]).count();
            let par = (s_a..ra).filter(|&r| have[r * rb + c]).count();
            if miss > 0 && miss <= par {
                for r in 0..s_a {
                    have[r * rb + c] = true;
                }
                progressed = true;
            }
        }
        for r in 0..s_a {
            let miss = (0..s_b).filter(|&c| !have[r * rb + c]).count();
            let par = (s_b..rb).filter(|&c| have[r * rb + c]).count();
            if miss > 0 && miss <= par {
                for c in 0..s_b {
                    have[r * rb + c] = true;
                }
                progressed = true;
            }
        }
        let all = (0..s_a).all(|r| (0..s_b).all(|c| have[r * rb + c]));
        if all {
            return true;
        }
        if !progressed {
            return false;
        }
    }
}

// ---------------------------------------------------------------------------
// Polynomial code baseline
// ---------------------------------------------------------------------------

/// Past this recovery threshold the real-arithmetic Vandermonde decode is
/// numerically meaningless (and the paper's master "cannot store" the
/// blocks): report virtual time but mark numerics infeasible.
pub const POLY_NUMERIC_CAP: usize = 64;

fn run_polynomial(
    env: &Env,
    a: &Matrix,
    b: &Matrix,
    job: &MatmulJob,
    redundancy: f64,
    rng: &mut Pcg64,
) -> anyhow::Result<(Matrix, JobReport)> {
    let mut report = JobReport::new("polynomial");
    let k = job.s_a * job.s_b;
    let n_workers = ((k as f64) * (1.0 + redundancy)).ceil() as usize;
    let code = PolynomialCode::new(job.s_a, job.s_b, n_workers);
    report.redundancy = code.redundancy();

    let pa = Partition::new(a.rows, a.cols, job.s_a);
    let pb = Partition::new(b.rows, b.cols, job.s_b);
    let a_blocks = pa.split(a);
    let b_blocks = pb.split(b);

    // Encode: every one of the n_workers coded inputs Ã_k/B̃_k is a
    // weighted sum of ALL the side's blocks — n× more encode volume than
    // the local scheme. Column-sliced across a fleet sized like the other
    // schemes' (10% of compute) for a fair comparison.
    let (vm, vk, vl) = job.vdims(a, b);
    let fleet = job.encode_fleet(n_workers);
    let enc_profile = sliced_encode_profile(
        2 * n_workers,
        job.s_a.max(job.s_b),
        vm / job.s_a,
        vk,
        fleet,
    );
    let enc_phase = launch(&env.model, &enc_profile, fleet, rng);
    let enc_out = speculative(&env.model, &enc_profile, &enc_phase, 0.95, rng);
    report.enc.tasks = fleet;
    report.enc.virtual_secs = enc_out.makespan;
    report.enc.blocks_read = n_workers * (job.s_a + job.s_b);

    // Compute: n_workers tasks; MDS termination at the K-th arrival.
    let profile = WorkProfile::block_product(vm / job.s_a, vk, vl / job.s_b);
    let phase = launch(&env.model, &profile, n_workers, rng);
    report.comp.tasks = n_workers;
    report.comp.stragglers = phase.straggled.iter().filter(|&&s| s).count();
    report.comp.virtual_secs = phase.wait_k(k);

    // Decode: EVERY decode worker reads all K blocks (the paper's
    // communication-overhead point) and the interpolation costs K² block
    // combines.
    let out_bytes = ((vm / job.s_a) * (vl / job.s_b) * 4) as u64;
    let workers = job.decode_workers.max(1);
    let per_worker_blocks = k; // locality = K: no partial reads possible
    let dec_profile = WorkProfile {
        bytes_read: per_worker_blocks as u64 * out_bytes,
        read_ops: per_worker_blocks as u64,
        flops: (k * k / workers) as f64 * ((vm / job.s_a) * (vl / job.s_b)) as f64,
        bytes_written: (k / workers).max(1) as u64 * out_bytes,
        write_ops: (k / workers).max(1) as u64,
    };
    let dec_phase = launch(&env.model, &dec_profile, workers, rng);
    report.dec.tasks = workers;
    report.dec.blocks_read = workers * k;
    report.dec.virtual_secs = dec_phase.wait_all();

    // Numerics only below the conditioning wall.
    if k > POLY_NUMERIC_CAP {
        report.numerics_ok = false;
        return Ok((Matrix::zeros(a.rows, b.rows), report));
    }
    let order = phase.arrival_order();
    let first_k: Vec<usize> = order[..k].to_vec();
    let results: Vec<(usize, Matrix)> = {
        let a_ref = &a_blocks;
        let b_ref = &b_blocks;
        let code_ref = &code;
        parallel_map(env.threads, k, move |t| {
            let w = first_k[t];
            let at = code_ref.encode_a(a_ref, w);
            let bt = code_ref.encode_b(b_ref, w);
            (w, env.backend.block_product(&at, &bt))
        })
    };
    let (blocks, _) = code.decode(&results)?;
    let c = assemble_grid(GridShape { rows: job.s_a, cols: job.s_b }, &blocks);
    Ok((c, report))
}

// ---------------------------------------------------------------------------
// Shared numeric helpers
// ---------------------------------------------------------------------------

fn compute_products(
    env: &Env,
    a_blocks: &[Matrix],
    b_blocks: &[Matrix],
    include: impl Fn(usize, usize) -> bool + Sync,
) -> Vec<Option<Matrix>> {
    let sb = b_blocks.len();
    parallel_map(env.threads, a_blocks.len() * sb, move |cell| {
        let (i, j) = (cell / sb, cell % sb);
        if include(i, j) {
            Some(env.backend.block_product(&a_blocks[i], &b_blocks[j]))
        } else {
            None
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul_bt;
    use crate::storage::ObjectStore;

    fn env() -> Env {
        Env::host()
    }

    fn inputs(m: usize, n: usize, l: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Pcg64::new(seed);
        (
            Matrix::randn(m, n, &mut rng, 0.0, 1.0),
            Matrix::randn(l, n, &mut rng, 0.0, 1.0),
        )
    }

    #[test]
    fn local_product_end_to_end_correct() {
        let env = env();
        let (a, b) = inputs(64, 48, 64, 1);
        let job = MatmulJob {
            s_a: 4,
            s_b: 4,
            scheme: Scheme::LocalProduct { l_a: 2, l_b: 2 },
            seed: 7,
            ..Default::default()
        };
        let (c, report) = run_matmul(&env, &a, &b, &job).unwrap();
        assert!(report.rel_err < 1e-4, "rel_err={}", report.rel_err);
        assert!(c.rel_err(&matmul_bt(&a, &b)) < 1e-4);
        assert!(report.total_secs() > 0.0);
        assert!((report.redundancy - 1.25).abs() < 1e-9); // (3·3)/(2·2)−1
    }

    #[test]
    fn local_product_correct_across_seeds() {
        // Different seeds ⇒ different straggler patterns; decode must
        // always reconstruct the exact product.
        let env = env();
        let (a, b) = inputs(48, 32, 48, 2);
        for seed in 0..8 {
            let job = MatmulJob {
                s_a: 4,
                s_b: 4,
                scheme: Scheme::LocalProduct { l_a: 2, l_b: 2 },
                seed,
                job_id: format!("seed{seed}"),
                ..Default::default()
            };
            let (_, report) = run_matmul(&env, &a, &b, &job).unwrap();
            assert!(report.rel_err < 1e-4, "seed {seed}: {}", report.rel_err);
        }
    }

    #[test]
    fn speculative_and_uncoded_correct() {
        let env = env();
        let (a, b) = inputs(32, 24, 32, 3);
        for scheme in [Scheme::Uncoded, Scheme::Speculative { wait_frac: 0.75 }] {
            let job = MatmulJob {
                s_a: 4,
                s_b: 4,
                scheme,
                seed: 5,
                ..Default::default()
            };
            let (_, report) = run_matmul(&env, &a, &b, &job).unwrap();
            assert!(report.rel_err < 1e-5, "{}: {}", report.scheme, report.rel_err);
            assert_eq!(report.enc.virtual_secs, 0.0);
            assert_eq!(report.dec.virtual_secs, 0.0);
        }
    }

    #[test]
    fn product_code_correct() {
        let env = env();
        let (a, b) = inputs(32, 24, 32, 4);
        let job = MatmulJob {
            s_a: 4,
            s_b: 4,
            scheme: Scheme::Product { t_a: 1, t_b: 1 },
            seed: 11,
            ..Default::default()
        };
        let (_, report) = run_matmul(&env, &a, &b, &job).unwrap();
        assert!(report.rel_err < 1e-3, "rel_err={}", report.rel_err);
        assert!((report.redundancy - 0.5625).abs() < 1e-9); // 25/16−1
    }

    #[test]
    fn polynomial_code_correct_small() {
        let env = env();
        let (a, b) = inputs(32, 24, 32, 5);
        let job = MatmulJob {
            s_a: 4,
            s_b: 4,
            scheme: Scheme::Polynomial { redundancy: 0.25 },
            seed: 13,
            ..Default::default()
        };
        let (_, report) = run_matmul(&env, &a, &b, &job).unwrap();
        assert!(report.numerics_ok);
        // Real-arithmetic polynomial decode at K=16 already carries ~1e-2
        // relative error (the conditioning wall the paper points to).
        assert!(report.rel_err < 5e-2, "rel_err={}", report.rel_err);
    }

    #[test]
    fn polynomial_large_marks_infeasible() {
        let env = env();
        let (a, b) = inputs(90, 16, 90, 6);
        let job = MatmulJob {
            s_a: 9,
            s_b: 9,
            scheme: Scheme::Polynomial { redundancy: 0.21 },
            seed: 17,
            verify: true,
            ..Default::default()
        };
        let (_, report) = run_matmul(&env, &a, &b, &job).unwrap();
        assert!(!report.numerics_ok); // K = 81 > cap
        assert!(report.comp.virtual_secs > 0.0);
        assert!(report.dec.virtual_secs > 0.0);
    }

    #[test]
    fn phases_populated_for_local_product() {
        let env = env();
        let (a, b) = inputs(64, 32, 64, 7);
        let job = MatmulJob {
            s_a: 4,
            s_b: 4,
            scheme: Scheme::LocalProduct { l_a: 4, l_b: 4 },
            seed: 23,
            ..Default::default()
        };
        let (_, report) = run_matmul(&env, &a, &b, &job).unwrap();
        assert!(report.enc.virtual_secs > 0.0);
        assert!(report.comp.virtual_secs > 0.0);
        assert!(report.dec.virtual_secs > 0.0);
        assert_eq!(report.comp.tasks, 25);
        assert_eq!(report.enc.tasks, 3); // encode fleet = ceil(25/10)
        // Store holds the coded inputs and the results.
        assert_eq!(env.store.list("job/coded/a/").len(), 5);
        assert_eq!(env.store.list("job/result/").len(), 16);
    }

    #[test]
    fn rejects_bad_shapes() {
        let env = env();
        let (a, b) = inputs(30, 24, 32, 8);
        let job = MatmulJob {
            s_a: 4,
            ..Default::default()
        };
        assert!(run_matmul(&env, &a, &b, &job).is_err());
    }
}

//! The coded matrix-multiplication workflow — the paper's Fig-2 pipeline
//! (`f_enc → f_comp → f_dec`, all phases on simulated serverless workers)
//! for every scheme: local product codes (the contribution), speculative
//! execution, uncoded, global-parity product codes, polynomial codes.
//!
//! Virtual time and real numerics advance together: the straggler model
//! decides *which* output blocks arrive before the earliest-decodable
//! cutoff, and the decode phase must then *really* reconstruct the missing
//! blocks from parities (through the compute backend, i.e. the PJRT
//! artifacts) — so every simulated run is also an end-to-end numerical
//! test against `A·Bᵀ`.
//!
//! Since the event-core refactor each job runs on one [`EventSim`]: the
//! virtual clock carries across the encode → compute → decode phases, the
//! earliest-decodable cutoff and speculative relaunches are event-driven
//! policies, and [`Env::pool`] can bound the worker fleet, in which case
//! later phases queue behind still-running tasks (worker reuse). The
//! default unbounded pool reproduces the historical barrier-synchronous
//! timings exactly.

use std::sync::Arc;

use crate::codes::local_product::{grid_decodable, LocalProductCode};
use crate::codes::peeling::plan_peel;
use crate::codes::polynomial::PolynomialCode;
use crate::codes::product::ProductCode;
use crate::codes::Scheme;
use crate::coordinator::metrics::JobReport;
use crate::linalg::blocked::{assemble_grid, GridShape, Partition};
use crate::linalg::matrix::Matrix;
use crate::platform::event::{run_phase, EventSim, PhaseState, Pool, Termination};
use crate::platform::{StragglerModel, WorkProfile};
use crate::runtime::ComputeBackend;
use crate::storage::{keys, InMemoryStore};
use crate::util::rng::Pcg64;
use crate::util::threadpool::{num_threads, parallel_map};

/// Re-exported for backwards compatibility; see
/// [`crate::codes::polynomial::NUMERIC_CAP`].
pub use crate::codes::polynomial::NUMERIC_CAP as POLY_NUMERIC_CAP;

/// Shared execution environment.
pub struct Env {
    pub backend: Arc<dyn ComputeBackend>,
    pub store: Arc<InMemoryStore>,
    pub model: StragglerModel,
    /// Host threads used to execute the real numerics.
    pub threads: usize,
    /// Serverless worker-pool capacity for the event simulator: `None` ⇒
    /// unbounded on-demand fleet (the paper's Lambda assumption and the
    /// legacy behaviour); `Some(w)` ⇒ at most `w` concurrent workers,
    /// with excess tasks queueing FIFO.
    pub pool: Option<usize>,
}

impl Env {
    /// Host-backend environment with default platform calibration.
    pub fn host() -> Env {
        Env {
            backend: Arc::new(crate::runtime::HostBackend),
            store: Arc::new(InMemoryStore::new()),
            model: StragglerModel::new(Default::default(), Default::default()),
            threads: num_threads(),
            pool: None,
        }
    }

    /// Environment with an explicit backend (e.g. PJRT).
    pub fn with_backend(backend: Arc<dyn ComputeBackend>) -> Env {
        Env {
            backend,
            store: Arc::new(InMemoryStore::new()),
            model: StragglerModel::new(Default::default(), Default::default()),
            threads: num_threads(),
            pool: None,
        }
    }

    /// Fresh event simulator over this environment's worker pool.
    pub fn sim(&self) -> EventSim {
        EventSim::new(Pool::from_option(self.pool))
    }
}

/// A coded matmul job description (`C = A·Bᵀ`).
#[derive(Debug, Clone)]
pub struct MatmulJob {
    /// Systematic row-blocks of A / B.
    pub s_a: usize,
    pub s_b: usize,
    pub scheme: Scheme,
    /// Parallel decoding workers (Remark 3).
    pub decode_workers: usize,
    /// Parallel encoding workers (Remark 1: encoding is column-sliced
    /// across a small worker fleet, <10% of the compute phase; 0 ⇒ auto =
    /// ceil(compute_tasks / 10)).
    pub encode_workers: usize,
    /// Verify the output against the direct product (costs a host GEMM).
    pub verify: bool,
    pub seed: u64,
    /// Unique job id for store keys.
    pub job_id: String,
    /// Full-matrix dims `(rows_a, k, rows_b)` used for the *virtual-time*
    /// work profiles. `None` ⇒ the actual matrix dims. Figure harnesses
    /// set this to the PAPER's scale (e.g. 0.5M) so simulated seconds are
    /// comparable to the paper's plots while the verified numerics run at
    /// lab scale (DESIGN.md §Virtual-time model).
    pub virtual_dims: Option<(usize, usize, usize)>,
}

impl Default for MatmulJob {
    fn default() -> Self {
        MatmulJob {
            s_a: 4,
            s_b: 4,
            scheme: Scheme::LocalProduct { l_a: 2, l_b: 2 },
            decode_workers: 4,
            encode_workers: 0,
            verify: true,
            seed: 0,
            job_id: "job".into(),
            virtual_dims: None,
        }
    }
}

impl MatmulJob {
    /// Virtual-time dims for profile building.
    fn vdims(&self, a: &Matrix, b: &Matrix) -> (usize, usize, usize) {
        self.virtual_dims.unwrap_or((a.rows, a.cols, b.rows))
    }

    /// Encode fleet size (Remark 1): explicit or ~10% of compute tasks.
    fn encode_fleet(&self, compute_tasks: usize) -> usize {
        if self.encode_workers > 0 {
            self.encode_workers
        } else {
            compute_tasks.div_ceil(10).max(1)
        }
    }
}

/// Run the job; returns the output matrix and the phase report.
pub fn run_matmul(env: &Env, a: &Matrix, b: &Matrix, job: &MatmulJob) -> anyhow::Result<(Matrix, JobReport)> {
    anyhow::ensure!(a.cols == b.cols, "A (m×n) · Bᵀ needs matching n");
    anyhow::ensure!(a.rows % job.s_a == 0, "A rows must divide s_a");
    anyhow::ensure!(b.rows % job.s_b == 0, "B rows must divide s_b");
    let mut rng = Pcg64::new(job.seed);

    let (c, mut report) = match job.scheme {
        Scheme::Uncoded => run_uncoded(env, a, b, job, &mut rng, None)?,
        Scheme::Speculative { wait_frac } => {
            run_uncoded(env, a, b, job, &mut rng, Some(wait_frac))?
        }
        Scheme::LocalProduct { l_a, l_b } => run_local_product(env, a, b, job, l_a, l_b, &mut rng)?,
        Scheme::Product { t_a, t_b } => run_product(env, a, b, job, t_a, t_b, &mut rng)?,
        Scheme::Polynomial { redundancy } => run_polynomial(env, a, b, job, redundancy, &mut rng)?,
    };

    if job.verify && report.numerics_ok {
        let direct = env.backend.block_product(a, b);
        report.rel_err = c.rel_err(&direct);
    }
    Ok((c, report))
}

// ---------------------------------------------------------------------------
// Uncoded / speculative
// ---------------------------------------------------------------------------

fn run_uncoded(
    env: &Env,
    a: &Matrix,
    b: &Matrix,
    job: &MatmulJob,
    rng: &mut Pcg64,
    wait_frac: Option<f64>,
) -> anyhow::Result<(Matrix, JobReport)> {
    let mut report = JobReport::new(if wait_frac.is_some() {
        "speculative"
    } else {
        "uncoded"
    });
    let pa = Partition::new(a.rows, a.cols, job.s_a);
    let pb = Partition::new(b.rows, b.cols, job.s_b);
    let a_blocks = pa.split(a);
    let b_blocks = pb.split(b);

    // Virtual compute phase over s_a × s_b tasks (profiles at virtual
    // dims), run through the event queue.
    let (vm, vk, vl) = job.vdims(a, b);
    let profile = WorkProfile::block_product(vm / job.s_a, vk, vl / job.s_b);
    let n_tasks = job.s_a * job.s_b;
    let mut sim = env.sim();
    let term = match wait_frac {
        None => Termination::WaitAll,
        Some(f) => Termination::Speculative { wait_frac: f },
    };
    let mut comp = PhaseState::launch_uniform(&mut sim, &env.model, &profile, n_tasks, 0, term, rng);
    run_phase(&mut sim, &mut comp, &env.model, rng, &mut |_, _| false);
    report.comp.tasks = n_tasks;
    report.comp.stragglers = comp.stragglers();
    report.comp.relaunched = comp.relaunched;
    report.comp.virtual_secs = comp.duration();

    // Numerics: every block is eventually computed.
    let blocks = compute_products(env, &a_blocks, &b_blocks, |_i, _j| true);
    let shape = GridShape { rows: job.s_a, cols: job.s_b };
    let c = assemble_grid(shape, &blocks.into_iter().map(Option::unwrap).collect::<Vec<_>>());
    Ok((c, report))
}

// ---------------------------------------------------------------------------
// Local product code (the paper's scheme)
// ---------------------------------------------------------------------------

fn run_local_product(
    env: &Env,
    a: &Matrix,
    b: &Matrix,
    job: &MatmulJob,
    l_a: usize,
    l_b: usize,
    rng: &mut Pcg64,
) -> anyhow::Result<(Matrix, JobReport)> {
    anyhow::ensure!(l_a > 0 && l_b > 0, "group sizes l_a/l_b must be positive");
    anyhow::ensure!(job.s_a % l_a == 0, "s_a ({}) % l_a ({l_a}) != 0", job.s_a);
    anyhow::ensure!(job.s_b % l_b == 0, "s_b ({}) % l_b ({l_b}) != 0", job.s_b);
    let mut report = JobReport::new("local-product");
    let code = LocalProductCode::new(job.s_a, l_a, job.s_b, l_b);
    report.redundancy = code.redundancy();

    let pa = Partition::new(a.rows, a.cols, job.s_a);
    let pb = Partition::new(b.rows, b.cols, job.s_b);
    let a_blocks = pa.split(a);
    let b_blocks = pb.split(b);

    // One event simulator per job: the clock carries across phases.
    let mut sim = env.sim();

    // --- Encode phase: column-sliced across a small fleet (Remark 1),
    // straggler-protected by speculative relaunch.
    let (vm, vk, vl) = job.vdims(a, b);
    let (ra, rb) = code.coded_grid();
    let fleet = job.encode_fleet(ra * rb);
    let enc_profile = WorkProfile::sliced_encode(
        code.a.groups() + code.b.groups(),
        l_a.max(l_b),
        vm / job.s_a,
        vk,
        fleet,
    );
    let mut enc = PhaseState::launch_uniform(
        &mut sim,
        &env.model,
        &enc_profile,
        fleet,
        0,
        Termination::Speculative { wait_frac: 0.95 },
        rng,
    );
    run_phase(&mut sim, &mut enc, &env.model, rng, &mut |_, _| false);
    report.enc.tasks = fleet;
    report.enc.stragglers = enc.stragglers();
    report.enc.relaunched = enc.relaunched;
    report.enc.virtual_secs = enc.duration();
    report.enc.blocks_read = l_a * code.a.groups() + l_b * code.b.groups();

    // Numerics: encode both sides through the backend, stash in the store
    // (the serverless dataflow — workers exchange blocks via storage).
    let backend = &env.backend;
    let a_coded = encode_side_numeric(backend.as_ref(), code.a, &a_blocks);
    let b_coded = encode_side_numeric(backend.as_ref(), code.b, &b_blocks);
    for (i, blk) in a_coded.iter().enumerate() {
        crate::storage::put_matrix(env.store.as_ref(), &keys::coded_block(&job.job_id, "a", i), blk);
    }
    for (j, blk) in b_coded.iter().enumerate() {
        crate::storage::put_matrix(env.store.as_ref(), &keys::coded_block(&job.job_id, "b", j), blk);
    }

    // --- Compute phase: (ra × rb) coded block products; the event-driven
    // earliest-decodable policy cuts off at the first virtual time every
    // local grid is peeling-decodable, cancelling stragglers (which frees
    // their workers on bounded pools).
    let profile = WorkProfile::block_product(vm / job.s_a, vk, vl / job.s_b);
    let mut comp = PhaseState::launch_uniform(
        &mut sim,
        &env.model,
        &profile,
        ra * rb,
        0,
        Termination::EarliestDecodable,
        rng,
    );
    report.comp.tasks = ra * rb;

    let (ga, gb) = code.groups();
    let mut pending: std::collections::BTreeSet<usize> = (0..ga * gb).collect();
    run_phase(
        &mut sim,
        &mut comp,
        &env.model,
        rng,
        &mut |mask: &[bool], newly: Option<usize>| {
            // A grid's decodability only changes when one of its own
            // cells arrives: retest just that grid per completion.
            match newly {
                Some(cell) => {
                    let g = code.grid_of_cell(cell);
                    if pending.contains(&g) && grid_decodable(&code, g, mask) {
                        pending.remove(&g);
                    }
                }
                None => pending.retain(|&g| !grid_decodable(&code, g, mask)),
            }
            pending.is_empty()
        },
    );
    report.comp.stragglers = comp.stragglers();
    report.comp.virtual_secs = comp.duration();
    let arrived = comp.arrived_mask();

    // Numerics: compute the arrived products only. The rest are the
    // stragglers decode must reconstruct.
    let mut grid: Vec<Option<Matrix>> = {
        let arrived_ref = &arrived;
        let a_ref = &a_coded;
        let b_ref = &b_coded;
        parallel_map(env.threads, ra * rb, move |cell| {
            if arrived_ref[cell] {
                let (i, j) = (cell / rb, cell % rb);
                Some(env.backend.block_product(&a_ref[i], &b_ref[j]))
            } else {
                None
            }
        })
    };

    // --- Decode phase: decode workers peel their grids in parallel.
    let mut plans = Vec::with_capacity(ga * gb);
    for gi in 0..ga {
        for gj in 0..gb {
            // Extract local grid, decode numerically, write back.
            let mut cells: Vec<Option<Matrix>> = Vec::with_capacity((l_a + 1) * (l_b + 1));
            for r in 0..=l_a {
                for c in 0..=l_b {
                    let (cr, cc) = code.grid_cell(gi, gj, r, c);
                    cells.push(grid[cr * rb + cc].take());
                }
            }
            let plan = decode_numeric(env.backend.as_ref(), l_a, l_b, &mut cells);
            let mut it = cells.into_iter();
            for r in 0..=l_a {
                for c in 0..=l_b {
                    let (cr, cc) = code.grid_cell(gi, gj, r, c);
                    grid[cr * rb + cc] = it.next().unwrap();
                }
            }
            plans.push(plan);
        }
    }

    // Virtual decode time: recovery steps round-robin over decode workers
    // (Remark 3); each worker's time is sampled from its aggregate
    // read/write profile.
    let workers = job.decode_workers.max(1);
    let dec_profiles = decode_worker_profiles(
        plans.iter().flat_map(|p| p.steps.iter().map(|s| s.reads)),
        workers,
        vm / job.s_a,
        vl / job.s_b,
    );
    report.dec.tasks = dec_profiles.len();
    report.dec.blocks_read = plans.iter().map(|p| p.total_reads).sum();
    if !dec_profiles.is_empty() {
        let mut dec = PhaseState::launch(
            &mut sim,
            &env.model,
            &dec_profiles,
            0,
            Termination::Speculative { wait_frac: 0.8 },
            rng,
        );
        run_phase(&mut sim, &mut dec, &env.model, rng, &mut |_, _| false);
        report.dec.relaunched = dec.relaunched;
        report.dec.virtual_secs = dec.duration();
    }

    // Recompute fallback: unreachable under earliest-decodable
    // termination (the cutoff only fires on decodable masks, and the
    // wait-all degenerate case has a full mask), kept as the defensive
    // path for cutoff policies that cannot guarantee decodability
    // (deadlines, Thm-2-tail experiments with adaptive coding).
    let undecodable: usize = plans.iter().map(|p| p.undecodable.len()).sum();
    report.decode_ok = undecodable == 0;
    if undecodable > 0 {
        let mut rec = PhaseState::launch_uniform(
            &mut sim,
            &env.model,
            &profile,
            undecodable,
            0,
            Termination::WaitAll,
            rng,
        );
        run_phase(&mut sim, &mut rec, &env.model, rng, &mut |_, _| false);
        report.dec.virtual_secs += rec.duration();
        report.dec.relaunched += undecodable;
        let grid_slice = &mut grid;
        for cell in 0..ra * rb {
            if grid_slice[cell].is_none() {
                let (i, j) = (cell / rb, cell % rb);
                grid_slice[cell] = Some(env.backend.block_product(&a_coded[i], &b_coded[j]));
            }
        }
    }

    // Extract systematic output.
    let sys = crate::codes::local_product::extract_systematic(&code, &grid)?;
    for (idx, blk) in sys.iter().enumerate() {
        let (i, j) = (idx / job.s_b, idx % job.s_b);
        crate::storage::put_matrix(env.store.as_ref(), &keys::result_block(&job.job_id, i, j), blk);
    }
    let c = assemble_grid(GridShape { rows: job.s_a, cols: job.s_b }, &sys);
    Ok((c, report))
}

/// Round-robin recovery steps (each costing `reads` block-reads) over
/// `workers` decode workers and build one aggregate [`WorkProfile`] per
/// worker that has any work. Shared accounting for the local-product
/// decode phase (also mirrored by the scenario runner).
pub fn decode_worker_profiles(
    step_reads: impl Iterator<Item = usize>,
    workers: usize,
    block_rows: usize,
    block_cols: usize,
) -> Vec<WorkProfile> {
    let out_bytes = (block_rows * block_cols * 4) as u64;
    let mut per_worker_reads = vec![0usize; workers];
    let mut per_worker_writes = vec![0usize; workers];
    let mut next = 0usize;
    for reads in step_reads {
        per_worker_reads[next % workers] += reads;
        per_worker_writes[next % workers] += 1;
        next += 1;
    }
    per_worker_reads
        .iter()
        .zip(&per_worker_writes)
        .filter(|(&reads, _)| reads > 0)
        .map(|(&reads, &writes)| WorkProfile {
            bytes_read: reads as u64 * out_bytes,
            read_ops: reads as u64,
            flops: (reads * block_rows * block_cols) as f64,
            bytes_written: writes as u64 * out_bytes,
            write_ops: writes as u64,
        })
        .collect()
}

/// Decode-phase profile of the product code's single decode worker: the
/// row/column recovery passes are globally coupled, so one worker reads
/// every surviving block of the touched lines and rewrites the recovered
/// cells. Shared by the coordinator and the scenario runner.
pub fn product_decode_profile(
    reads: usize,
    recovered: usize,
    block_rows: usize,
    block_cols: usize,
) -> WorkProfile {
    let out_bytes = (block_rows * block_cols * 4) as u64;
    WorkProfile {
        bytes_read: reads as u64 * out_bytes,
        read_ops: reads as u64,
        flops: (reads * block_rows * block_cols) as f64,
        bytes_written: (recovered.max(1) as u64) * out_bytes,
        write_ops: recovered as u64,
    }
}

/// Per-worker decode profile of the polynomial code: every decode worker
/// reads all K blocks (locality = K) and the K² block combines split
/// across the fleet. Shared by the coordinator and the scenario runner.
pub fn polynomial_decode_profile(
    k: usize,
    workers: usize,
    block_rows: usize,
    block_cols: usize,
) -> WorkProfile {
    let out_bytes = (block_rows * block_cols * 4) as u64;
    WorkProfile {
        bytes_read: k as u64 * out_bytes,
        read_ops: k as u64,
        flops: (k * k / workers) as f64 * (block_rows * block_cols) as f64,
        bytes_written: (k / workers).max(1) as u64 * out_bytes,
        write_ops: (k / workers).max(1) as u64,
    }
}

/// Backend-routed side encode (each parity via `stack_sum`).
fn encode_side_numeric(
    backend: &dyn ComputeBackend,
    layout: crate::codes::layout::LocalLayout,
    blocks: &[Matrix],
) -> Vec<Matrix> {
    use crate::codes::layout::CodedBlock;
    (0..layout.coded_len())
        .map(|k| match layout.block_at(k) {
            CodedBlock::Systematic { orig } => blocks[orig].clone(),
            CodedBlock::Parity { group } => {
                let members: Vec<&Matrix> =
                    layout.group_members(group).map(|m| &blocks[m]).collect();
                backend.stack_sum(&members)
            }
        })
        .collect()
}

/// Backend-routed peeling decode of one local grid (numeric twin of
/// [`decode_local_grid`], but every recovery runs through the compute
/// backend so the PJRT `parity_residual` / `stack_sum` artifacts are on
/// the decode hot path).
fn decode_numeric(
    backend: &dyn ComputeBackend,
    l_a: usize,
    l_b: usize,
    cells: &mut [Option<Matrix>],
) -> crate::codes::peeling::PeelPlan {
    use crate::codes::peeling::Axis;
    let rows = l_a + 1;
    let cols = l_b + 1;
    let present: Vec<bool> = cells.iter().map(Option::is_some).collect();
    let plan = plan_peel(rows, cols, &present);
    for step in &plan.steps {
        let (r, c) = step.cell;
        let line: Vec<usize> = match step.axis {
            Axis::Row => (0..cols).map(|cc| r * cols + cc).collect(),
            Axis::Col => (0..rows).map(|rr| rr * cols + c).collect(),
        };
        let target = r * cols + c;
        let parity_idx = *line.last().unwrap();
        let value = if target == parity_idx {
            let members: Vec<&Matrix> = line[..line.len() - 1]
                .iter()
                .map(|&i| cells[i].as_ref().expect("plan order"))
                .collect();
            backend.stack_sum(&members)
        } else {
            let parity = cells[parity_idx].as_ref().expect("plan order").clone();
            let survivors: Vec<&Matrix> = line[..line.len() - 1]
                .iter()
                .filter(|&&i| i != target)
                .map(|&i| cells[i].as_ref().expect("plan order"))
                .collect();
            backend.parity_residual(&parity, &survivors)
        };
        cells[target] = Some(value);
    }
    plan
}

// ---------------------------------------------------------------------------
// Product code baseline (global parities)
// ---------------------------------------------------------------------------

fn run_product(
    env: &Env,
    a: &Matrix,
    b: &Matrix,
    job: &MatmulJob,
    t_a: usize,
    t_b: usize,
    rng: &mut Pcg64,
) -> anyhow::Result<(Matrix, JobReport)> {
    let mut report = JobReport::new("product");
    let pc = ProductCode::new(job.s_a, t_a, job.s_b, t_b);
    report.redundancy = pc.redundancy();
    let pa = Partition::new(a.rows, a.cols, job.s_a);
    let pb = Partition::new(b.rows, b.cols, job.s_b);
    let a_blocks = pa.split(a);
    let b_blocks = pb.split(b);

    let mut sim = env.sim();

    // Encode: each parity reads ALL s blocks of its side (global parities
    // — the encode-cost handicap vs local codes), column-sliced across
    // the same small fleet.
    let (vm, vk, vl) = job.vdims(a, b);
    let (ra, rb) = pc.coded_grid();
    let fleet = job.encode_fleet(ra * rb);
    let enc_profile = WorkProfile::sliced_encode(
        t_a + t_b,
        job.s_a.max(job.s_b),
        vm / job.s_a,
        vk,
        fleet,
    );
    let mut enc = PhaseState::launch_uniform(
        &mut sim,
        &env.model,
        &enc_profile,
        fleet,
        0,
        Termination::Speculative { wait_frac: 0.95 },
        rng,
    );
    run_phase(&mut sim, &mut enc, &env.model, rng, &mut |_, _| false);
    report.enc.tasks = fleet;
    report.enc.virtual_secs = enc.duration();
    report.enc.blocks_read = t_a * job.s_a + t_b * job.s_b;

    let (ac, bc) = pc.encode_sides(&a_blocks, &b_blocks);

    // Compute phase with event-driven earliest-decodable termination.
    let profile = WorkProfile::block_product(vm / job.s_a, vk, vl / job.s_b);
    let mut comp = PhaseState::launch_uniform(
        &mut sim,
        &env.model,
        &profile,
        ra * rb,
        0,
        Termination::EarliestDecodable,
        rng,
    );
    // Global parities couple every cell, so the whole-mask fixpoint is
    // re-run per completion (no per-grid incremental form exists).
    run_phase(&mut sim, &mut comp, &env.model, rng, &mut |mask: &[bool], _| {
        pc.decodable(mask)
    });
    report.comp.tasks = ra * rb;
    report.comp.stragglers = comp.stragglers();
    report.comp.virtual_secs = comp.duration();
    let arrived = comp.arrived_mask();

    // Numerics over arrived cells.
    let mut grid: Vec<Option<Matrix>> = {
        let arrived_ref = &arrived;
        let ac_ref = &ac;
        let bc_ref = &bc;
        parallel_map(env.threads, ra * rb, move |cell| {
            if arrived_ref[cell] {
                let (i, j) = (cell / rb, cell % rb);
                Some(env.backend.block_product(&ac_ref[i], &bc_ref[j]))
            } else {
                None
            }
        })
    };

    let dec = pc.decode(&mut grid)?;
    report.dec.blocks_read = dec.blocks_read;
    if dec.blocks_read > 0 {
        // Unlike the local scheme's independent grids, the product code's
        // row/column recovery passes are globally coupled (a column pass
        // feeds the next row pass), so decode does not parallelize across
        // workers — the paper's "huge communication overhead" (§II-B).
        let _ = job.decode_workers;
        let dec_profile =
            product_decode_profile(dec.blocks_read, dec.recovered, vm / job.s_a, vl / job.s_b);
        let mut decp = PhaseState::launch_uniform(
            &mut sim,
            &env.model,
            &dec_profile,
            1,
            0,
            Termination::Speculative { wait_frac: 0.8 },
            rng,
        );
        run_phase(&mut sim, &mut decp, &env.model, rng, &mut |_, _| false);
        report.dec.tasks = 1;
        report.dec.relaunched = decp.relaunched;
        report.dec.virtual_secs = decp.duration();
    }

    let c = assemble_grid(
        GridShape { rows: job.s_a, cols: job.s_b },
        &dec.systematic,
    );
    Ok((c, report))
}

// ---------------------------------------------------------------------------
// Polynomial code baseline
// ---------------------------------------------------------------------------

fn run_polynomial(
    env: &Env,
    a: &Matrix,
    b: &Matrix,
    job: &MatmulJob,
    redundancy: f64,
    rng: &mut Pcg64,
) -> anyhow::Result<(Matrix, JobReport)> {
    let mut report = JobReport::new("polynomial");
    anyhow::ensure!(
        redundancy.is_finite() && redundancy >= 0.0,
        "polynomial redundancy must be a non-negative number"
    );
    let k = job.s_a * job.s_b;
    let n_workers = ((k as f64) * (1.0 + redundancy)).ceil() as usize;
    let code = PolynomialCode::new(job.s_a, job.s_b, n_workers);
    report.redundancy = code.redundancy();

    let pa = Partition::new(a.rows, a.cols, job.s_a);
    let pb = Partition::new(b.rows, b.cols, job.s_b);
    let a_blocks = pa.split(a);
    let b_blocks = pb.split(b);

    let mut sim = env.sim();

    // Encode: every one of the n_workers coded inputs Ã_k/B̃_k is a
    // weighted sum of ALL the side's blocks — n× more encode volume than
    // the local scheme. Column-sliced across a fleet sized like the other
    // schemes' (10% of compute) for a fair comparison.
    let (vm, vk, vl) = job.vdims(a, b);
    let fleet = job.encode_fleet(n_workers);
    let enc_profile = WorkProfile::sliced_encode(
        2 * n_workers,
        job.s_a.max(job.s_b),
        vm / job.s_a,
        vk,
        fleet,
    );
    let mut enc = PhaseState::launch_uniform(
        &mut sim,
        &env.model,
        &enc_profile,
        fleet,
        0,
        Termination::Speculative { wait_frac: 0.95 },
        rng,
    );
    run_phase(&mut sim, &mut enc, &env.model, rng, &mut |_, _| false);
    report.enc.tasks = fleet;
    report.enc.virtual_secs = enc.duration();
    report.enc.blocks_read = n_workers * (job.s_a + job.s_b);

    // Compute: n_workers tasks; MDS termination at the K-th arrival
    // (wait-k as an event policy: the cutoff abandons the stragglers).
    let profile = WorkProfile::block_product(vm / job.s_a, vk, vl / job.s_b);
    let mut comp = PhaseState::launch_uniform(
        &mut sim,
        &env.model,
        &profile,
        n_workers,
        0,
        Termination::WaitK(k),
        rng,
    );
    run_phase(&mut sim, &mut comp, &env.model, rng, &mut |_, _| false);
    report.comp.tasks = n_workers;
    report.comp.stragglers = comp.stragglers();
    report.comp.virtual_secs = comp.duration();

    // Decode: EVERY decode worker reads all K blocks (the paper's
    // communication-overhead point) and the interpolation costs K² block
    // combines.
    let workers = job.decode_workers.max(1);
    let dec_profile = polynomial_decode_profile(k, workers, vm / job.s_a, vl / job.s_b);
    let mut decp = PhaseState::launch_uniform(
        &mut sim,
        &env.model,
        &dec_profile,
        workers,
        0,
        Termination::WaitAll,
        rng,
    );
    run_phase(&mut sim, &mut decp, &env.model, rng, &mut |_, _| false);
    report.dec.tasks = workers;
    report.dec.blocks_read = workers * k;
    report.dec.virtual_secs = decp.duration();

    // Numerics only below the conditioning wall.
    if k > POLY_NUMERIC_CAP {
        report.numerics_ok = false;
        return Ok((Matrix::zeros(a.rows, b.rows), report));
    }
    let first_k: Vec<usize> = comp.arrival_order().to_vec();
    anyhow::ensure!(first_k.len() == k, "wait-k must deliver exactly K arrivals");
    let results: Vec<(usize, Matrix)> = {
        let a_ref = &a_blocks;
        let b_ref = &b_blocks;
        let code_ref = &code;
        let first_ref = &first_k;
        parallel_map(env.threads, k, move |t| {
            let w = first_ref[t];
            let at = code_ref.encode_a(a_ref, w);
            let bt = code_ref.encode_b(b_ref, w);
            (w, env.backend.block_product(&at, &bt))
        })
    };
    let (blocks, _) = code.decode(&results)?;
    let c = assemble_grid(GridShape { rows: job.s_a, cols: job.s_b }, &blocks);
    Ok((c, report))
}

// ---------------------------------------------------------------------------
// Shared numeric helpers
// ---------------------------------------------------------------------------

fn compute_products(
    env: &Env,
    a_blocks: &[Matrix],
    b_blocks: &[Matrix],
    include: impl Fn(usize, usize) -> bool + Sync,
) -> Vec<Option<Matrix>> {
    let sb = b_blocks.len();
    parallel_map(env.threads, a_blocks.len() * sb, move |cell| {
        let (i, j) = (cell / sb, cell % sb);
        if include(i, j) {
            Some(env.backend.block_product(&a_blocks[i], &b_blocks[j]))
        } else {
            None
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul_bt;
    use crate::storage::ObjectStore;

    fn env() -> Env {
        Env::host()
    }

    fn inputs(m: usize, n: usize, l: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Pcg64::new(seed);
        (
            Matrix::randn(m, n, &mut rng, 0.0, 1.0),
            Matrix::randn(l, n, &mut rng, 0.0, 1.0),
        )
    }

    #[test]
    fn local_product_end_to_end_correct() {
        let env = env();
        let (a, b) = inputs(64, 48, 64, 1);
        let job = MatmulJob {
            s_a: 4,
            s_b: 4,
            scheme: Scheme::LocalProduct { l_a: 2, l_b: 2 },
            seed: 7,
            ..Default::default()
        };
        let (c, report) = run_matmul(&env, &a, &b, &job).unwrap();
        assert!(report.rel_err < 1e-4, "rel_err={}", report.rel_err);
        assert!(c.rel_err(&matmul_bt(&a, &b)) < 1e-4);
        assert!(report.total_secs() > 0.0);
        assert!((report.redundancy - 1.25).abs() < 1e-9); // (3·3)/(2·2)−1
    }

    #[test]
    fn local_product_correct_across_seeds() {
        // Different seeds ⇒ different straggler patterns; decode must
        // always reconstruct the exact product.
        let env = env();
        let (a, b) = inputs(48, 32, 48, 2);
        for seed in 0..8 {
            let job = MatmulJob {
                s_a: 4,
                s_b: 4,
                scheme: Scheme::LocalProduct { l_a: 2, l_b: 2 },
                seed,
                job_id: format!("seed{seed}"),
                ..Default::default()
            };
            let (_, report) = run_matmul(&env, &a, &b, &job).unwrap();
            assert!(report.rel_err < 1e-4, "seed {seed}: {}", report.rel_err);
        }
    }

    #[test]
    fn speculative_and_uncoded_correct() {
        let env = env();
        let (a, b) = inputs(32, 24, 32, 3);
        for scheme in [Scheme::Uncoded, Scheme::Speculative { wait_frac: 0.75 }] {
            let job = MatmulJob {
                s_a: 4,
                s_b: 4,
                scheme,
                seed: 5,
                ..Default::default()
            };
            let (_, report) = run_matmul(&env, &a, &b, &job).unwrap();
            assert!(report.rel_err < 1e-5, "{}: {}", report.scheme, report.rel_err);
            assert_eq!(report.enc.virtual_secs, 0.0);
            assert_eq!(report.dec.virtual_secs, 0.0);
            assert!(report.decode_ok);
        }
    }

    #[test]
    fn product_code_correct() {
        let env = env();
        let (a, b) = inputs(32, 24, 32, 4);
        let job = MatmulJob {
            s_a: 4,
            s_b: 4,
            scheme: Scheme::Product { t_a: 1, t_b: 1 },
            seed: 11,
            ..Default::default()
        };
        let (_, report) = run_matmul(&env, &a, &b, &job).unwrap();
        assert!(report.rel_err < 1e-3, "rel_err={}", report.rel_err);
        assert!((report.redundancy - 0.5625).abs() < 1e-9); // 25/16−1
    }

    #[test]
    fn polynomial_code_correct_small() {
        let env = env();
        let (a, b) = inputs(32, 24, 32, 5);
        let job = MatmulJob {
            s_a: 4,
            s_b: 4,
            scheme: Scheme::Polynomial { redundancy: 0.25 },
            seed: 13,
            ..Default::default()
        };
        let (_, report) = run_matmul(&env, &a, &b, &job).unwrap();
        assert!(report.numerics_ok);
        // Real-arithmetic polynomial decode at K=16 already carries ~1e-2
        // relative error (the conditioning wall the paper points to).
        assert!(report.rel_err < 5e-2, "rel_err={}", report.rel_err);
    }

    #[test]
    fn polynomial_large_marks_infeasible() {
        let env = env();
        let (a, b) = inputs(90, 16, 90, 6);
        let job = MatmulJob {
            s_a: 9,
            s_b: 9,
            scheme: Scheme::Polynomial { redundancy: 0.21 },
            seed: 17,
            verify: true,
            ..Default::default()
        };
        let (_, report) = run_matmul(&env, &a, &b, &job).unwrap();
        assert!(!report.numerics_ok); // K = 81 > cap
        assert!(report.comp.virtual_secs > 0.0);
        assert!(report.dec.virtual_secs > 0.0);
    }

    #[test]
    fn phases_populated_for_local_product() {
        let env = env();
        let (a, b) = inputs(64, 32, 64, 7);
        let job = MatmulJob {
            s_a: 4,
            s_b: 4,
            scheme: Scheme::LocalProduct { l_a: 4, l_b: 4 },
            seed: 23,
            ..Default::default()
        };
        let (_, report) = run_matmul(&env, &a, &b, &job).unwrap();
        assert!(report.enc.virtual_secs > 0.0);
        assert!(report.comp.virtual_secs > 0.0);
        assert!(report.dec.virtual_secs > 0.0);
        assert_eq!(report.comp.tasks, 25);
        assert_eq!(report.enc.tasks, 3); // encode fleet = ceil(25/10)
        // Store holds the coded inputs and the results.
        assert_eq!(env.store.list("job/coded/a/").len(), 5);
        assert_eq!(env.store.list("job/result/").len(), 16);
    }

    #[test]
    fn bounded_pool_never_beats_unbounded() {
        // Worker reuse on a pool smaller than the task fan-out can only
        // delay phases; the numerics must stay exact either way.
        let (a, b) = inputs(48, 32, 48, 8);
        let job = MatmulJob {
            s_a: 4,
            s_b: 4,
            scheme: Scheme::LocalProduct { l_a: 2, l_b: 2 },
            seed: 31,
            ..Default::default()
        };
        let unbounded = Env::host();
        let (_, r_unb) = run_matmul(&unbounded, &a, &b, &job).unwrap();
        let mut tight = Env::host();
        tight.pool = Some(4); // 36 compute tasks over 4 workers
        let (_, r_tight) = run_matmul(&tight, &a, &b, &job).unwrap();
        assert!(r_tight.rel_err < 1e-4, "rel_err={}", r_tight.rel_err);
        // Queued starts only delay a fixed duration set: the encode phase
        // (fleet 4, wait_frac 0.95 ⇒ k = n, no relaunch draws) and the
        // earliest-decodable compute cutoff are pointwise monotone in the
        // pool size. (Total time is not compared: speculative relaunch
        // draws in the decode phase attach to different tasks per pool.)
        assert!(r_tight.enc.virtual_secs >= r_unb.enc.virtual_secs - 1e-9);
        assert!(r_tight.comp.virtual_secs >= r_unb.comp.virtual_secs - 1e-9);
        // And a pool at least as large as every phase's fan-out is
        // time-identical to unbounded.
        let mut wide = Env::host();
        wide.pool = Some(100);
        let (_, r_wide) = run_matmul(&wide, &a, &b, &job).unwrap();
        assert_eq!(r_wide.comp.virtual_secs, r_unb.comp.virtual_secs);
        assert_eq!(r_wide.enc.virtual_secs, r_unb.enc.virtual_secs);
        assert_eq!(r_wide.dec.virtual_secs, r_unb.dec.virtual_secs);
    }

    #[test]
    fn rejects_bad_shapes() {
        let env = env();
        let (a, b) = inputs(30, 24, 32, 8);
        let job = MatmulJob {
            s_a: 4,
            ..Default::default()
        };
        assert!(run_matmul(&env, &a, &b, &job).is_err());
    }
}

//! The coded matrix-multiplication workflow — the paper's Fig-2 pipeline
//! (`f_enc → f_comp → f_dec`, all phases on simulated serverless workers)
//! for every registered scheme: local product codes (the contribution),
//! speculative execution, uncoded, global-parity product codes,
//! polynomial codes.
//!
//! Since the `CodingScheme` refactor this module carries no per-scheme
//! logic at all: [`run_matmul`] instantiates the job's scheme through the
//! registry ([`crate::codes::scheme`]) and hands it to the one generic
//! phase driver ([`crate::coordinator::driver::run_job`]). Virtual time
//! and real numerics advance together exactly as before — the straggler
//! model decides *which* output blocks arrive before the cutoff, and the
//! scheme's decode hook must really reconstruct the missing blocks from
//! parities through the compute backend — so every simulated run is also
//! an end-to-end numerical test against `A·Bᵀ`.
//!
//! Each job runs on one [`EventSim`]: the virtual clock carries across
//! the encode → compute → decode phases, cutoffs and speculative
//! relaunches are event-driven policies, and [`Env::pool`] can bound the
//! worker fleet, in which case later phases queue behind still-running
//! tasks (worker reuse). The default unbounded pool reproduces the
//! historical barrier-synchronous timings exactly.

use std::sync::Arc;

use crate::codes::Scheme;
use crate::coordinator::metrics::JobReport;
use crate::linalg::matrix::Matrix;
use crate::platform::event::{EventSim, Pool};
use crate::platform::StragglerModel;
use crate::runtime::ComputeBackend;
use crate::storage::cache::{BlockCache, CachedStore};
use crate::storage::faults::RetryPolicy;
use crate::storage::{MemStore, ObjectStore};
use crate::util::rng::Pcg64;
use crate::util::threadpool::num_threads;

/// Re-exported for backwards compatibility; see
/// [`crate::codes::polynomial::NUMERIC_CAP`].
pub use crate::codes::polynomial::NUMERIC_CAP as POLY_NUMERIC_CAP;

// The per-scheme decode accounting used to live here; it now sits next
// to each scheme's `CodingScheme` impl. Re-exported so older call sites
// keep compiling.
pub use crate::codes::local_product::decode_worker_profiles;
pub use crate::codes::polynomial::polynomial_decode_profile;
pub use crate::codes::product::product_decode_profile;

/// Shared execution environment.
pub struct Env {
    pub backend: Arc<dyn ComputeBackend>,
    /// The simulated S3: a sharded [`MemStore`] by default, optionally
    /// behind an LRU read-through cache (see [`EnvBuilder::cache_bytes`]).
    pub store: Arc<dyn ObjectStore>,
    /// Stats handle of the read-through cache, when one is configured.
    pub cache: Option<Arc<BlockCache>>,
    pub model: StragglerModel,
    /// Host threads used to execute the real numerics.
    pub threads: usize,
    /// Serverless worker-pool capacity for the event simulator: `None` ⇒
    /// unbounded on-demand fleet (the paper's Lambda assumption and the
    /// legacy behaviour); `Some(w)` ⇒ at most `w` concurrent workers,
    /// with excess tasks queueing FIFO.
    pub pool: Option<usize>,
    /// Retry/backoff policy for staged block-product read-back — how
    /// hard the driver tries before demoting a block to an erasure.
    pub retry: RetryPolicy,
}

/// Builder for [`Env`] — the one source of environment defaults
/// (host backend, fresh store, paper-calibrated straggler model, all
/// cores, unbounded pool).
#[derive(Default)]
pub struct EnvBuilder {
    backend: Option<Arc<dyn ComputeBackend>>,
    store: Option<Arc<dyn ObjectStore>>,
    model: Option<StragglerModel>,
    threads: Option<usize>,
    pool: Option<usize>,
    cache_bytes: usize,
    retry: Option<RetryPolicy>,
}

impl EnvBuilder {
    /// Compute backend (default: the pure-Rust [`crate::runtime::HostBackend`]).
    pub fn backend(mut self, backend: Arc<dyn ComputeBackend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Object store (default: a fresh sharded [`MemStore`]).
    pub fn store(mut self, store: Arc<dyn ObjectStore>) -> Self {
        self.store = Some(store);
        self
    }

    /// Put an LRU read-through cache of `bytes` capacity in front of the
    /// store (default: none; 0 disables).
    pub fn cache_bytes(mut self, bytes: usize) -> Self {
        self.cache_bytes = bytes;
        self
    }

    /// Straggler model (default: the paper's AWS-Lambda calibration).
    pub fn model(mut self, model: StragglerModel) -> Self {
        self.model = Some(model);
        self
    }

    /// Host threads for the real numerics (default: all cores).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// Bound the simulated worker fleet (default: unbounded).
    pub fn pool(mut self, workers: usize) -> Self {
        self.pool = Some(workers);
        self
    }

    /// Retry/backoff policy for staged block reads (default:
    /// [`RetryPolicy::default`] — 3 retries, 1 s exponential backoff).
    pub fn retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = Some(retry);
        self
    }

    pub fn build(self) -> Env {
        let base: Arc<dyn ObjectStore> = self.store.unwrap_or_else(|| Arc::new(MemStore::new()));
        let (store, cache) = if self.cache_bytes > 0 {
            let cached = Arc::new(CachedStore::new(base, self.cache_bytes));
            let handle = cached.cache();
            (cached as Arc<dyn ObjectStore>, Some(handle))
        } else {
            (base, None)
        };
        Env {
            backend: self
                .backend
                .unwrap_or_else(|| Arc::new(crate::runtime::HostBackend)),
            store,
            cache,
            model: self
                .model
                .unwrap_or_else(|| StragglerModel::new(Default::default(), Default::default())),
            threads: self.threads.unwrap_or_else(num_threads),
            pool: self.pool,
            retry: self.retry.unwrap_or_default(),
        }
    }
}

impl Env {
    /// Start building an environment from the defaults.
    pub fn builder() -> EnvBuilder {
        EnvBuilder::default()
    }

    /// Host-backend environment with default platform calibration.
    pub fn host() -> Env {
        Env::builder().build()
    }

    /// Environment with an explicit backend (e.g. PJRT).
    pub fn with_backend(backend: Arc<dyn ComputeBackend>) -> Env {
        Env::builder().backend(backend).build()
    }

    /// Fresh event simulator over this environment's worker pool.
    pub fn sim(&self) -> EventSim {
        EventSim::new(Pool::from_option(self.pool))
    }
}

/// A coded matmul job description (`C = A·Bᵀ`).
#[derive(Debug, Clone)]
pub struct MatmulJob {
    /// Systematic row-blocks of A / B.
    pub s_a: usize,
    pub s_b: usize,
    pub scheme: Scheme,
    /// Parallel decoding workers (Remark 3).
    pub decode_workers: usize,
    /// Parallel encoding workers (Remark 1: encoding is column-sliced
    /// across a small worker fleet, <10% of the compute phase; 0 ⇒ auto =
    /// ceil(compute_tasks / 10)).
    pub encode_workers: usize,
    /// Verify the output against the direct product (costs a host GEMM).
    pub verify: bool,
    pub seed: u64,
    /// Unique job id for store keys.
    pub job_id: String,
    /// Full-matrix dims `(rows_a, k, rows_b)` used for the *virtual-time*
    /// work profiles. `None` ⇒ the actual matrix dims. Figure harnesses
    /// set this to the PAPER's scale (e.g. 0.5M) so simulated seconds are
    /// comparable to the paper's plots while the verified numerics run at
    /// lab scale (DESIGN.md §Virtual-time model).
    pub virtual_dims: Option<(usize, usize, usize)>,
}

impl Default for MatmulJob {
    fn default() -> Self {
        MatmulJob {
            s_a: 4,
            s_b: 4,
            scheme: Scheme::LocalProduct { l_a: 2, l_b: 2 },
            decode_workers: 4,
            encode_workers: 0,
            verify: true,
            seed: 0,
            job_id: "job".into(),
            virtual_dims: None,
        }
    }
}

/// Builder for [`MatmulJob`] so call sites stop constructing
/// field-structs by hand. Starts from [`MatmulJob::default`].
#[derive(Debug, Clone, Default)]
pub struct MatmulJobBuilder {
    job: MatmulJob,
}

impl MatmulJobBuilder {
    /// Systematic row-blocks per side.
    pub fn blocks(mut self, s_a: usize, s_b: usize) -> Self {
        self.job.s_a = s_a;
        self.job.s_b = s_b;
        self
    }

    pub fn scheme(mut self, scheme: Scheme) -> Self {
        self.job.scheme = scheme;
        self
    }

    pub fn decode_workers(mut self, n: usize) -> Self {
        self.job.decode_workers = n;
        self
    }

    pub fn encode_workers(mut self, n: usize) -> Self {
        self.job.encode_workers = n;
        self
    }

    pub fn verify(mut self, verify: bool) -> Self {
        self.job.verify = verify;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.job.seed = seed;
        self
    }

    pub fn job_id(mut self, id: impl Into<String>) -> Self {
        self.job.job_id = id.into();
        self
    }

    /// Paper-scale dims `(rows_a, k, rows_b)` for virtual time.
    pub fn virtual_dims(mut self, dims: (usize, usize, usize)) -> Self {
        self.job.virtual_dims = Some(dims);
        self
    }

    /// Cube virtual dims (`d × d × d`), the common figure-harness case.
    pub fn virtual_cube(mut self, d: usize) -> Self {
        self.job.virtual_dims = Some((d, d, d));
        self
    }

    pub fn build(self) -> MatmulJob {
        self.job
    }
}

impl MatmulJob {
    pub fn builder() -> MatmulJobBuilder {
        MatmulJobBuilder::default()
    }

    /// Virtual-time dims for profile building.
    pub(crate) fn vdims(&self, a: &Matrix, b: &Matrix) -> (usize, usize, usize) {
        self.virtual_dims.unwrap_or((a.rows, a.cols, b.rows))
    }

    /// Encode fleet size (Remark 1): explicit or ~10% of compute tasks.
    pub(crate) fn encode_fleet(&self, compute_tasks: usize) -> usize {
        if self.encode_workers > 0 {
            self.encode_workers
        } else {
            compute_tasks.div_ceil(10).max(1)
        }
    }
}

/// Run the job; returns the output matrix and the phase report. All five
/// schemes (and any future registry entry) execute through the one
/// generic driver — there is no per-scheme dispatch here.
pub fn run_matmul(
    env: &Env,
    a: &Matrix,
    b: &Matrix,
    job: &MatmulJob,
) -> anyhow::Result<(Matrix, JobReport)> {
    anyhow::ensure!(a.cols == b.cols, "A (m×n) · Bᵀ needs matching n");
    anyhow::ensure!(a.rows % job.s_a == 0, "A rows must divide s_a");
    anyhow::ensure!(b.rows % job.s_b == 0, "B rows must divide s_b");
    let scheme = job.scheme.instantiate(job.s_a, job.s_b)?;
    let mut rng = Pcg64::new(job.seed);

    let (c, mut report) =
        crate::coordinator::driver::run_job(env, a, b, job, scheme.as_ref(), &mut rng)?;

    if job.verify && report.numerics_ok {
        let direct = env.backend.block_product(a, b);
        report.rel_err = c.rel_err(&direct);
    }
    Ok((c, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm::matmul_bt;
    use crate::storage::ObjectStore;

    fn env() -> Env {
        Env::host()
    }

    fn inputs(m: usize, n: usize, l: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Pcg64::new(seed);
        (
            Matrix::randn(m, n, &mut rng, 0.0, 1.0),
            Matrix::randn(l, n, &mut rng, 0.0, 1.0),
        )
    }

    #[test]
    fn local_product_end_to_end_correct() {
        let env = env();
        let (a, b) = inputs(64, 48, 64, 1);
        let job = MatmulJob {
            s_a: 4,
            s_b: 4,
            scheme: Scheme::LocalProduct { l_a: 2, l_b: 2 },
            seed: 7,
            ..Default::default()
        };
        let (c, report) = run_matmul(&env, &a, &b, &job).unwrap();
        assert!(report.rel_err < 1e-4, "rel_err={}", report.rel_err);
        assert!(c.rel_err(&matmul_bt(&a, &b)) < 1e-4);
        assert!(report.total_secs() > 0.0);
        assert!((report.redundancy - 1.25).abs() < 1e-9); // (3·3)/(2·2)−1
    }

    #[test]
    fn local_product_correct_across_seeds() {
        // Different seeds ⇒ different straggler patterns; decode must
        // always reconstruct the exact product.
        let env = env();
        let (a, b) = inputs(48, 32, 48, 2);
        for seed in 0..8 {
            let job = MatmulJob {
                s_a: 4,
                s_b: 4,
                scheme: Scheme::LocalProduct { l_a: 2, l_b: 2 },
                seed,
                job_id: format!("seed{seed}"),
                ..Default::default()
            };
            let (_, report) = run_matmul(&env, &a, &b, &job).unwrap();
            assert!(report.rel_err < 1e-4, "seed {seed}: {}", report.rel_err);
        }
    }

    #[test]
    fn speculative_and_uncoded_correct() {
        let env = env();
        let (a, b) = inputs(32, 24, 32, 3);
        for scheme in [Scheme::Uncoded, Scheme::Speculative { wait_frac: 0.75 }] {
            let job = MatmulJob {
                s_a: 4,
                s_b: 4,
                scheme,
                seed: 5,
                ..Default::default()
            };
            let (_, report) = run_matmul(&env, &a, &b, &job).unwrap();
            assert!(report.rel_err < 1e-5, "{}: {}", report.scheme, report.rel_err);
            assert_eq!(report.enc.virtual_secs, 0.0);
            assert_eq!(report.dec.virtual_secs, 0.0);
            assert!(report.decode_ok);
        }
    }

    #[test]
    fn product_code_correct() {
        let env = env();
        let (a, b) = inputs(32, 24, 32, 4);
        let job = MatmulJob {
            s_a: 4,
            s_b: 4,
            scheme: Scheme::Product { t_a: 1, t_b: 1 },
            seed: 11,
            ..Default::default()
        };
        let (_, report) = run_matmul(&env, &a, &b, &job).unwrap();
        assert!(report.rel_err < 1e-3, "rel_err={}", report.rel_err);
        assert!((report.redundancy - 0.5625).abs() < 1e-9); // 25/16−1
    }

    #[test]
    fn polynomial_code_correct_small() {
        let env = env();
        let (a, b) = inputs(32, 24, 32, 5);
        let job = MatmulJob {
            s_a: 4,
            s_b: 4,
            scheme: Scheme::Polynomial { redundancy: 0.25 },
            seed: 13,
            ..Default::default()
        };
        let (_, report) = run_matmul(&env, &a, &b, &job).unwrap();
        assert!(report.numerics_ok);
        // Real-arithmetic polynomial decode at K=16 already carries ~1e-2
        // relative error (the conditioning wall the paper points to).
        assert!(report.rel_err < 5e-2, "rel_err={}", report.rel_err);
    }

    #[test]
    fn polynomial_large_marks_infeasible() {
        let env = env();
        let (a, b) = inputs(90, 16, 90, 6);
        let job = MatmulJob {
            s_a: 9,
            s_b: 9,
            scheme: Scheme::Polynomial { redundancy: 0.21 },
            seed: 17,
            verify: true,
            ..Default::default()
        };
        let (_, report) = run_matmul(&env, &a, &b, &job).unwrap();
        assert!(!report.numerics_ok); // K = 81 > cap
        assert!(report.comp.virtual_secs > 0.0);
        assert!(report.dec.virtual_secs > 0.0);
    }

    #[test]
    fn phases_populated_for_local_product() {
        let env = env();
        let (a, b) = inputs(64, 32, 64, 7);
        let job = MatmulJob {
            s_a: 4,
            s_b: 4,
            scheme: Scheme::LocalProduct { l_a: 4, l_b: 4 },
            seed: 23,
            ..Default::default()
        };
        let (_, report) = run_matmul(&env, &a, &b, &job).unwrap();
        assert!(report.enc.virtual_secs > 0.0);
        assert!(report.comp.virtual_secs > 0.0);
        assert!(report.dec.virtual_secs > 0.0);
        assert_eq!(report.comp.tasks, 25);
        assert_eq!(report.enc.tasks, 3); // encode fleet = ceil(25/10)
        // Store holds the coded inputs and the results.
        assert_eq!(env.store.list("job/coded/a/").len(), 5);
        assert_eq!(env.store.list("job/result/").len(), 16);
    }

    #[test]
    fn bounded_pool_never_beats_unbounded() {
        // Worker reuse on a pool smaller than the task fan-out can only
        // delay phases; the numerics must stay exact either way.
        let (a, b) = inputs(48, 32, 48, 8);
        let job = MatmulJob {
            s_a: 4,
            s_b: 4,
            scheme: Scheme::LocalProduct { l_a: 2, l_b: 2 },
            seed: 31,
            ..Default::default()
        };
        let unbounded = Env::host();
        let (_, r_unb) = run_matmul(&unbounded, &a, &b, &job).unwrap();
        let tight = Env::builder().pool(4).build(); // 36 compute tasks over 4 workers
        let (_, r_tight) = run_matmul(&tight, &a, &b, &job).unwrap();
        assert!(r_tight.rel_err < 1e-4, "rel_err={}", r_tight.rel_err);
        // Queued starts only delay a fixed duration set: the encode phase
        // (fleet 4, wait_frac 0.95 ⇒ k = n, no relaunch draws) and the
        // earliest-decodable compute cutoff are pointwise monotone in the
        // pool size. (Total time is not compared: speculative relaunch
        // draws in the decode phase attach to different tasks per pool.)
        assert!(r_tight.enc.virtual_secs >= r_unb.enc.virtual_secs - 1e-9);
        assert!(r_tight.comp.virtual_secs >= r_unb.comp.virtual_secs - 1e-9);
        // And a pool at least as large as every phase's fan-out is
        // time-identical to unbounded.
        let wide = Env::builder().pool(100).build();
        let (_, r_wide) = run_matmul(&wide, &a, &b, &job).unwrap();
        assert_eq!(r_wide.comp.virtual_secs, r_unb.comp.virtual_secs);
        assert_eq!(r_wide.enc.virtual_secs, r_unb.enc.virtual_secs);
        assert_eq!(r_wide.dec.virtual_secs, r_unb.dec.virtual_secs);
    }

    #[test]
    fn staging_roundtrips_through_cached_store_with_manifest() {
        let env = Env::builder().cache_bytes(1 << 20).build();
        let (a, b) = inputs(64, 48, 64, 9);
        let job = MatmulJob {
            s_a: 4,
            s_b: 4,
            scheme: Scheme::LocalProduct { l_a: 2, l_b: 2 },
            seed: 3,
            job_id: "cached".into(),
            ..Default::default()
        };
        let (_, report) = run_matmul(&env, &a, &b, &job).unwrap();
        assert!(report.rel_err < 1e-4, "rel_err={}", report.rel_err);

        // The staging scheme attributes its store traffic to the report:
        // coded inputs + block products + results in, decode reads out.
        let st = report.storage.expect("staging scheme reports storage");
        assert!(st.puts > 0 && st.bytes_in > 0);
        assert!(st.gets > 0 && st.hits == st.gets, "all reads must hit");
        // Every decode read was cold exactly once (read-through fill).
        assert_eq!(st.cache_misses, st.gets);

        // Worker block-products are staged under out/ and the manifest
        // indexes every staged key (itself excluded).
        assert!(!env.store.list("cached/out/").is_empty());
        let man = crate::runtime::JobManifest::load(env.store.as_ref(), "cached").unwrap();
        assert_eq!(man.len(), env.store.list("cached/").len() - 1);
        assert!(man.get("cached/result/00000x00000").is_some());
        assert!(man.total_bytes() > 0);

        // The cache actually serves repeats: a second read of the same
        // object is a hit that never reaches the backing store.
        let cache = env.cache.as_ref().expect("cache configured");
        let before = cache.stats();
        let key = "cached/result/00000x00000";
        let _ = env.store.get(key);
        let _ = env.store.get(key);
        assert!(cache.stats().hits > before.hits);
    }

    #[test]
    fn rejects_bad_shapes() {
        let env = env();
        let (a, b) = inputs(30, 24, 32, 8);
        let job = MatmulJob {
            s_a: 4,
            ..Default::default()
        };
        assert!(run_matmul(&env, &a, &b, &job).is_err());
    }

    #[test]
    fn builders_mirror_field_construction() {
        let job = MatmulJob::builder()
            .blocks(8, 4)
            .scheme(Scheme::Product { t_a: 1, t_b: 2 })
            .decode_workers(3)
            .encode_workers(2)
            .verify(false)
            .seed(99)
            .job_id("built")
            .virtual_cube(20_000)
            .build();
        assert_eq!(job.s_a, 8);
        assert_eq!(job.s_b, 4);
        assert_eq!(job.scheme, Scheme::Product { t_a: 1, t_b: 2 });
        assert_eq!(job.decode_workers, 3);
        assert_eq!(job.encode_workers, 2);
        assert!(!job.verify);
        assert_eq!(job.seed, 99);
        assert_eq!(job.job_id, "built");
        assert_eq!(job.virtual_dims, Some((20_000, 20_000, 20_000)));
        // Env builder: defaults equal Env::host(), overrides stick.
        let e = Env::builder().threads(2).pool(7).build();
        assert_eq!(e.threads, 2);
        assert_eq!(e.pool, Some(7));
        assert_eq!(e.backend.name(), Env::host().backend.name());
    }
}

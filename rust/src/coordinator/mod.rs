//! The serverless coordinator — the paper's workflow engine.
//!
//! Orchestrates the three phases of Fig 2 over the platform simulator and
//! the compute backend: parallel encode, straggler-prone compute with
//! scheme-specific termination, and parallel local decode with recompute
//! fallback. End-to-end latency is `T_enc + T_comp + T_dec`.

pub mod matmul;
pub mod matvec;
pub mod metrics;

pub use matmul::{run_matmul, Env, MatmulJob};
pub use matvec::{IterationReport, MatvecEngine};
pub use metrics::{JobReport, PhaseMetrics, REPORT_HEADERS};

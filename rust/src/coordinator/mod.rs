//! The serverless coordinator — the paper's workflow engine.
//!
//! Orchestrates the three phases of Fig 2 over the platform simulator and
//! the compute backend: parallel encode, straggler-prone compute with
//! scheme-specific termination, and parallel local decode with recompute
//! fallback. End-to-end latency is `T_enc + T_comp + T_dec`.
//!
//! Scheme knowledge lives behind the [`crate::codes::scheme::CodingScheme`]
//! trait; [`driver::run_job`] is the one generic phase driver every
//! scheme (and workload) executes through.

pub mod api;
pub mod driver;
pub mod matmul;
pub mod matvec;
pub mod metrics;
pub mod service;

pub use driver::run_job;
pub use matmul::{run_matmul, Env, EnvBuilder, MatmulJob, MatmulJobBuilder};
pub use matvec::{IterationReport, MatvecEngine};
pub use metrics::{JobReport, PhaseMetrics, REPORT_HEADERS};

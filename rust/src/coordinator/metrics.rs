//! Job metrics: the `T_enc + T_comp + T_dec` decomposition the paper's
//! evaluation revolves around (Fig 2), plus communication accounting.

use crate::storage::faults::StorageFaultMetrics;
use crate::util::json::{obj, Json};

/// One phase's virtual-time outcome.
#[derive(Debug, Clone, Default)]
pub struct PhaseMetrics {
    /// Virtual seconds this phase took (its makespan under the scheme's
    /// termination rule).
    pub virtual_secs: f64,
    /// Tasks launched.
    pub tasks: usize,
    /// Tasks that straggled (per the model).
    pub stragglers: usize,
    /// Tasks relaunched (speculative) or recomputed (undecodable).
    pub relaunched: usize,
    /// Blocks read by this phase's workers.
    pub blocks_read: usize,
}

impl PhaseMetrics {
    pub fn to_json(&self) -> Json {
        obj()
            .field("virtual_secs", self.virtual_secs)
            .field("tasks", self.tasks)
            .field("stragglers", self.stragglers)
            .field("relaunched", self.relaunched)
            .field("blocks_read", self.blocks_read)
            .build()
    }
}

/// Object-store traffic of one job (deltas over the job's lifetime).
/// `cache_*` stay zero when the environment has no read-through cache.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StorageMetrics {
    pub puts: u64,
    pub gets: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub hits: u64,
    pub misses: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl StorageMetrics {
    pub fn to_json(&self) -> Json {
        obj()
            .field("puts", self.puts)
            .field("gets", self.gets)
            .field("bytes_in", self.bytes_in)
            .field("bytes_out", self.bytes_out)
            .field("hits", self.hits)
            .field("misses", self.misses)
            .field("cache_hits", self.cache_hits)
            .field("cache_misses", self.cache_misses)
            .build()
    }
}

/// Fault-injection outcome of one job — only emitted when the scenario's
/// `"failures"` section is present, so fault-free reports keep their
/// historical byte-for-byte shape.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultMetrics {
    /// Failed attempts observed (every worker death, retried or not).
    pub deaths: u64,
    /// Re-dispatches performed after failures.
    pub retries: u64,
    /// Logical tasks that exhausted their retry budget (permanent loss).
    pub exhausted: u64,
    /// Deaths absorbed by a live twin attempt (speculative relaunch or
    /// stolen remainder): no re-dispatch was needed, so
    /// `deaths == retries + exhausted + absorbed` holds exactly.
    pub absorbed: u64,
    /// True when some phase ended without all the work it wanted — the
    /// graceful-degradation flag (`decode_ok` goes false with it).
    pub degraded: bool,
    /// Attempts dispatched per worker class, in model order; empty for a
    /// homogeneous fleet.
    pub classes: Vec<(String, u64)>,
}

impl FaultMetrics {
    pub fn to_json(&self) -> Json {
        let mut doc = obj()
            .field("deaths", self.deaths)
            .field("retries", self.retries)
            .field("exhausted", self.exhausted)
            .field("absorbed", self.absorbed)
            .field("degraded", self.degraded)
            .build();
        if !self.classes.is_empty() {
            let mut by_class = obj().build();
            for (name, count) in &self.classes {
                by_class.set(name, Json::from(*count));
            }
            doc.set("classes", by_class);
        }
        doc
    }
}

/// Sub-task progress outcome of one job — only emitted when the
/// scenario's `"progress"` section is present, so progress-free reports
/// keep their historical byte-for-byte shape.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ProgressMetrics {
    /// Mid-task progress slices observed across all primary attempts.
    pub slices_arrived: u64,
    /// Flops of straggler partial work the job actually used (kept
    /// slices of stolen/retried remainders plus credited stragglers).
    pub exploited_flops: f64,
    /// Lagging tasks whose uncompleted remainder was re-dispatched.
    pub remainders_stolen: u64,
}

impl ProgressMetrics {
    pub fn to_json(&self) -> Json {
        obj()
            .field("slices_arrived", self.slices_arrived)
            .field("exploited_flops", self.exploited_flops)
            .field("remainders_stolen", self.remainders_stolen)
            .build()
    }
}

/// End-to-end report for one coded job.
#[derive(Debug, Clone)]
pub struct JobReport {
    pub scheme: String,
    pub enc: PhaseMetrics,
    pub comp: PhaseMetrics,
    pub dec: PhaseMetrics,
    /// Redundant computation fraction of the scheme.
    pub redundancy: f64,
    /// Relative Frobenius error of the output vs the direct product
    /// (NaN when not verified).
    pub rel_err: f64,
    /// False when the scheme could not produce numerics at this scale
    /// (polynomial codes past their conditioning wall — the paper's
    /// "not feasible" regime).
    pub numerics_ok: bool,
    /// True when the decode phase recovered every straggler from parities
    /// alone; false when a recompute round was needed. Under the current
    /// earliest-decodable termination this is an *invariant* (the cutoff
    /// only fires on decodable masks, so the recompute fallback is
    /// defensive); cutoff policies that cannot guarantee decodability —
    /// deadlines, adaptive/partial-work coding — will report false here.
    pub decode_ok: bool,
    /// Object-store traffic of this job; `None` for timing-only runs
    /// (the scenario runner) and schemes that stage nothing.
    pub storage: Option<StorageMetrics>,
    /// Fault-injection outcome; `None` when the run has no `"failures"`
    /// section (keeps pre-churn reports byte-identical).
    pub faults: Option<FaultMetrics>,
    /// Sub-task progress outcome; `None` when the run has no
    /// `"progress"` section (keeps pre-progress reports byte-identical).
    pub progress: Option<ProgressMetrics>,
    /// Storage-fault outcome; `None` unless at least one fault event
    /// touched this job (keeps pre-fault reports byte-identical).
    pub storage_faults: Option<StorageFaultMetrics>,
}

impl JobReport {
    pub fn new(scheme: &str) -> JobReport {
        JobReport {
            scheme: scheme.to_string(),
            enc: PhaseMetrics::default(),
            comp: PhaseMetrics::default(),
            dec: PhaseMetrics::default(),
            redundancy: 0.0,
            rel_err: f64::NAN,
            numerics_ok: true,
            decode_ok: true,
            storage: None,
            faults: None,
            progress: None,
            storage_faults: None,
        }
    }

    /// `T_tot = T_enc + T_comp + T_dec` (§I).
    pub fn total_secs(&self) -> f64 {
        self.enc.virtual_secs + self.comp.virtual_secs + self.dec.virtual_secs
    }

    pub fn to_json(&self) -> Json {
        let mut doc = obj()
            .field("scheme", self.scheme.as_str())
            .field("t_enc", self.enc.virtual_secs)
            .field("t_comp", self.comp.virtual_secs)
            .field("t_dec", self.dec.virtual_secs)
            .field("t_total", self.total_secs())
            .field("redundancy", self.redundancy)
            .field("rel_err", self.rel_err)
            .field("numerics_ok", self.numerics_ok)
            .field("decode_ok", self.decode_ok)
            .field("enc", self.enc.to_json())
            .field("comp", self.comp.to_json())
            .field("dec", self.dec.to_json())
            .build();
        // Appended (not interleaved) so documents without storage data
        // keep their historical byte-for-byte shape.
        if let Some(s) = &self.storage {
            doc.set("storage", s.to_json());
        }
        if let Some(f) = &self.faults {
            doc.set("faults", f.to_json());
        }
        if let Some(p) = &self.progress {
            doc.set("progress", p.to_json());
        }
        if let Some(sf) = &self.storage_faults {
            doc.set("storage_faults", sf.to_json());
        }
        doc
    }

    /// One table row: scheme, T_enc, T_comp, T_dec, total.
    pub fn row(&self) -> Vec<String> {
        vec![
            self.scheme.clone(),
            format!("{:.1}", self.enc.virtual_secs),
            format!("{:.1}", self.comp.virtual_secs),
            format!("{:.1}", self.dec.virtual_secs),
            format!("{:.1}", self.total_secs()),
            if self.rel_err.is_nan() {
                "-".into()
            } else {
                format!("{:.2e}", self.rel_err)
            },
        ]
    }
}

pub const REPORT_HEADERS: [&str; 6] =
    ["scheme", "T_enc (s)", "T_comp (s)", "T_dec (s)", "T_total (s)", "rel_err"];

/// Streaming sample accumulator with *exact* quantiles, for the golden
/// latency pins of the coordinator service (and any other report that
/// needs p50/p95/p99 at golden precision).
///
/// Samples are appended in O(1); the sorted view is built lazily on the
/// first quantile query after an insert and cached until the next
/// insert. Exactness matters more than memory here: goldens compare at
/// 1e-6 tolerance, so sketch-style approximations (t-digest, HDR) would
/// make the pinned percentiles depend on ingestion order.
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples: Vec<f64>,
    /// Sorted copy of `samples`; rebuilt lazily, invalidated on insert.
    sorted: Vec<f64>,
    sum: f64,
}

impl LatencyStats {
    pub fn new() -> LatencyStats {
        LatencyStats::default()
    }

    /// Record one sample. Non-finite values are a caller bug.
    pub fn record(&mut self, x: f64) {
        assert!(x.is_finite(), "latency sample must be finite, got {x}");
        self.samples.push(x);
        self.sorted.clear();
        self.sum += x;
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            f64::NAN
        } else {
            self.sum / self.samples.len() as f64
        }
    }

    fn sorted(&mut self) -> &[f64] {
        if self.sorted.is_empty() && !self.samples.is_empty() {
            self.sorted = self.samples.clone();
            self.sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        }
        &self.sorted
    }

    /// Exact linear-interpolated quantile, `q` in `[0, 1]`; NaN when
    /// empty. `&mut` because the sorted cache may need a rebuild.
    pub fn quantile(&mut self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        crate::util::stats::percentile_sorted(self.sorted(), q)
    }

    pub fn min(&mut self) -> f64 {
        self.quantile(0.0)
    }

    pub fn max(&mut self) -> f64 {
        self.quantile(1.0)
    }

    /// Counts per equal-width bucket over `[lo, hi)`, with underflow
    /// clamped into the first bucket and overflow into the last — a
    /// fixed-shape histogram goldens can pin without knowing the range
    /// of the data in advance.
    pub fn bucket_counts(&self, lo: f64, hi: f64, buckets: usize) -> Vec<u64> {
        assert!(buckets >= 1, "need at least one bucket");
        assert!(hi > lo, "bucket range must be non-empty");
        let mut counts = vec![0u64; buckets];
        let width = (hi - lo) / buckets as f64;
        for &x in &self.samples {
            let i = (((x - lo) / width).floor() as isize).clamp(0, buckets as isize - 1);
            counts[i as usize] += 1;
        }
        counts
    }

    /// The summary shape every service report uses:
    /// `{count, mean, min, p50, p95, p99, max}`.
    pub fn to_json(&mut self) -> Json {
        obj()
            .field("count", self.count())
            .field("mean", self.mean())
            .field("min", self.min())
            .field("p50", self.quantile(0.50))
            .field("p95", self.quantile(0.95))
            .field("p99", self.quantile(0.99))
            .field("max", self.max())
            .build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_add_up() {
        let mut r = JobReport::new("local-product");
        r.enc.virtual_secs = 10.0;
        r.comp.virtual_secs = 100.0;
        r.dec.virtual_secs = 5.0;
        assert!((r.total_secs() - 115.0).abs() < 1e-12);
        let j = r.to_json();
        assert_eq!(j.get("t_total").unwrap().as_f64(), Some(115.0));
        assert_eq!(j.get("scheme").unwrap().as_str(), Some("local-product"));
    }

    #[test]
    fn storage_block_appears_only_when_present() {
        let mut r = JobReport::new("local-product");
        assert!(r.to_json().get("storage").is_none());
        r.storage = Some(StorageMetrics {
            puts: 3,
            gets: 7,
            bytes_in: 100,
            bytes_out: 250,
            hits: 7,
            misses: 0,
            cache_hits: 2,
            cache_misses: 5,
        });
        let j = r.to_json();
        let s = j.get("storage").expect("storage block");
        assert_eq!(s.get("puts").unwrap().as_u64(), Some(3));
        assert_eq!(s.get("cache_misses").unwrap().as_u64(), Some(5));
    }

    #[test]
    fn faults_block_appears_only_when_present() {
        let mut r = JobReport::new("uncoded");
        assert!(r.to_json().get("faults").is_none());
        r.faults = Some(FaultMetrics {
            deaths: 4,
            retries: 2,
            exhausted: 1,
            absorbed: 1,
            degraded: true,
            classes: vec![("warm".into(), 10), ("cold".into(), 2)],
        });
        let j = r.to_json();
        let f = j.get("faults").expect("faults block");
        assert_eq!(f.get("deaths").unwrap().as_u64(), Some(4));
        assert_eq!(f.get("absorbed").unwrap().as_u64(), Some(1));
        assert_eq!(f.get("degraded").unwrap().as_bool(), Some(true));
        let c = f.get("classes").expect("classes map");
        assert_eq!(c.get("warm").unwrap().as_u64(), Some(10));
        assert_eq!(c.get("cold").unwrap().as_u64(), Some(2));
        // A homogeneous fleet omits the classes map entirely.
        r.faults.as_mut().unwrap().classes.clear();
        assert!(r.to_json().get("faults").unwrap().get("classes").is_none());
    }

    #[test]
    fn progress_block_appears_only_when_present() {
        let mut r = JobReport::new("local-product");
        assert!(r.to_json().get("progress").is_none());
        r.progress = Some(ProgressMetrics {
            slices_arrived: 96,
            exploited_flops: 1.5e9,
            remainders_stolen: 2,
        });
        let j = r.to_json();
        let p = j.get("progress").expect("progress block");
        assert_eq!(p.get("slices_arrived").unwrap().as_u64(), Some(96));
        assert_eq!(p.get("remainders_stolen").unwrap().as_u64(), Some(2));
        assert_eq!(p.get("exploited_flops").unwrap().as_f64(), Some(1.5e9));
    }

    #[test]
    fn storage_faults_block_appears_only_when_present() {
        let mut r = JobReport::new("local-product");
        assert!(r.to_json().get("storage_faults").is_none());
        r.storage_faults = Some(StorageFaultMetrics {
            transients: 5,
            retries: 6,
            lost: 1,
            corrupt: 2,
            recovered_via_parity: 1,
        });
        let j = r.to_json();
        let sf = j.get("storage_faults").expect("storage_faults block");
        assert_eq!(sf.get("transients").unwrap().as_u64(), Some(5));
        assert_eq!(sf.get("retries").unwrap().as_u64(), Some(6));
        assert_eq!(sf.get("lost").unwrap().as_u64(), Some(1));
        assert_eq!(sf.get("corrupt").unwrap().as_u64(), Some(2));
        assert_eq!(sf.get("recovered_via_parity").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn latency_stats_exact_quantiles() {
        let mut s = LatencyStats::new();
        // 1..=100 in scrambled order: exact quantiles of a known set.
        for i in (1..=100).rev() {
            s.record(i as f64);
        }
        assert_eq!(s.count(), 100);
        assert!((s.mean() - 50.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
        // percentile_sorted interpolates over n-1 gaps: p50 of 1..=100
        // is 50.5, p95 is 95.05, p99 is 99.01.
        assert!((s.quantile(0.50) - 50.5).abs() < 1e-9);
        assert!((s.quantile(0.95) - 95.05).abs() < 1e-9);
        assert!((s.quantile(0.99) - 99.01).abs() < 1e-9);
        let j = s.to_json();
        assert_eq!(j.get("count").unwrap().as_u64(), Some(100));
        assert!((j.get("p95").unwrap().as_f64().unwrap() - 95.05).abs() < 1e-9);
    }

    #[test]
    fn latency_stats_cache_invalidates_on_insert() {
        let mut s = LatencyStats::new();
        s.record(10.0);
        assert_eq!(s.quantile(1.0), 10.0); // builds the sorted cache
        s.record(2.0); // must invalidate it
        assert_eq!(s.quantile(0.0), 2.0);
        assert_eq!(s.quantile(1.0), 10.0);
        assert_eq!(s.count(), 2);
    }

    #[test]
    fn latency_stats_empty_is_nan() {
        let mut s = LatencyStats::new();
        assert!(s.is_empty());
        assert!(s.mean().is_nan());
        assert!(s.quantile(0.5).is_nan());
        // NaN serializes as null — a golden wildcard, never a crash.
        let j = s.to_json();
        assert!(j.get("p50").unwrap().as_f64().unwrap().is_nan());
        assert!(j.to_string_pretty().contains("\"p50\": null"));
    }

    #[test]
    fn latency_stats_bucket_counts_clamp() {
        let mut s = LatencyStats::new();
        for x in [-5.0, 0.0, 1.5, 2.5, 9.9, 42.0] {
            s.record(x);
        }
        // 5 buckets over [0, 10): width 2. Underflow joins bucket 0,
        // overflow joins the last.
        assert_eq!(s.bucket_counts(0.0, 10.0, 5), vec![3, 1, 0, 0, 2]);
    }

    #[test]
    fn row_formats() {
        let mut r = JobReport::new("s");
        r.rel_err = 1.5e-6;
        let row = r.row();
        assert_eq!(row.len(), REPORT_HEADERS.len());
        assert_eq!(row[5], "1.50e-6");
        r.rel_err = f64::NAN;
        assert_eq!(r.row()[5], "-");
    }
}

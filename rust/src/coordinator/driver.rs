//! The generic job driver: one phase pipeline for every
//! [`CodingScheme`].
//!
//! Where `coordinator/matmul.rs` used to carry a near-duplicate `run_*`
//! function per scheme, [`run_job`] executes any `&dyn CodingScheme` on
//! one [`EventSim`]: encode (if the scheme has one) → compute under the
//! scheme's [`Termination`] policy and decodability probe → decode from
//! the arrival mask → recompute fallback for undecodable cells. Virtual
//! time and real numerics advance together, exactly as before the
//! refactor: the straggler model decides *which* blocks arrive before
//! the cutoff, and the scheme's numeric hooks must then really
//! reconstruct the output through the compute backend.
//!
//! # RNG draw-order contract
//!
//! The sampled timeline of a job is a pure function of its seed, so the
//! driver draws in a fixed phase order — encode launch, compute launch,
//! decode launch, recompute launch, each followed by any speculative
//! relaunch or remainder-steal draws — and numeric hooks and
//! decodability probes never touch the job RNG. Probes must also honour
//! the pure-`None`-hint rule (DESIGN.md §Progress events): a
//! `probe(mask, None)` call is a stateless feasibility query over an
//! arbitrary hypothetical mask, asked by infeasibility checks and
//! partial-credit retests; only `probe(mask, Some(cell))` records an
//! arrival. Schemes whose decode consumes partial block-products opt in
//! via [`ComputePolicy::partial_credit`]; the driver itself runs real
//! numerics on fully-arrived blocks only, so partial credit is a
//! timing-layer feature here. This is what keeps golden scenario
//! timelines bit-identical across refactors (DESIGN.md §Adding a
//! scheme).

use crate::codes::scheme::{CodingScheme, ComputePolicy, JobShape};
use crate::coordinator::matmul::{Env, MatmulJob};
use crate::coordinator::metrics::{FaultMetrics, JobReport, StorageMetrics};
use crate::linalg::blocked::{assemble_grid, GridShape, Partition};
use crate::linalg::matrix::{BlockBuf, Matrix};
use crate::platform::event::{run_phase, EventSim, PhaseState, Termination};
use crate::platform::straggler::{StragglerModel, WorkProfile};
use crate::runtime::manifest::JobManifest;
use crate::storage::faults::{RetryPolicy, StorageError, StorageFaultMetrics};
use crate::storage::{keys, ObjectStore};
use crate::util::rng::Pcg64;
use crate::util::threadpool::{parallel_for, parallel_map};

/// Launch one phase (sampling a duration per profile, in task order, at
/// submission) and drive the sim until its termination rule fires.
/// `probe` is only consulted under [`Termination::EarliestDecodable`].
pub fn drive_phase(
    sim: &mut EventSim,
    model: &StragglerModel,
    works: &[WorkProfile],
    term: Termination,
    probe: &mut dyn FnMut(&[bool], Option<usize>) -> bool,
    rng: &mut Pcg64,
) -> PhaseState {
    let mut ps = PhaseState::launch(sim, model, works, 0, term, rng);
    run_phase(sim, &mut ps, model, rng, probe);
    ps
}

/// [`drive_phase`] with the termination rule and decodability probe
/// supplied by a [`ComputePolicy`] — the shared compute-phase entry of
/// the matmul and matvec coordinators.
pub fn drive_policy_phase(
    sim: &mut EventSim,
    model: &StragglerModel,
    works: &[WorkProfile],
    policy: &dyn ComputePolicy,
    rng: &mut Pcg64,
) -> PhaseState {
    let mut probe = policy.decode_probe();
    drive_phase(sim, model, works, policy.compute_termination(), &mut *probe, rng)
}

/// Run one coded matmul job (`C = A·Bᵀ`) under `scheme`. Returns the
/// output matrix and the phase report; `run_matmul` wraps this with
/// scheme instantiation and output verification.
pub fn run_job(
    env: &Env,
    a: &Matrix,
    b: &Matrix,
    job: &MatmulJob,
    scheme: &dyn CodingScheme,
    rng: &mut Pcg64,
) -> anyhow::Result<(Matrix, JobReport)> {
    let mut report = JobReport::new(scheme.name());
    report.redundancy = scheme.redundancy();
    report.numerics_ok = scheme.numerics_feasible();
    // Baselines for the per-job storage delta (the store is shared, so
    // only this job's traffic is attributed to it).
    let staged = scheme.stages_blocks_in_store();
    let store_before = env.store.stats();
    let cache_before = env.cache.as_ref().map(|c| c.stats());
    let mut manifest = JobManifest::new(&job.job_id);

    let (vm, vk, vl) = job.vdims(a, b);
    let shape = JobShape::new(job.s_a, job.s_b, (vm, vk, vl));
    let pa = Partition::new(a.rows, a.cols, job.s_a);
    let pb = Partition::new(b.rows, b.cols, job.s_b);
    // Shared block handles: from here on every hand-off — encode
    // systematic cells, store staging, decode grid extraction — is a
    // refcount bump, not a payload copy.
    let a_blocks: Vec<BlockBuf> = pa.split(a).into_iter().map(BlockBuf::new).collect();
    let b_blocks: Vec<BlockBuf> = pb.split(b).into_iter().map(BlockBuf::new).collect();

    let n_tasks = scheme.compute_tasks();
    // One event simulator per job: the clock carries across phases.
    let mut sim = env.sim();

    // --- Encode phase (schemes with parities only).
    let fleet = job.encode_fleet(n_tasks);
    if let Some(plan) = scheme.encode_plan(&shape, fleet) {
        let works = vec![plan.profile; fleet];
        let enc =
            drive_phase(&mut sim, &env.model, &works, plan.termination, &mut |_, _| false, rng);
        report.enc.tasks = fleet;
        report.enc.stragglers = enc.stragglers();
        report.enc.relaunched = enc.relaunched;
        report.enc.virtual_secs = enc.duration();
        report.enc.blocks_read = plan.blocks_read;
    }

    // Numerics: encode through the backend (parallel per-parity fan-out
    // inside the scheme); staging schemes stash the coded blocks in the
    // store (the serverless dataflow — workers exchange blocks via
    // storage) and record them in the job manifest. Staging hands the
    // store the blocks' shared payloads (`put_block`): zero copies, the
    // store's byte counters still report the logical wire size. Manifest
    // entries are recorded serially (deterministic order); the store
    // writes fan out over the host pool.
    let backend = env.backend.as_ref();
    let (a_coded, b_coded) = scheme.encode_numeric(backend, &a_blocks, &b_blocks);
    if staged {
        let store = env.store.as_ref();
        let to_stage: Vec<(String, &BlockBuf)> = a_coded
            .iter()
            .enumerate()
            .map(|(i, blk)| (keys::coded_block(&job.job_id, "a", i), blk))
            .chain(
                b_coded
                    .iter()
                    .enumerate()
                    .map(|(j, blk)| (keys::coded_block(&job.job_id, "b", j), blk)),
            )
            .collect();
        for (key, blk) in &to_stage {
            manifest.push(key.clone(), blk.rows, blk.cols);
        }
        parallel_for(env.threads, to_stage.len(), |i| {
            let (key, blk) = &to_stage[i];
            store.put_block(key, (*blk).clone());
        });
    }

    // --- Compute phase under the scheme's termination policy; an
    // earliest-decodable cutoff cancels stragglers (freeing their workers
    // on bounded pools).
    let comp_profile = shape.compute_profile();
    let comp_works = vec![comp_profile; n_tasks];
    let mut probe = scheme.decode_probe();
    let comp = drive_phase(
        &mut sim,
        &env.model,
        &comp_works,
        scheme.compute_termination(),
        &mut *probe,
        rng,
    );
    report.comp.tasks = n_tasks;
    report.comp.stragglers = comp.stragglers();
    report.comp.relaunched = comp.relaunched;
    report.comp.virtual_secs = comp.duration();
    let mut arrived = comp.arrived_mask();
    let mut arrival_order = comp.arrival_order().to_vec();

    // Numerics: compute the arrived products only. The rest are the
    // stragglers decode must reconstruct.
    let mut grid: Vec<Option<BlockBuf>> = if report.numerics_ok {
        let arrived_ref = &arrived;
        let a_ref = &a_coded;
        let b_ref = &b_coded;
        parallel_map(env.threads, n_tasks, move |cell| {
            if arrived_ref[cell] {
                Some(scheme.cell_product(env.backend.as_ref(), a_ref, b_ref, cell))
            } else {
                None
            }
        })
    } else {
        vec![None; n_tasks]
    };

    // The workers' block-products land in the store too, and decode
    // reads them back through the (optionally cached) store — the
    // paper's S3 round-trip between f_comp and f_dec. Both directions
    // are refcount bumps on the shared handles (`put_block` /
    // `get_block`): the round-trip is exact by construction and the
    // store/cache counters account the same logical wire bytes as the
    // historical serialize-and-parse path.
    let mut sf = StorageFaultMetrics::default();
    if staged && report.numerics_ok {
        let store = env.store.as_ref();
        let rb = b_coded.len();
        let out_keys: Vec<(usize, String)> = grid
            .iter()
            .enumerate()
            .filter(|(_, slot)| slot.is_some())
            .map(|(cell, _)| (cell, keys::out_block(&job.job_id, cell / rb, cell % rb)))
            .collect();
        for (cell, key) in &out_keys {
            let m = grid[*cell].as_ref().expect("filtered to arrived cells");
            manifest.push(key.clone(), m.rows, m.cols);
        }
        parallel_for(env.threads, out_keys.len(), |i| {
            let (cell, key) = &out_keys[i];
            let blk = grid[*cell].as_ref().expect("filtered to arrived cells");
            store.put_block(key, blk.clone());
        });
        // Read back through the typed error path. A block that stays
        // unreadable after the retry budget is not a job failure — it is
        // demoted to one more *erasure*, exactly what the code was built
        // to absorb, and decode re-plans from the thinned arrival mask.
        let mut backoff_secs = 0.0f64;
        for (cell, key) in &out_keys {
            match read_staged_block(store, key, &env.retry, &mut sf, &mut backoff_secs) {
                Ok(blk) => grid[*cell] = Some(blk),
                Err(_) => {
                    sf.lost += 1;
                    arrived[*cell] = false;
                    arrival_order.retain(|&c| c != *cell);
                    grid[*cell] = None;
                }
            }
        }
        if backoff_secs > 0.0 {
            // Retries waited in virtual time; the clock carries into the
            // decode phase and the wait is billed to the decode report.
            sim.advance_to(sim.now() + backoff_secs);
            report.dec.virtual_secs += backoff_secs;
        }
    }

    // --- Decode phase from the arrival mask.
    let plan = scheme.decode_plan(&arrived, &shape, job.decode_workers);
    report.dec.tasks = plan.profiles.len();
    report.dec.blocks_read = plan.blocks_read;
    report.decode_ok = plan.undecodable == 0;
    if !plan.profiles.is_empty() {
        let term = plan.termination;
        let dec = drive_phase(&mut sim, &env.model, &plan.profiles, term, &mut |_, _| false, rng);
        report.dec.relaunched += dec.relaunched;
        report.dec.virtual_secs += dec.duration();
    }

    // Storage-loss resolution. A single lost block usually *is*
    // coverable: the erasure code was provisioned for stragglers, and a
    // read failure is just one more erasure — decode peels it from the
    // parities and the job still reports `decode_ok = true`. When the
    // losses exceed the parity slack, the job degrades honestly: the
    // blocks are gone from the store, so recomputing them here would
    // fabricate data the storage tier lost. No panic either way.
    if sf.lost > 0 {
        if plan.undecodable == 0 {
            sf.recovered_via_parity = sf.lost;
        } else {
            report
                .faults
                .get_or_insert_with(FaultMetrics::default)
                .degraded = true;
            report.storage_faults = Some(sf);
            report.storage = Some(storage_delta(env, &store_before, cache_before));
            return Ok((Matrix::zeros(a.rows, b.rows), report));
        }
    }
    if sf.any() {
        report.storage_faults = Some(sf);
    }

    // Recompute fallback: unreachable under earliest-decodable
    // termination (the cutoff only fires on decodable masks), kept as the
    // defensive path for cutoff policies that cannot guarantee
    // decodability (deadlines, Thm-2-tail experiments).
    if plan.undecodable > 0 {
        let rec_works = vec![comp_profile; plan.undecodable];
        let wait_all = Termination::WaitAll;
        let rec = drive_phase(&mut sim, &env.model, &rec_works, wait_all, &mut |_, _| false, rng);
        report.dec.virtual_secs += rec.duration();
        report.dec.relaunched += plan.undecodable;
        if report.numerics_ok {
            for (cell, slot) in grid.iter_mut().enumerate() {
                if slot.is_none() {
                    *slot = Some(scheme.cell_product(backend, &a_coded, &b_coded, cell));
                }
            }
        }
    }

    // --- Numeric decode and output assembly.
    if !report.numerics_ok {
        if staged {
            report.storage = Some(storage_delta(env, &store_before, cache_before));
        }
        return Ok((Matrix::zeros(a.rows, b.rows), report));
    }
    let sys = scheme.decode_numeric(backend, grid, &arrival_order)?;
    if staged {
        let store = env.store.as_ref();
        let result_keys: Vec<String> = (0..sys.len())
            .map(|idx| keys::result_block(&job.job_id, idx / job.s_b, idx % job.s_b))
            .collect();
        for (key, blk) in result_keys.iter().zip(&sys) {
            manifest.push(key.clone(), blk.rows, blk.cols);
        }
        parallel_for(env.threads, sys.len(), |idx| {
            store.put_block(&result_keys[idx], sys[idx].clone());
        });
        // The manifest is the workers' lookup contract: everything the
        // job staged, discoverable from the job id alone.
        manifest.save(store);
        report.storage = Some(storage_delta(env, &store_before, cache_before));
    }
    let c = assemble_grid(
        GridShape {
            rows: job.s_a,
            cols: job.s_b,
        },
        &sys,
    );
    Ok((c, report))
}

/// Read one staged block through the typed-error path with bounded,
/// deterministic exponential backoff. Transient and corrupt reads are
/// retried, each retry adding its backoff to the virtual-time bill; a
/// `NotFound` (the object is gone) is final immediately. A returned
/// error means the retry budget is exhausted — the caller demotes the
/// block to an erasure rather than failing the job.
fn read_staged_block(
    store: &dyn ObjectStore,
    key: &str,
    retry: &RetryPolicy,
    sf: &mut StorageFaultMetrics,
    backoff: &mut f64,
) -> Result<BlockBuf, StorageError> {
    let mut attempt: u32 = 0;
    loop {
        match store.try_get_block(key) {
            Ok(blk) => return Ok(blk),
            Err(e) => {
                match &e {
                    StorageError::Transient { .. } => sf.transients += 1,
                    StorageError::Corrupt { .. } => sf.corrupt += 1,
                    StorageError::NotFound { .. } => {}
                }
                if !e.retryable() || attempt >= retry.max_retries {
                    return Err(e);
                }
                attempt += 1;
                sf.retries += 1;
                *backoff += retry.backoff(attempt);
            }
        }
    }
}

/// This job's share of the store/cache counters since `before`.
fn storage_delta(
    env: &Env,
    before: &crate::storage::StatsSnapshot,
    cache_before: Option<crate::storage::cache::CacheStats>,
) -> StorageMetrics {
    let now = env.store.stats();
    let (cache_hits, cache_misses) = match (env.cache.as_ref(), cache_before) {
        (Some(cache), Some(b)) => {
            let c = cache.stats();
            (
                c.hits.saturating_sub(b.hits),
                c.misses.saturating_sub(b.misses),
            )
        }
        _ => (0, 0),
    };
    StorageMetrics {
        puts: now.puts.saturating_sub(before.puts),
        gets: now.gets.saturating_sub(before.gets),
        bytes_in: now.bytes_in.saturating_sub(before.bytes_in),
        bytes_out: now.bytes_out.saturating_sub(before.bytes_out),
        hits: now.hits.saturating_sub(before.hits),
        misses: now.misses.saturating_sub(before.misses),
        cache_hits,
        cache_misses,
    }
}

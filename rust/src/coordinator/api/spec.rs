//! The canonical, versioned job-spec surface.
//!
//! Every way a job enters the system — explicit scenario `jobs`
//! entries, service arrival templates, `slec submit` ad-hoc specs, the
//! `slec run` CLI flags and the daemon's `POST /v1/jobs` bodies — parses
//! through [`parse_job_spec`]: one strict-keyed parser, one validation
//! path, one error vocabulary (unknown keys fail loudly, naming the
//! culprit and the known set). The contexts differ only in which
//! service-side keys they admit, captured by [`SpecContext`].
//!
//! Documents may carry an explicit `schema_version`; the current
//! surface is [`SCHEMA_VERSION`]. Reports emitted by the API path
//! (submit, daemon, replay) carry the same field, appended via
//! [`versioned`] so pre-existing golden documents stay byte-identical.

use crate::codes::Scheme;
use crate::platform::scenario::{
    ensure_known_keys, parse_failures, parse_progress, parse_storage_faults, JobSpec, StorageSpec,
};
use crate::util::json::Json;

/// Version of the JobSpec/JobReport wire surface. Bumped on any
/// incompatible change to the job-spec keys or the report shape.
pub const SCHEMA_VERSION: u64 = 1;

/// Where a job spec is being parsed from — decides which service-side
/// keys are legal. The base surface (scheme, partitioning, dims,
/// workers, failures, progress, storage_faults, `schema_version`) is
/// identical everywhere.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpecContext {
    /// Explicit scenario `jobs` entry: no service keys — `tenant`,
    /// `priority` and `deadline_s` would silently do nothing there, so
    /// they are rejected as unknown.
    Batch,
    /// Service arrival template: service keys plus the template
    /// `weight`. (`arrival` is additionally forbidden by the template
    /// parser — times come from the Poisson process.)
    Template,
    /// Ad-hoc submission (`slec submit`, `POST /v1/jobs`): service keys,
    /// no `weight` (there is no template mix to weight against).
    Submit,
}

impl SpecContext {
    fn extra_keys(self) -> &'static [&'static str] {
        match self {
            SpecContext::Batch => &[],
            SpecContext::Template => &["weight", "tenant", "priority", "deadline_s"],
            SpecContext::Submit => &["tenant", "priority", "deadline_s"],
        }
    }
}

/// Parse one job spec — the single parser behind every entry point.
/// Strict: unknown keys, wrong types and invalid partitionings are
/// errors naming the culprit key. `storage` (when the surrounding
/// scenario has a `storage` section) is needed to validate
/// shard-aligned failure models.
pub fn parse_job_spec(
    j: &Json,
    storage: Option<&StorageSpec>,
    ctx: SpecContext,
) -> anyhow::Result<JobSpec> {
    let mut known = vec![
        "schema_version",
        "scheme",
        "s_a",
        "s_b",
        "dims",
        "decode_workers",
        "encode_workers",
        "arrival",
        "failures",
        "progress",
        "storage_faults",
    ];
    known.extend_from_slice(ctx.extra_keys());
    ensure_known_keys("job", j, &known)?;
    check_schema_version(j)?;
    let scheme_str = j
        .get("scheme")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("job needs a 'scheme' string"))?;
    let scheme = Scheme::parse(scheme_str)?;
    let s_a = j
        .get("s_a")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow::anyhow!("job needs integer 's_a'"))?;
    let s_b = j
        .get("s_b")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow::anyhow!("job needs integer 's_b'"))?;
    let dims = match j.get("dims") {
        Some(Json::Arr(items)) if items.len() == 3 => {
            let d: Vec<usize> = items
                .iter()
                .map(|it| it.as_usize().unwrap_or(0))
                .collect();
            anyhow::ensure!(d.iter().all(|&x| x > 0), "'dims' must be positive");
            (d[0], d[1], d[2])
        }
        Some(Json::Num(_)) => {
            let n = j.get("dims").unwrap().as_usize().unwrap_or(0);
            anyhow::ensure!(n > 0, "'dims' must be positive");
            (n, n, n)
        }
        _ => anyhow::bail!("job needs 'dims' (an [m, k, l] array or one cube dim)"),
    };
    anyhow::ensure!(s_a > 0 && s_b > 0, "'s_a' and 's_b' must be positive");
    anyhow::ensure!(dims.0 % s_a == 0, "s_a must divide dims[0]");
    anyhow::ensure!(dims.2 % s_b == 0, "s_b must divide dims[2]");
    let decode_workers = j.get("decode_workers").and_then(Json::as_usize).unwrap_or(4);
    let encode_workers = j.get("encode_workers").and_then(Json::as_usize).unwrap_or(0);
    let arrival = j.get("arrival").and_then(Json::as_f64).unwrap_or(0.0);
    anyhow::ensure!(arrival >= 0.0, "'arrival' must be non-negative");
    let failures = parse_failures(j.get("failures"), storage)?;
    let progress = parse_progress(j.get("progress"))?;
    let storage_faults = parse_storage_faults(j.get("storage_faults"))?;
    let tenant = match j.get("tenant") {
        None => None,
        Some(v) => Some(
            v.as_str()
                .ok_or_else(|| anyhow::anyhow!("job 'tenant' must be a string"))?
                .to_string(),
        ),
    };
    let priority = match j.get("priority") {
        None => 0,
        Some(v) => v
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("job 'priority' must be a non-negative integer"))?
            as u32,
    };
    let deadline_s = match j.get("deadline_s") {
        None => None,
        Some(v) => {
            let d = v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("job 'deadline_s' must be a number"))?;
            anyhow::ensure!(
                d.is_finite() && d > 0.0,
                "job 'deadline_s' must be positive"
            );
            Some(d)
        }
    };
    // Validate the scheme's parameters against the partitioning through
    // the same registry instantiation the runner uses.
    scheme.instantiate(s_a, s_b)?;
    Ok(JobSpec {
        scheme,
        s_a,
        s_b,
        dims,
        decode_workers,
        encode_workers,
        arrival,
        failures,
        progress,
        storage_faults,
        tenant,
        priority,
        deadline_s,
    })
}

/// Validate an optional `schema_version` key: absent = current, present
/// = must be an integer equal to [`SCHEMA_VERSION`].
pub fn check_schema_version(j: &Json) -> anyhow::Result<()> {
    let Some(v) = j.get("schema_version") else { return Ok(()) };
    let n = v
        .as_u64()
        .ok_or_else(|| anyhow::anyhow!("'schema_version' must be an integer"))?;
    anyhow::ensure!(
        n == SCHEMA_VERSION,
        "unsupported 'schema_version' {n} (this build speaks {SCHEMA_VERSION})"
    );
    Ok(())
}

/// Load a job spec from a file path or inline JSON — the `slec submit`
/// and daemon front-door convention (a file path if one exists, inline
/// JSON otherwise), through the canonical parser's `Submit` context.
pub fn load_job_spec(input: &str) -> anyhow::Result<JobSpec> {
    let src = match std::fs::read_to_string(input) {
        Ok(s) => s,
        Err(_) if input.trim_start().starts_with('{') => input.to_string(),
        Err(e) => anyhow::bail!("cannot read job spec '{input}': {e}"),
    };
    parse_job_spec(
        &crate::util::json::parse(&src)?,
        None,
        SpecContext::Submit,
    )
}

/// Stamp a report document with the current [`SCHEMA_VERSION`] —
/// appended, like the `storage`/`faults` blocks, so documents that
/// never pass through the API path keep their historical byte shape.
pub fn versioned(mut doc: Json) -> Json {
    doc.set("schema_version", Json::from(SCHEMA_VERSION));
    doc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn spec_json(extra: &str) -> Json {
        parse(&format!(
            r#"{{"scheme": "local-product:2x2", "s_a": 4, "s_b": 4, "dims": 1000{extra}}}"#
        ))
        .unwrap()
    }

    #[test]
    fn contexts_gate_service_keys() {
        let j = spec_json(r#", "tenant": "acme", "priority": 2, "deadline_s": 60"#);
        // Batch rejects service keys, naming the culprit.
        let err = parse_job_spec(&j, None, SpecContext::Batch)
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown job key 'tenant'"), "{err}");
        // Submit accepts them.
        let spec = parse_job_spec(&j, None, SpecContext::Submit).unwrap();
        assert_eq!(spec.tenant.as_deref(), Some("acme"));
        assert_eq!(spec.priority, 2);
        assert_eq!(spec.deadline_s, Some(60.0));
        // Only Template accepts `weight`.
        let w = spec_json(r#", "weight": 2.0"#);
        assert!(parse_job_spec(&w, None, SpecContext::Submit).is_err());
        assert!(parse_job_spec(&w, None, SpecContext::Template).is_ok());
    }

    #[test]
    fn schema_version_accepted_current_rejected_other() {
        let ok = spec_json(r#", "schema_version": 1"#);
        assert!(parse_job_spec(&ok, None, SpecContext::Batch).is_ok());
        let bad = spec_json(r#", "schema_version": 2"#);
        let err = parse_job_spec(&bad, None, SpecContext::Batch)
            .unwrap_err()
            .to_string();
        assert!(err.contains("unsupported 'schema_version' 2"), "{err}");
        let not_int = spec_json(r#", "schema_version": "one""#);
        assert!(parse_job_spec(&not_int, None, SpecContext::Batch).is_err());
    }

    #[test]
    fn load_job_spec_takes_inline_json_or_file() {
        let inline = r#"{"scheme": "uncoded", "s_a": 2, "s_b": 2, "dims": 100}"#;
        let spec = load_job_spec(inline).unwrap();
        assert_eq!(spec.scheme.name(), "uncoded");
        let dir = std::env::temp_dir().join("slec-spec-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("job.json");
        std::fs::write(&path, inline).unwrap();
        let from_file = load_job_spec(path.to_str().unwrap()).unwrap();
        assert_eq!(from_file.scheme.name(), "uncoded");
        // Neither a file nor inline JSON: a readable error.
        assert!(load_job_spec("no-such-file.json").is_err());
    }

    #[test]
    fn versioned_appends_the_current_version() {
        let doc = versioned(crate::util::json::obj().field("x", 1).build());
        assert_eq!(doc.get("schema_version").unwrap().as_u64(), Some(SCHEMA_VERSION));
        // Appended last, not interleaved.
        let text = doc.to_string_compact();
        assert!(text.ends_with(r#""schema_version":1}"#), "{text}");
    }
}

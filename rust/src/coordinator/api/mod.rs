//! The coordinator's public API surface.
//!
//! One typed, versioned boundary for everything that crosses into or
//! out of the system:
//!
//! - [`spec`] — the canonical [`parse_job_spec`] every entry point
//!   (scenario `jobs`, arrival templates, `slec submit`, `slec run`,
//!   `POST /v1/jobs`) parses through, plus the [`SCHEMA_VERSION`]
//!   stamped on API-path reports.
//! - [`http`] — a dependency-free HTTP/1.1 layer and the
//!   [`ENDPOINTS`] route table.
//! - [`daemon`] — `slec daemon`: real sockets in front of the
//!   deterministic service core, with a submission log whose replay is
//!   bit-identical ([`replay_submission_log`]).

pub mod daemon;
pub mod http;
pub mod spec;

pub use daemon::{replay_submission_log, submission_log, Daemon, DaemonConfig, LOG_MAGIC};
pub use http::{Request, Response, ENDPOINTS};
pub use spec::{
    check_schema_version, load_job_spec, parse_job_spec, versioned, SpecContext, SCHEMA_VERSION,
};

use std::path::{Path, PathBuf};

/// One row of the scenario listing (CLI `slec scenarios` and the
/// daemon's `GET /v1/scenarios` render the same index).
#[derive(Debug, Clone)]
pub struct ScenarioInfo {
    pub name: String,
    /// `"service"` (has an `arrivals` section) or `"batch"`.
    pub kind: &'static str,
    /// Offered arrivals for a service scenario, explicit job count for
    /// a batch one.
    pub jobs: usize,
    pub description: String,
    pub path: PathBuf,
}

/// The conventional scenario directory relative to the working
/// directory (repo root or `rust/`), if one exists.
pub fn default_scenario_dir() -> Option<PathBuf> {
    ["rust/scenarios", "scenarios"]
        .iter()
        .map(PathBuf::from)
        .find(|p| p.is_dir())
}

/// Parse every `*.json` scenario in `dir`, sorted by file name. A file
/// that fails to parse fails the listing — a broken bundled scenario
/// should never be silently hidden.
pub fn scenario_index(dir: &Path) -> anyhow::Result<Vec<ScenarioInfo>> {
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    files.sort();
    let mut out = Vec::with_capacity(files.len());
    for path in files {
        let sc = crate::platform::scenario::parse_scenario(&crate::util::json::load_file(&path)?)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let (kind, jobs) = match &sc.arrivals {
            Some(arr) => ("service", arr.jobs),
            None => ("batch", sc.jobs.len()),
        };
        out.push(ScenarioInfo {
            name: sc.name,
            kind,
            jobs,
            description: sc.description,
            path,
        });
    }
    Ok(out)
}

//! `slec daemon` — a wall-clock front door onto the simulated service.
//!
//! The daemon binds a real TCP socket and speaks the API of
//! [`super::http::ENDPOINTS`], but the jobs it accepts still *run in
//! virtual time* on the deterministic event core: each submission is
//! stamped with the current virtual instant (wall-clock seconds since
//! start × `time_scale`; `time_scale = 0` freezes the clock, making
//! live runs fully deterministic for tests) and fed through the exact
//! `ServiceCore` arrive/drain path that batch `serve` runs use. Job
//! sim streams are forked from `(seed, arrival seq)`, so the daemon
//! inherits the service's reproducibility contract wholesale.
//!
//! Every submission — including rejected ones, which still consume a
//! sequence number and an RNG fork — is appended to a submission log.
//! [`replay_submission_log`] feeds a log back through the same core and
//! produces a **bit-identical** report: the wall clock only ever enters
//! the system through the logged arrival stamps.

use std::io::Write as _;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::coordinator::service::{run_service_with, Offered, ServiceCore};
use crate::platform::scenario::{ArrivalSpec, Scenario, StorageSpec};
use crate::platform::straggler::{StragglerParams, WorkerRates};
use crate::util::json::{obj, Json};

use super::http::{read_request, Request, Response, ENDPOINTS};
use super::spec::{check_schema_version, parse_job_spec, versioned, SpecContext};

/// Magic/version key identifying a submission-log document.
pub const LOG_MAGIC: &str = "slec_submission_log";

/// Configuration of a daemon instance. Either a full service scenario
/// (reusing its fleet, storage, tenants and admission sections) or, by
/// default, a synthetic single-fleet scenario built from the scalar
/// knobs below.
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// Bind address; port 0 picks an ephemeral port.
    pub addr: String,
    pub seed: u64,
    /// Fleet size of the default synthetic scenario.
    pub workers: usize,
    /// Admission queue depth (0 = unbounded) of the default scenario.
    pub queue_depth: usize,
    /// Concurrent in-flight job cap (0 = unbounded) of the default
    /// scenario.
    pub max_inflight: usize,
    /// Virtual seconds per wall-clock second. 0 freezes the virtual
    /// clock: every submission arrives at t=0 and runs are
    /// wall-clock-independent.
    pub time_scale: f64,
    /// Run against a full service scenario instead of the synthetic
    /// default (its `arrivals.jobs` count is ignored — jobs come from
    /// the socket).
    pub scenario: Option<Scenario>,
    /// Where to persist the submission log (rewritten on every
    /// submission and at shutdown).
    pub log_path: Option<PathBuf>,
    /// Per-connection socket read/write timeout in wall-clock seconds.
    /// Bounds how long a client that connects and then goes silent (or
    /// trickles bytes — slow-loris) can pin the accept loop. `0`
    /// disables the timeout.
    pub io_timeout_s: f64,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        DaemonConfig {
            addr: "127.0.0.1:7070".into(),
            seed: 0,
            workers: 16,
            queue_depth: 0,
            max_inflight: 0,
            time_scale: 1.0,
            scenario: None,
            log_path: None,
            io_timeout_s: 10.0,
        }
    }
}

impl DaemonConfig {
    /// The scenario this daemon runs: the provided one, or a synthetic
    /// single-fleet scenario with a shared 8-shard object store and the
    /// configured admission bounds.
    pub fn to_scenario(&self) -> anyhow::Result<Scenario> {
        if let Some(sc) = &self.scenario {
            anyhow::ensure!(
                sc.arrivals.is_some(),
                "daemon scenario '{}' has no 'arrivals' section (needed for admission bounds)",
                sc.name
            );
            return Ok(sc.clone());
        }
        anyhow::ensure!(self.workers > 0, "daemon needs at least one worker");
        Ok(Scenario {
            name: "daemon".into(),
            description: "ad-hoc submissions over the HTTP API".into(),
            seed: self.seed,
            workers: vec![self.workers],
            straggler: StragglerParams::default(),
            rates: WorkerRates::default(),
            storage: Some(StorageSpec {
                shards: 8,
                shard_bandwidth_bps: 100e6,
                latency_s: 0.0,
                cache_blocks: 0,
            }),
            failures: None,
            progress: None,
            storage_faults: None,
            tenants: vec![],
            arrivals: Some(ArrivalSpec {
                jobs: 0,
                rate_per_s: 0.0,
                templates: vec![],
                queue_depth: self.queue_depth,
                max_inflight: self.max_inflight,
            }),
            autoscale: None,
            jobs: vec![],
        })
    }
}

/// A bound, running daemon: one `ServiceCore` lifetime behind a
/// listener.
pub struct Daemon {
    listener: TcpListener,
    core: ServiceCore,
    sc: Scenario,
    time_scale: f64,
    started: Instant,
    last_v: f64,
    entries: Vec<Json>,
    log_path: Option<PathBuf>,
    io_timeout: Option<Duration>,
    shutdown: bool,
}

impl Daemon {
    /// Bind the listener and build the service core.
    pub fn bind(cfg: &DaemonConfig) -> anyhow::Result<Daemon> {
        let sc = cfg.to_scenario()?;
        let workers = *sc.workers.first().ok_or_else(|| {
            anyhow::anyhow!("daemon scenario '{}' has an empty workers sweep", sc.name)
        })?;
        let core = ServiceCore::new(&sc, workers)?;
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| anyhow::anyhow!("cannot bind '{}': {e}", cfg.addr))?;
        Ok(Daemon {
            listener,
            core,
            sc,
            time_scale: cfg.time_scale,
            started: Instant::now(),
            last_v: 0.0,
            entries: Vec::new(),
            log_path: cfg.log_path.clone(),
            io_timeout: (cfg.io_timeout_s > 0.0)
                .then(|| Duration::from_secs_f64(cfg.io_timeout_s)),
            shutdown: false,
        })
    }

    /// The bound address (resolves port 0 to the actual ephemeral
    /// port).
    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Current virtual time: monotone over `elapsed × time_scale`.
    fn virtual_now(&mut self) -> f64 {
        let v = self.started.elapsed().as_secs_f64() * self.time_scale;
        if v > self.last_v {
            self.last_v = v;
        }
        self.last_v
    }

    /// Accept and answer requests until a `POST /v1/shutdown` arrives;
    /// returns the final (drained) report document.
    pub fn serve(&mut self) -> anyhow::Result<Json> {
        while !self.shutdown {
            let (stream, _) = match self.listener.accept() {
                Ok(conn) => conn,
                Err(e) => {
                    eprintln!("accept: {e}");
                    continue;
                }
            };
            if let Err(e) = self.handle_conn(stream) {
                eprintln!("connection: {e}");
            }
        }
        self.write_log()?;
        self.core.drain()?;
        self.core.check_drained()?;
        Ok(self.report_doc())
    }

    fn handle_conn(&mut self, mut stream: TcpStream) -> anyhow::Result<()> {
        // A silent or trickling client must not pin the accept loop:
        // bound both directions, and answer a read timeout with 408 so
        // well-behaved-but-slow clients learn why they were cut off.
        stream.set_read_timeout(self.io_timeout)?;
        stream.set_write_timeout(self.io_timeout)?;
        let response = match read_request(&mut stream) {
            Ok(req) => self.route(&req),
            Err(e) => Response::error(e.status, &e.msg),
        };
        response.write_to(&mut stream)?;
        Ok(())
    }

    /// Dispatch one request. Pure routing — every payload rule lives in
    /// the canonical spec parser, so the HTTP surface and the CLI speak
    /// the same error vocabulary.
    fn route(&mut self, req: &Request) -> Response {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => Response::text(200, "ok\n"),
            ("GET", "/metrics") => Response::text(200, self.metrics_text()),
            ("GET", "/v1/scenarios") => self.scenarios_response(),
            ("GET", "/v1/report") => {
                let v = self.virtual_now();
                if let Err(e) = self.core.pump_to(v) {
                    return Response::error(500, &format!("{e:#}"));
                }
                let summary = self.core.summary();
                Response::json(200, &self.partial_report(summary))
            }
            ("POST", "/v1/jobs") => self.submit(req),
            ("POST", "/v1/shutdown") => {
                self.shutdown = true;
                // Drain so the shutdown response *is* the final report;
                // `serve` re-drains (a no-op) before returning it.
                match self.core.drain().and_then(|()| self.core.check_drained()) {
                    Ok(()) => Response::json(200, &self.report_doc()),
                    Err(e) => Response::error(500, &format!("drain failed: {e}")),
                }
            }
            ("GET", path) if path.starts_with("/v1/jobs/") => self.job_status(path),
            // Known path, wrong method: 405, not 404.
            (_, path)
                if path.starts_with("/v1/jobs/")
                    || ENDPOINTS.iter().any(|(_, p, _)| *p == path) =>
            {
                Response::error(405, &format!("method {} not allowed on {path}", req.method))
            }
            (_, path) => {
                let routes: Vec<String> = ENDPOINTS
                    .iter()
                    .map(|(m, p, _)| format!("{m} {p}"))
                    .collect();
                Response::error(
                    404,
                    &format!("no route for '{path}' (routes: {})", routes.join(", ")),
                )
            }
        }
    }

    /// `POST /v1/jobs`: canonical parse, tenant resolution, virtual
    /// arrival stamp, admission through the service core, log append.
    fn submit(&mut self, req: &Request) -> Response {
        let body = match std::str::from_utf8(&req.body) {
            Ok(s) => s,
            Err(_) => return Response::error(400, "body is not UTF-8"),
        };
        let raw = match crate::util::json::parse(body) {
            Ok(j) => j,
            Err(e) => return Response::error(400, &format!("body is not JSON: {e}")),
        };
        let mut spec =
            match parse_job_spec(&raw, self.sc.storage.as_ref(), SpecContext::Submit) {
                Ok(s) => s,
                Err(e) => return Response::error(400, &format!("{e:#}")),
            };
        let tenant = match resolve_tenant(&self.sc, spec.tenant.as_deref()) {
            Ok(t) => t,
            Err(e) => return Response::error(400, &e),
        };
        let arrival = self.virtual_now();
        spec.arrival = arrival;
        let seq = self.entries.len();
        let offered = Offered {
            seq,
            arrival,
            tenant,
            template: None,
            spec,
        };
        let tenant_name = offered.spec.tenant.clone();
        if let Err(e) = self.core.arrive(offered) {
            return Response::error(500, &format!("{e:#}"));
        }
        self.entries.push(
            obj()
                .field("seq", seq)
                .field("arrival", arrival)
                .field(
                    "tenant",
                    tenant_name.map_or(Json::Null, |t| Json::from(t.as_str())),
                )
                .field("spec", raw)
                .build(),
        );
        if let Err(e) = self.write_log() {
            return Response::error(500, &format!("writing submission log: {e:#}"));
        }
        let state = self.core.job_state(seq).expect("job just arrived");
        let status = if state.wire().starts_with("rejected") {
            429
        } else {
            202
        };
        Response::json(
            status,
            &versioned(
                obj()
                    .field("seq", seq)
                    .field("status", state.wire())
                    .field("arrival", arrival)
                    .build(),
            ),
        )
    }

    /// `GET /v1/jobs/<seq>`.
    fn job_status(&mut self, path: &str) -> Response {
        let tail = &path["/v1/jobs/".len()..];
        let seq: usize = match tail.parse() {
            Ok(n) => n,
            Err(_) => {
                return Response::error(400, &format!("job id '{tail}' is not an integer"))
            }
        };
        // Catch the core up to the present so "running" vs "done"
        // reflects the virtual clock (replay-invisible: processing
        // events early never moves a timestamp).
        let v = self.virtual_now();
        if let Err(e) = self.core.pump_to(v) {
            return Response::error(500, &format!("{e:#}"));
        }
        match self.core.job_json(seq) {
            Some(doc) => Response::json(200, &versioned(doc)),
            None => Response::error(404, &format!("no job with seq {seq}")),
        }
    }

    fn scenarios_response(&self) -> Response {
        let infos = match super::default_scenario_dir() {
            Some(dir) => match super::scenario_index(&dir) {
                Ok(infos) => infos,
                Err(e) => return Response::error(500, &format!("{e:#}")),
            },
            None => Vec::new(),
        };
        let items: Vec<Json> = infos
            .iter()
            .map(|i| {
                obj()
                    .field("name", i.name.as_str())
                    .field("kind", i.kind)
                    .field("jobs", i.jobs)
                    .field("description", i.description.as_str())
                    .build()
            })
            .collect();
        Response::json(200, &versioned(obj().field("scenarios", Json::Arr(items)).build()))
    }

    fn metrics_text(&mut self) -> String {
        let v = self.virtual_now();
        let _ = self.core.pump_to(v);
        let s = self.core.stats();
        let mut out = String::new();
        for (name, value) in [
            ("slec_offered_total", s.offered as f64),
            ("slec_admitted_total", s.admitted as f64),
            ("slec_rejected_queue_total", s.rejected_queue as f64),
            ("slec_rejected_quota_total", s.rejected_quota as f64),
            ("slec_jobs_done_total", s.done as f64),
            ("slec_jobs_queued", s.queued as f64),
            ("slec_jobs_inflight", s.inflight as f64),
            ("slec_workers", s.workers as f64),
            ("slec_virtual_seconds", s.now),
            ("slec_storage_transients_total", s.storage_faults.transients as f64),
            ("slec_storage_retries_total", s.storage_faults.retries as f64),
            ("slec_storage_lost_total", s.storage_faults.lost as f64),
            ("slec_storage_corrupt_total", s.storage_faults.corrupt as f64),
            (
                "slec_storage_recovered_total",
                s.storage_faults.recovered_via_parity as f64,
            ),
        ] {
            out.push_str(&format!("# TYPE {name} gauge\n{name} {value}\n"));
        }
        out
    }

    /// The versioned report wrapper — shared verbatim with the replay
    /// path, which is what makes replay bit-identity checkable on the
    /// whole document.
    fn report_doc(&mut self) -> Json {
        let summary = self.core.summary();
        daemon_report(&self.sc, self.entries.len(), summary)
    }

    fn partial_report(&mut self, summary: Json) -> Json {
        daemon_report(&self.sc, self.entries.len(), summary)
    }

    /// Persist the submission log (whole-file rewrite: logs are small
    /// and this keeps the file valid JSON at every instant).
    fn write_log(&self) -> anyhow::Result<()> {
        let Some(path) = &self.log_path else { return Ok(()) };
        let doc = obj()
            .field("slec_submission_log", 1u64)
            .field("mode", "daemon")
            .field("seed", self.sc.seed)
            .field(
                "config",
                obj()
                    .field("workers", self.core.stats().workers)
                    .field(
                        "queue_depth",
                        self.sc.arrivals.as_ref().map_or(0, |a| a.queue_depth),
                    )
                    .field(
                        "max_inflight",
                        self.sc.arrivals.as_ref().map_or(0, |a| a.max_inflight),
                    )
                    .build(),
            )
            .field("entries", Json::Arr(self.entries.clone()))
            .build();
        let mut f = std::fs::File::create(path)
            .map_err(|e| anyhow::anyhow!("creating {}: {e}", path.display()))?;
        f.write_all(doc.to_string_pretty().as_bytes())?;
        f.write_all(b"\n")?;
        Ok(())
    }
}

/// Map a submitted tenant name onto the scenario's tenant index.
/// Anonymous submissions are always allowed (no quota applies); a named
/// tenant must exist when the scenario defines any.
fn resolve_tenant(sc: &Scenario, tenant: Option<&str>) -> Result<Option<usize>, String> {
    let Some(name) = tenant else { return Ok(None) };
    if sc.tenants.is_empty() {
        // No tenant sections configured: the name still namespaces the
        // job's storage keys, but there is no quota slot to bill.
        return Ok(None);
    }
    match sc.tenants.iter().position(|t| t.name == name) {
        Some(i) => Ok(Some(i)),
        None => {
            let known: Vec<&str> = sc.tenants.iter().map(|t| t.name.as_str()).collect();
            Err(format!(
                "unknown tenant '{name}' (known: {})",
                known.join(", ")
            ))
        }
    }
}

/// The daemon's report wrapper: identifying fields + the service run
/// summary, stamped with the schema version.
fn daemon_report(sc: &Scenario, submissions: usize, summary: Json) -> Json {
    versioned(
        obj()
            .field("scenario", sc.name.as_str())
            .field("seed", sc.seed)
            .field("submissions", submissions)
            .field("run", summary)
            .build(),
    )
}

/// The submission log of a batch `serve` run: entries reference the
/// sampled template by index (the scenario file already holds the
/// specs), so a replay against the same scenario reconstructs every
/// offered job loss-free.
pub fn submission_log(sc: &Scenario) -> anyhow::Result<Json> {
    let arr = sc
        .arrivals
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("'{}' has no 'arrivals' section to log", sc.name))?;
    let offered = crate::coordinator::service::offered_jobs(sc, arr);
    let entries: Vec<Json> = offered
        .iter()
        .map(|o| {
            obj()
                .field("seq", o.seq)
                .field("arrival", o.arrival)
                .field(
                    "tenant",
                    o.tenant.map_or(Json::Null, |i| Json::from(i as u64)),
                )
                .field(
                    "template",
                    o.template
                        .map_or(Json::Null, |i| Json::from(i as u64)),
                )
                .build()
        })
        .collect();
    Ok(obj()
        .field("slec_submission_log", 1u64)
        .field("mode", "serve")
        .field("seed", sc.seed)
        .field("entries", Json::Arr(entries))
        .build())
}

/// Replay a submission log.
///
/// - `mode: "serve"` needs the original scenario (templates live
///   there); the output is the raw service document — byte-identical to
///   the `slec serve` artifact of the run that wrote the log.
/// - `mode: "daemon"` rebuilds the synthetic daemon scenario from the
///   log's `config` block (or runs against an explicit scenario) and
///   re-submits every logged spec at its logged virtual arrival; the
///   output is byte-identical to the daemon's final report.
pub fn replay_submission_log(log: &Json, scenario: Option<&Scenario>) -> anyhow::Result<Json> {
    let magic = log
        .get(LOG_MAGIC)
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow::anyhow!("not a submission log (missing '{LOG_MAGIC}')"))?;
    anyhow::ensure!(magic == 1, "unsupported submission-log version {magic}");
    let mode = log
        .get("mode")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("submission log has no 'mode'"))?;
    let entries = log
        .get("entries")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("submission log has no 'entries' array"))?;
    match mode {
        "serve" => {
            let sc = scenario.ok_or_else(|| {
                anyhow::anyhow!("replaying a serve log needs --scenario (templates live there)")
            })?;
            let offered = serve_entries_to_offered(sc, entries)?;
            run_service_with(sc, &offered)
        }
        "daemon" => {
            let sc = match scenario {
                Some(sc) => sc.clone(),
                None => daemon_scenario_from_log(log)?,
            };
            let workers = *sc.workers.first().ok_or_else(|| {
                anyhow::anyhow!("scenario '{}' has an empty workers sweep", sc.name)
            })?;
            let mut core = ServiceCore::new(&sc, workers)?;
            for (i, e) in entries.iter().enumerate() {
                let o = daemon_entry_to_offered(&sc, e, i)?;
                core.arrive(o)?;
            }
            core.drain()?;
            core.check_drained()?;
            let summary = core.summary();
            Ok(daemon_report(&sc, entries.len(), summary))
        }
        other => anyhow::bail!("unknown submission-log mode '{other}'"),
    }
}

fn serve_entries_to_offered(sc: &Scenario, entries: &[Json]) -> anyhow::Result<Vec<Offered>> {
    let arr = sc
        .arrivals
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("scenario '{}' has no 'arrivals' section", sc.name))?;
    let mut out = Vec::with_capacity(entries.len());
    for (i, e) in entries.iter().enumerate() {
        let seq = e
            .get("seq")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("entry {i}: missing 'seq'"))?;
        let arrival = e
            .get("arrival")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("entry {i}: missing 'arrival'"))?;
        let ti = e
            .get("template")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("entry {i}: serve logs need a 'template' index"))?;
        anyhow::ensure!(
            ti < arr.templates.len(),
            "entry {i}: template {ti} out of range ({} templates)",
            arr.templates.len()
        );
        let tenant = match e.get("tenant") {
            None | Some(Json::Null) => None,
            Some(v) => {
                let t = v
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("entry {i}: 'tenant' must be an index"))?;
                anyhow::ensure!(
                    t < sc.tenants.len(),
                    "entry {i}: tenant {t} out of range ({} tenants)",
                    sc.tenants.len()
                );
                Some(t)
            }
        };
        let (_, template) = &arr.templates[ti];
        let mut spec = template.clone();
        spec.arrival = arrival;
        if let Some(t) = tenant {
            spec.tenant = Some(sc.tenants[t].name.clone());
        }
        out.push(Offered {
            seq,
            arrival,
            tenant,
            template: Some(ti),
            spec,
        });
    }
    Ok(out)
}

fn daemon_entry_to_offered(sc: &Scenario, e: &Json, i: usize) -> anyhow::Result<Offered> {
    let seq = e
        .get("seq")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow::anyhow!("entry {i}: missing 'seq'"))?;
    let arrival = e
        .get("arrival")
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("entry {i}: missing 'arrival'"))?;
    let raw = e
        .get("spec")
        .ok_or_else(|| anyhow::anyhow!("entry {i}: daemon logs need a 'spec' document"))?;
    let mut spec = parse_job_spec(raw, sc.storage.as_ref(), SpecContext::Submit)
        .map_err(|err| anyhow::anyhow!("entry {i}: {err}"))?;
    let tenant = resolve_tenant(sc, spec.tenant.as_deref())
        .map_err(|err| anyhow::anyhow!("entry {i}: {err}"))?;
    spec.arrival = arrival;
    Ok(Offered {
        seq,
        arrival,
        tenant,
        template: None,
        spec,
    })
}

/// Rebuild the synthetic daemon scenario from a daemon log's `config`
/// block.
fn daemon_scenario_from_log(log: &Json) -> anyhow::Result<Scenario> {
    let seed = log
        .get("seed")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow::anyhow!("submission log has no 'seed'"))?;
    let cfgj = log
        .get("config")
        .ok_or_else(|| anyhow::anyhow!("daemon log has no 'config' block; pass --scenario"))?;
    check_schema_version(log)?;
    let cfg = DaemonConfig {
        seed,
        workers: cfgj
            .get("workers")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("log config has no 'workers'"))?,
        queue_depth: cfgj.get("queue_depth").and_then(Json::as_usize).unwrap_or(0),
        max_inflight: cfgj.get("max_inflight").and_then(Json::as_usize).unwrap_or(0),
        ..DaemonConfig::default()
    };
    cfg.to_scenario()
}

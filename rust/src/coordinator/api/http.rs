//! A deliberately small HTTP/1.1 layer over `std::net` — just enough
//! protocol for the daemon's JSON API, with zero dependencies.
//!
//! Scope: one request per connection (`Connection: close` semantics),
//! methods GET/POST, a `Content-Length` body (no chunked encoding), and
//! hard caps on header and body size so a misbehaving client cannot
//! balloon memory. Everything the daemon serves is JSON except
//! `/healthz` and `/metrics`, which follow their conventional plain-text
//! shapes.

use std::io::{BufRead, BufReader, Read, Write};

use crate::util::json::{obj, Json};

use super::spec::SCHEMA_VERSION;

/// Cap on the request line + headers. Anything larger is a client bug.
const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Cap on a request body (a job spec is a few hundred bytes; scenario
/// uploads are not a thing on this surface).
const MAX_BODY_BYTES: usize = 4 * 1024 * 1024;

/// The daemon's route table: method, path, one-line description. The
/// single source of truth — `GET /v1/scenarios`-style docs tables in the
/// README are tested against it, and the 404 handler lists it.
pub const ENDPOINTS: &[(&str, &str, &str)] = &[
    ("POST", "/v1/jobs", "submit a job spec; returns seq + admission status"),
    ("GET", "/v1/jobs/<seq>", "poll one job: queued / running / done + report"),
    ("GET", "/v1/report", "service report over everything submitted so far"),
    ("GET", "/v1/scenarios", "list bundled scenario files"),
    ("GET", "/healthz", "liveness probe (plain text)"),
    ("GET", "/metrics", "counters in Prometheus text format"),
    ("POST", "/v1/shutdown", "drain queued jobs, return the final report, stop"),
];

/// A parsed request: method, path, and raw body bytes.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

/// A protocol-level failure while reading a request, mapped straight to
/// a status code by the caller.
#[derive(Debug)]
pub struct BadRequest {
    pub status: u16,
    pub msg: String,
}

fn bad(status: u16, msg: impl Into<String>) -> BadRequest {
    BadRequest {
        status,
        msg: msg.into(),
    }
}

/// Read one HTTP/1.1 request from a stream. Enforces the header and
/// body caps; tolerates (and ignores) headers it does not understand.
pub fn read_request(stream: &mut impl Read) -> Result<Request, BadRequest> {
    let mut r = BufReader::new(stream);
    let mut line = String::new();
    let mut header_bytes = 0usize;
    read_line(&mut r, &mut line, &mut header_bytes)?;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| bad(400, "empty request line"))?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| bad(400, "request line has no path"))?
        .to_string();
    match parts.next() {
        Some(v) if v.starts_with("HTTP/1.") => {}
        _ => return Err(bad(400, "not an HTTP/1.x request")),
    }
    if method != "GET" && method != "POST" {
        return Err(bad(405, format!("method '{method}' not allowed (GET or POST)")));
    }
    let mut content_length = 0usize;
    loop {
        line.clear();
        read_line(&mut r, &mut line, &mut header_bytes)?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            break;
        }
        if let Some((name, value)) = trimmed.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .map_err(|_| bad(400, "unparsable Content-Length"))?;
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err(bad(
            400,
            format!("body of {content_length} bytes exceeds the {MAX_BODY_BYTES}-byte cap"),
        ));
    }
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body).map_err(|e| match io_err(e) {
        b if b.status == 408 => b,
        b => bad(400, format!("short body: {}", b.msg)),
    })?;
    Ok(Request { method, path, body })
}

/// Map a socket read error onto a status: a timeout (the connection's
/// `set_read_timeout` deadline, surfaced as `WouldBlock` on Unix or
/// `TimedOut` on Windows) is the client's fault and gets 408 —
/// everything else is a plain 400.
fn io_err(e: std::io::Error) -> BadRequest {
    use std::io::ErrorKind;
    match e.kind() {
        ErrorKind::WouldBlock | ErrorKind::TimedOut => {
            bad(408, "timed out waiting for the request")
        }
        _ => bad(400, format!("reading request: {e}")),
    }
}

fn read_line(
    r: &mut impl BufRead,
    line: &mut String,
    header_bytes: &mut usize,
) -> Result<(), BadRequest> {
    let n = r.read_line(line).map_err(io_err)?;
    if n == 0 {
        return Err(bad(400, "connection closed mid-request"));
    }
    *header_bytes += n;
    if *header_bytes > MAX_HEADER_BYTES {
        return Err(bad(400, "request headers exceed the 16 KiB cap"));
    }
    Ok(())
}

/// A response ready to serialize: status, content type and body.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    /// A JSON response.
    pub fn json(status: u16, doc: &Json) -> Self {
        Response {
            status,
            content_type: "application/json",
            body: doc.to_string_pretty().into_bytes(),
        }
    }

    /// A plain-text response (healthz, metrics).
    pub fn text(status: u16, body: impl Into<String>) -> Self {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.into().into_bytes(),
        }
    }

    /// An error response: the one error vocabulary of the API surface —
    /// `{"error": ..., "schema_version": ...}` — so clients parse every
    /// failure the same way, whichever layer produced it.
    pub fn error(status: u16, msg: &str) -> Self {
        let doc = obj()
            .field("error", msg)
            .field("schema_version", SCHEMA_VERSION)
            .build();
        Response::json(status, &doc)
    }

    /// Serialize onto the wire.
    pub fn write_to(&self, stream: &mut impl Write) -> std::io::Result<()> {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            self.body.len()
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(&self.body)?;
        stream.flush()
    }
}

/// Reason phrases for the handful of statuses this surface speaks.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        _ => "Unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(raw: &str) -> Result<Request, BadRequest> {
        read_request(&mut raw.as_bytes())
    }

    #[test]
    fn parses_a_post_with_body() {
        let r = req("POST /v1/jobs HTTP/1.1\r\nHost: x\r\nContent-Length: 7\r\n\r\n{\"a\":1}")
            .unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/v1/jobs");
        assert_eq!(r.body, b"{\"a\":1}");
    }

    #[test]
    fn parses_a_get_without_body() {
        let r = req("GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/healthz");
        assert!(r.body.is_empty());
    }

    #[test]
    fn rejects_protocol_garbage() {
        assert_eq!(req("").unwrap_err().status, 400);
        assert_eq!(req("GET /x\r\n\r\n").unwrap_err().status, 400);
        assert_eq!(req("DELETE /x HTTP/1.1\r\n\r\n").unwrap_err().status, 405);
        assert_eq!(
            req("POST /x HTTP/1.1\r\nContent-Length: ten\r\n\r\n")
                .unwrap_err()
                .status,
            400
        );
        // Declared body longer than what arrives.
        assert_eq!(
            req("POST /x HTTP/1.1\r\nContent-Length: 50\r\n\r\nshort")
                .unwrap_err()
                .status,
            400
        );
        // Body cap.
        let huge = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", 5 << 20);
        assert_eq!(req(&huge).unwrap_err().status, 400);
    }

    #[test]
    fn socket_timeouts_map_to_408_everything_else_to_400() {
        use std::io::{Error, ErrorKind};
        assert_eq!(io_err(Error::from(ErrorKind::WouldBlock)).status, 408);
        assert_eq!(io_err(Error::from(ErrorKind::TimedOut)).status, 408);
        assert_eq!(io_err(Error::from(ErrorKind::ConnectionReset)).status, 400);
    }

    #[test]
    fn responses_serialize_with_length_and_reason() {
        let mut out = Vec::new();
        Response::text(200, "ok").write_to(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 2\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\nok"), "{text}");
    }

    #[test]
    fn error_bodies_carry_the_schema_version() {
        let r = Response::error(400, "unknown job key 'speling'");
        let doc = crate::util::json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(
            doc.get("error").unwrap().as_str(),
            Some("unknown job key 'speling'")
        );
        assert_eq!(doc.get("schema_version").unwrap().as_u64(), Some(SCHEMA_VERSION));
    }
}

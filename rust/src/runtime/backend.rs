//! Compute backends: the numeric operations the coordinator's workers
//! perform, either through the AOT-compiled PJRT artifacts
//! (`PjrtBackend`, behind the `pjrt` feature) or the pure-Rust host
//! kernels ([`HostBackend`], always available and the default).
//!
//! `PjrtBackend` resolves artifacts by shape-mangled name
//! (`matmul_bt_{m}x{k}x{n}` …). Shapes outside the compiled set fall back
//! to the host kernels — counted, so benchmarks can verify the hot path
//! really runs through PJRT.

#[cfg(feature = "pjrt")]
use std::sync::atomic::{AtomicU64, Ordering};

use crate::linalg::matrix::Matrix;
use crate::linalg::gemm;
#[cfg(feature = "pjrt")]
use crate::runtime::{PjrtHandleSync, Tensor};

/// The worker-side numeric ops (Fig 2's f_enc / f_comp / f_dec payloads).
pub trait ComputeBackend: Send + Sync {
    /// `C_ij = A_i · B_jᵀ`.
    fn block_product(&self, a: &Matrix, b: &Matrix) -> Matrix;
    /// Parity encode: Σ blocks.
    fn stack_sum(&self, blocks: &[&Matrix]) -> Matrix;
    /// Recovery: parity − Σ survivors.
    fn parity_residual(&self, parity: &Matrix, survivors: &[&Matrix]) -> Matrix;
    /// `y = A·x`.
    fn gemv(&self, a: &Matrix, x: &[f32]) -> Vec<f32>;
    fn name(&self) -> &'static str;
}

/// Pure-Rust reference backend (also the oracle in tests).
#[derive(Debug, Default)]
pub struct HostBackend;

impl ComputeBackend for HostBackend {
    fn block_product(&self, a: &Matrix, b: &Matrix) -> Matrix {
        gemm::matmul_bt(a, b)
    }

    fn stack_sum(&self, blocks: &[&Matrix]) -> Matrix {
        assert!(!blocks.is_empty());
        for b in &blocks[1..] {
            assert_eq!(b.shape(), blocks[0].shape());
        }
        let slices: Vec<&[f32]> = blocks.iter().map(|b| b.data.as_slice()).collect();
        Matrix::from_vec(
            blocks[0].rows,
            blocks[0].cols,
            crate::linalg::kernels::sum(&slices),
        )
    }

    fn parity_residual(&self, parity: &Matrix, survivors: &[&Matrix]) -> Matrix {
        for b in survivors {
            assert_eq!(b.shape(), parity.shape());
        }
        let slices: Vec<&[f32]> = survivors.iter().map(|b| b.data.as_slice()).collect();
        Matrix::from_vec(
            parity.rows,
            parity.cols,
            crate::linalg::kernels::residual(&parity.data, &slices),
        )
    }

    fn gemv(&self, a: &Matrix, x: &[f32]) -> Vec<f32> {
        gemm::matvec(a, x)
    }

    fn name(&self) -> &'static str {
        "host"
    }
}

/// PJRT-backed compute with per-op host fallback for uncompiled shapes.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    handle: PjrtHandleSync,
    host: HostBackend,
    /// Ops served by PJRT artifacts.
    pub pjrt_ops: AtomicU64,
    /// Ops that fell back to host kernels (shape not in the manifest).
    pub fallback_ops: AtomicU64,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    pub fn new(handle: PjrtHandleSync) -> PjrtBackend {
        PjrtBackend {
            handle,
            host: HostBackend,
            pjrt_ops: AtomicU64::new(0),
            fallback_ops: AtomicU64::new(0),
        }
    }

    pub fn counts(&self) -> (u64, u64) {
        (
            self.pjrt_ops.load(Ordering::Relaxed),
            self.fallback_ops.load(Ordering::Relaxed),
        )
    }

    fn try_pjrt(&self, artifact: &str, inputs: Vec<Tensor>) -> Option<Vec<Tensor>> {
        if !self.handle.has(artifact) {
            return None;
        }
        match self.handle.execute(artifact, inputs) {
            Ok(outs) => {
                self.pjrt_ops.fetch_add(1, Ordering::Relaxed);
                Some(outs)
            }
            Err(e) => {
                // A manifest hit that fails to execute is a real bug —
                // surface it loudly rather than silently falling back.
                panic!("PJRT execution of '{artifact}' failed: {e}");
            }
        }
    }

    fn fallback(&self) {
        self.fallback_ops.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(feature = "pjrt")]
impl ComputeBackend for PjrtBackend {
    fn block_product(&self, a: &Matrix, b: &Matrix) -> Matrix {
        let artifact = format!("matmul_bt_{}x{}x{}", a.rows, a.cols, b.rows);
        if let Some(outs) =
            self.try_pjrt(&artifact, vec![Tensor::from_matrix(a), Tensor::from_matrix(b)])
        {
            return outs[0].to_matrix().expect("rank-2 output");
        }
        self.fallback();
        self.host.block_product(a, b)
    }

    fn stack_sum(&self, blocks: &[&Matrix]) -> Matrix {
        let (r, c) = blocks[0].shape();
        let artifact = format!("stack_sum_{}x{r}x{c}", blocks.len());
        if self.handle.has(artifact.as_str()) {
            let outs = self
                .try_pjrt(&artifact, vec![Tensor::stack(blocks)])
                .expect("checked has()");
            return outs[0].to_matrix().expect("rank-2 output");
        }
        self.fallback();
        self.host.stack_sum(blocks)
    }

    fn parity_residual(&self, parity: &Matrix, survivors: &[&Matrix]) -> Matrix {
        if survivors.is_empty() {
            return parity.clone();
        }
        let (r, c) = parity.shape();
        let artifact = format!("parity_residual_{}x{r}x{c}", survivors.len());
        if self.handle.has(artifact.as_str()) {
            let outs = self
                .try_pjrt(
                    &artifact,
                    vec![Tensor::from_matrix(parity), Tensor::stack(survivors)],
                )
                .expect("checked has()");
            return outs[0].to_matrix().expect("rank-2 output");
        }
        self.fallback();
        self.host.parity_residual(parity, survivors)
    }

    fn gemv(&self, a: &Matrix, x: &[f32]) -> Vec<f32> {
        let artifact = format!("gemv_{}x{}", a.rows, a.cols);
        if let Some(outs) =
            self.try_pjrt(&artifact, vec![Tensor::from_matrix(a), Tensor::from_vec1(x)])
        {
            return outs[0].data.clone();
        }
        self.fallback();
        self.host.gemv(a, x)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn host_backend_matches_gemm() {
        let mut rng = Pcg64::new(1);
        let a = Matrix::randn(16, 24, &mut rng, 0.0, 1.0);
        let b = Matrix::randn(12, 24, &mut rng, 0.0, 1.0);
        let be = HostBackend;
        assert_eq!(be.block_product(&a, &b), gemm::matmul_bt(&a, &b));
        assert_eq!(be.name(), "host");
    }

    #[test]
    fn host_stack_ops() {
        let mut rng = Pcg64::new(2);
        let blocks: Vec<Matrix> = (0..4)
            .map(|_| Matrix::randn(5, 6, &mut rng, 0.0, 1.0))
            .collect();
        let refs: Vec<&Matrix> = blocks.iter().collect();
        let be = HostBackend;
        let sum = be.stack_sum(&refs);
        let manual = blocks[0]
            .add(&blocks[1])
            .add(&blocks[2])
            .add(&blocks[3]);
        assert!(sum.rel_err(&manual) < 1e-6);
        // residual(sum, all but one) == the left-out block
        let surv: Vec<&Matrix> = blocks[1..].iter().collect();
        let rec = be.parity_residual(&sum, &surv);
        assert!(rec.rel_err(&blocks[0]) < 1e-5);
    }

    #[test]
    fn host_gemv_matches() {
        let mut rng = Pcg64::new(3);
        let a = Matrix::randn(20, 30, &mut rng, 0.0, 1.0);
        let x: Vec<f32> = (0..30).map(|i| i as f32 * 0.1).collect();
        let be = HostBackend;
        let y = be.gemv(&a, &x);
        let want = gemm::matvec(&a, &x);
        assert_eq!(y, want);
    }

    #[test]
    fn residual_with_no_survivors_is_parity() {
        let p = Matrix::eye(3);
        let be = HostBackend;
        assert_eq!(be.parity_residual(&p, &[]), p);
    }
}

//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`) and execute
//! them from the coordinator's hot path.
//!
//! Layering (see DESIGN.md): `python/compile/aot.py` lowers the L2 JAX
//! graphs (which call the L1 Pallas kernels) to HLO **text**; this module
//! parses the text with `HloModuleProto::from_text_file`, compiles it on
//! the PJRT CPU client, caches the executable, and exposes typed helpers.
//!
//! Threading: the `xla` crate's `PjRtClient` is `Rc`-backed (not `Send`),
//! so a dedicated **engine thread** owns the client and executables; the
//! cloneable [`PjrtHandle`] ships requests over a channel. The CPU PJRT
//! client parallelizes each op internally, so serializing requests does
//! not starve the machine.

pub mod backend;
pub mod manifest;

pub use backend::{ComputeBackend, HostBackend, PjrtBackend};
pub use manifest::{ArtifactInfo, Manifest};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;

/// A tensor crossing the engine boundary: flat f32 data + dims.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub dims: Vec<i64>,
}

impl Tensor {
    pub fn from_matrix(m: &crate::linalg::Matrix) -> Tensor {
        Tensor {
            data: m.data.clone(),
            dims: vec![m.rows as i64, m.cols as i64],
        }
    }

    pub fn to_matrix(&self) -> anyhow::Result<crate::linalg::Matrix> {
        anyhow::ensure!(self.dims.len() == 2, "expected rank-2, got {:?}", self.dims);
        Ok(crate::linalg::Matrix::from_vec(
            self.dims[0] as usize,
            self.dims[1] as usize,
            self.data.clone(),
        ))
    }

    /// Stack blocks of identical shape into a rank-3 (l, r, c) tensor.
    pub fn stack(blocks: &[&crate::linalg::Matrix]) -> Tensor {
        assert!(!blocks.is_empty());
        let (r, c) = blocks[0].shape();
        let mut data = Vec::with_capacity(blocks.len() * r * c);
        for b in blocks {
            assert_eq!(b.shape(), (r, c), "stack blocks must share a shape");
            data.extend_from_slice(&b.data);
        }
        Tensor {
            data,
            dims: vec![blocks.len() as i64, r as i64, c as i64],
        }
    }

    pub fn from_vec1(v: &[f32]) -> Tensor {
        Tensor {
            data: v.to_vec(),
            dims: vec![v.len() as i64],
        }
    }
}

enum Request {
    Execute {
        artifact: String,
        inputs: Vec<Tensor>,
        reply: mpsc::Sender<anyhow::Result<Vec<Tensor>>>,
    },
    Stats {
        reply: mpsc::Sender<EngineStats>,
    },
    Shutdown,
}

/// Counters exposed by the engine.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct EngineStats {
    pub executions: u64,
    pub compiles: u64,
    pub errors: u64,
}

/// The engine: owns the dedicated PJRT thread for its lifetime.
pub struct PjrtRuntime {
    handle: PjrtHandleSync,
    thread: Option<std::thread::JoinHandle<()>>,
}

/// Internally synchronized handle (Sender guarded by a Mutex for Sync).
#[derive(Clone)]
pub struct PjrtHandleSync {
    tx: std::sync::Arc<std::sync::Mutex<mpsc::Sender<Request>>>,
    manifest: std::sync::Arc<Manifest>,
}

impl PjrtHandleSync {
    /// Execute an artifact by exact name.
    pub fn execute(&self, artifact: &str, inputs: Vec<Tensor>) -> anyhow::Result<Vec<Tensor>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Request::Execute {
                artifact: artifact.to_string(),
                inputs,
                reply: reply_tx,
            })
            .map_err(|_| anyhow::anyhow!("PJRT engine thread is gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("PJRT engine dropped the reply"))?
    }

    pub fn stats(&self) -> EngineStats {
        let (reply_tx, reply_rx) = mpsc::channel();
        if self
            .tx
            .lock()
            .unwrap()
            .send(Request::Stats { reply: reply_tx })
            .is_err()
        {
            return EngineStats::default();
        }
        reply_rx.recv().unwrap_or_default()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// True when the manifest has an artifact of this exact name.
    pub fn has(&self, artifact: &str) -> bool {
        self.manifest.get(artifact).is_some()
    }
}

impl PjrtRuntime {
    /// Start the engine on the artifacts directory. Fails fast if the
    /// manifest is missing (run `make artifacts`).
    pub fn start(dir: impl AsRef<Path>) -> anyhow::Result<PjrtRuntime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = std::sync::Arc::new(Manifest::load(&dir)?);
        let (tx, rx) = mpsc::channel::<Request>();
        let m2 = std::sync::Arc::clone(&manifest);
        let thread = std::thread::Builder::new()
            .name("slec-pjrt".into())
            .spawn(move || engine_main(dir, m2, rx))?;
        Ok(PjrtRuntime {
            handle: PjrtHandleSync {
                tx: std::sync::Arc::new(std::sync::Mutex::new(tx)),
                manifest,
            },
            thread: Some(thread),
        })
    }

    /// Default artifacts directory: `$SLEC_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("SLEC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn handle(&self) -> PjrtHandleSync {
        self.handle.clone()
    }
}

impl Drop for PjrtRuntime {
    fn drop(&mut self) {
        let _ = self.handle.tx.lock().unwrap().send(Request::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn engine_main(dir: PathBuf, manifest: std::sync::Arc<Manifest>, rx: mpsc::Receiver<Request>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("[slec-pjrt] failed to create PJRT CPU client: {e}");
            // Drain requests with errors so callers don't hang.
            for req in rx {
                match req {
                    Request::Execute { reply, .. } => {
                        let _ = reply.send(Err(anyhow::anyhow!("no PJRT client")));
                    }
                    Request::Stats { reply } => {
                        let _ = reply.send(EngineStats::default());
                    }
                    Request::Shutdown => break,
                }
            }
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    let mut stats = EngineStats::default();

    for req in rx {
        match req {
            Request::Shutdown => break,
            Request::Stats { reply } => {
                let _ = reply.send(stats.clone());
            }
            Request::Execute {
                artifact,
                inputs,
                reply,
            } => {
                let result =
                    execute_one(&client, &dir, &manifest, &mut cache, &mut stats, &artifact, inputs);
                if result.is_err() {
                    stats.errors += 1;
                }
                let _ = reply.send(result);
            }
        }
    }
}

fn execute_one(
    client: &xla::PjRtClient,
    dir: &Path,
    manifest: &Manifest,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    stats: &mut EngineStats,
    artifact: &str,
    inputs: Vec<Tensor>,
) -> anyhow::Result<Vec<Tensor>> {
    let info = manifest
        .get(artifact)
        .ok_or_else(|| anyhow::anyhow!("artifact '{artifact}' not in manifest"))?;
    anyhow::ensure!(
        inputs.len() == info.inputs.len(),
        "artifact '{artifact}' wants {} inputs, got {}",
        info.inputs.len(),
        inputs.len()
    );
    for (i, (t, want)) in inputs.iter().zip(&info.inputs).enumerate() {
        let got: Vec<i64> = t.dims.clone();
        anyhow::ensure!(
            got == *want,
            "artifact '{artifact}' input {i}: shape {got:?} != manifest {want:?}"
        );
    }

    if !cache.contains_key(artifact) {
        let path = dir.join(&info.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {artifact}: {e}"))?;
        stats.compiles += 1;
        cache.insert(artifact.to_string(), exe);
    }
    let exe = cache.get(artifact).unwrap();

    let literals: Vec<xla::Literal> = inputs
        .iter()
        .map(|t| {
            xla::Literal::vec1(&t.data)
                .reshape(&t.dims)
                .map_err(|e| anyhow::anyhow!("reshaping input to {:?}: {e}", t.dims))
        })
        .collect::<anyhow::Result<Vec<_>>>()?;

    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| anyhow::anyhow!("executing {artifact}: {e}"))?;
    stats.executions += 1;
    let tuple = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("fetching result of {artifact}: {e}"))?;
    // aot.py lowers with return_tuple=True: unpack N outputs.
    let parts = tuple
        .to_tuple()
        .map_err(|e| anyhow::anyhow!("untupling result of {artifact}: {e}"))?;
    anyhow::ensure!(
        parts.len() == info.outputs.len(),
        "artifact '{artifact}': {} outputs vs manifest {}",
        parts.len(),
        info.outputs.len()
    );
    let mut out = Vec::with_capacity(parts.len());
    for (lit, dims) in parts.into_iter().zip(&info.outputs) {
        let data = lit
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("reading result of {artifact}: {e}"))?;
        out.push(Tensor {
            data,
            dims: dims.clone(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_matrix_roundtrip() {
        let mut rng = crate::util::rng::Pcg64::new(1);
        let m = crate::linalg::Matrix::randn(3, 5, &mut rng, 0.0, 1.0);
        let t = Tensor::from_matrix(&m);
        assert_eq!(t.dims, vec![3, 5]);
        assert_eq!(t.to_matrix().unwrap(), m);
    }

    #[test]
    fn tensor_stack_shape() {
        let a = crate::linalg::Matrix::zeros(2, 3);
        let b = crate::linalg::Matrix::eye(2).slice(0, 2, 0, 2); // wrong shape
        let t = Tensor::stack(&[&a, &a]);
        assert_eq!(t.dims, vec![2, 2, 3]);
        assert_eq!(t.data.len(), 12);
        let _ = b; // shape-mismatch panic covered below
    }

    #[test]
    #[should_panic(expected = "share a shape")]
    fn tensor_stack_rejects_mixed_shapes() {
        let a = crate::linalg::Matrix::zeros(2, 3);
        let b = crate::linalg::Matrix::zeros(3, 2);
        let _ = Tensor::stack(&[&a, &b]);
    }

    #[test]
    fn rank_check_on_to_matrix() {
        let t = Tensor::from_vec1(&[1.0, 2.0]);
        assert!(t.to_matrix().is_err());
    }
}

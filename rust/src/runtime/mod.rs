//! Compute runtime: the [`ComputeBackend`] abstraction the coordinator's
//! workers execute through, plus the optional PJRT engine.
//!
//! Two backends exist:
//!
//! - [`HostBackend`] (always available, the default): pure-Rust kernels
//!   from [`crate::linalg::gemm`] — hermetic, offline, and the oracle the
//!   tests verify against.
//! - `PjrtBackend` (behind the `pjrt` cargo feature): routes shape-mangled
//!   artifact names to AOT-compiled HLO executables via a dedicated engine
//!   thread ([`pjrt`]). Requires `make artifacts` and a real `xla` crate
//!   at link time; the vendored `vendor/xla` stub keeps the code
//!   type-checking offline.
//!
//! The artifact [`Manifest`] (the contract with `python/compile/aot.py`)
//! is feature-independent so `slec inspect-artifacts` always works.

pub mod backend;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use backend::{ComputeBackend, HostBackend};
#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
pub use manifest::{ArtifactInfo, JobBlockInfo, JobManifest, Manifest};
#[cfg(feature = "pjrt")]
pub use pjrt::{EngineStats, PjrtHandleSync, PjrtRuntime};

use std::path::PathBuf;

/// Default artifacts directory: `$SLEC_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("SLEC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Placeholder for the PJRT engine when built without the `pjrt` feature.
///
/// Never constructed; it exists so `Config::build_env`'s return type
/// (`Option<PjrtRuntime>`) is feature-independent and callers destructure
/// identically under either build.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtRuntime {
    _private: (),
}

/// A tensor crossing the engine boundary: flat f32 data + dims.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub dims: Vec<i64>,
}

impl Tensor {
    pub fn from_matrix(m: &crate::linalg::Matrix) -> Tensor {
        Tensor {
            data: m.data.clone(),
            dims: vec![m.rows as i64, m.cols as i64],
        }
    }

    pub fn to_matrix(&self) -> anyhow::Result<crate::linalg::Matrix> {
        anyhow::ensure!(self.dims.len() == 2, "expected rank-2, got {:?}", self.dims);
        Ok(crate::linalg::Matrix::from_vec(
            self.dims[0] as usize,
            self.dims[1] as usize,
            self.data.clone(),
        ))
    }

    /// Stack blocks of identical shape into a rank-3 (l, r, c) tensor.
    pub fn stack(blocks: &[&crate::linalg::Matrix]) -> Tensor {
        assert!(!blocks.is_empty());
        let (r, c) = blocks[0].shape();
        let mut data = Vec::with_capacity(blocks.len() * r * c);
        for b in blocks {
            assert_eq!(b.shape(), (r, c), "stack blocks must share a shape");
            data.extend_from_slice(&b.data);
        }
        Tensor {
            data,
            dims: vec![blocks.len() as i64, r as i64, c as i64],
        }
    }

    pub fn from_vec1(v: &[f32]) -> Tensor {
        Tensor {
            data: v.to_vec(),
            dims: vec![v.len() as i64],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_matrix_roundtrip() {
        let mut rng = crate::util::rng::Pcg64::new(1);
        let m = crate::linalg::Matrix::randn(3, 5, &mut rng, 0.0, 1.0);
        let t = Tensor::from_matrix(&m);
        assert_eq!(t.dims, vec![3, 5]);
        assert_eq!(t.to_matrix().unwrap(), m);
    }

    #[test]
    fn tensor_stack_shape() {
        let a = crate::linalg::Matrix::zeros(2, 3);
        let b = crate::linalg::Matrix::eye(2).slice(0, 2, 0, 2); // wrong shape
        let t = Tensor::stack(&[&a, &a]);
        assert_eq!(t.dims, vec![2, 2, 3]);
        assert_eq!(t.data.len(), 12);
        let _ = b; // shape-mismatch panic covered below
    }

    #[test]
    #[should_panic(expected = "share a shape")]
    fn tensor_stack_rejects_mixed_shapes() {
        let a = crate::linalg::Matrix::zeros(2, 3);
        let b = crate::linalg::Matrix::zeros(3, 2);
        let _ = Tensor::stack(&[&a, &b]);
    }

    #[test]
    fn rank_check_on_to_matrix() {
        let t = Tensor::from_vec1(&[1.0, 2.0]);
        assert!(t.to_matrix().is_err());
    }

    #[test]
    fn artifacts_dir_default_and_override() {
        // No env manipulation (tests run in parallel): assert against
        // whatever the ambient environment says the answer should be.
        let d = default_artifacts_dir();
        match std::env::var_os("SLEC_ARTIFACTS") {
            Some(v) => assert_eq!(d, std::path::PathBuf::from(v)),
            None => assert_eq!(d, std::path::PathBuf::from("artifacts")),
        }
    }
}

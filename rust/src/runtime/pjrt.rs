//! The PJRT engine: load AOT artifacts (`artifacts/*.hlo.txt`) and execute
//! them from the coordinator's hot path. Compiled only under the `pjrt`
//! cargo feature.
//!
//! Layering (see DESIGN.md): `python/compile/aot.py` lowers the L2 JAX
//! graphs (which call the L1 Pallas kernels) to HLO **text**; this module
//! parses the text with `HloModuleProto::from_text_file`, compiles it on
//! the PJRT CPU client, caches the executable, and exposes typed helpers.
//!
//! Threading: the `xla` crate's `PjRtClient` is `Rc`-backed (not `Send`),
//! so a dedicated **engine thread** owns the client and executables; the
//! cloneable [`PjrtHandleSync`] ships requests over a channel. The CPU
//! PJRT client parallelizes each op internally, so serializing requests
//! does not starve the machine.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;

use crate::runtime::manifest::Manifest;
use crate::runtime::Tensor;

enum Request {
    Execute {
        artifact: String,
        inputs: Vec<Tensor>,
        reply: mpsc::Sender<anyhow::Result<Vec<Tensor>>>,
    },
    Stats {
        reply: mpsc::Sender<EngineStats>,
    },
    Shutdown,
}

/// Counters exposed by the engine.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct EngineStats {
    pub executions: u64,
    pub compiles: u64,
    pub errors: u64,
}

/// The engine: owns the dedicated PJRT thread for its lifetime.
pub struct PjrtRuntime {
    handle: PjrtHandleSync,
    thread: Option<std::thread::JoinHandle<()>>,
}

/// Internally synchronized handle (Sender guarded by a Mutex for Sync).
#[derive(Clone)]
pub struct PjrtHandleSync {
    tx: std::sync::Arc<std::sync::Mutex<mpsc::Sender<Request>>>,
    manifest: std::sync::Arc<Manifest>,
}

impl PjrtHandleSync {
    /// Execute an artifact by exact name.
    pub fn execute(&self, artifact: &str, inputs: Vec<Tensor>) -> anyhow::Result<Vec<Tensor>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .lock()
            .unwrap()
            .send(Request::Execute {
                artifact: artifact.to_string(),
                inputs,
                reply: reply_tx,
            })
            .map_err(|_| anyhow::anyhow!("PJRT engine thread is gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("PJRT engine dropped the reply"))?
    }

    pub fn stats(&self) -> EngineStats {
        let (reply_tx, reply_rx) = mpsc::channel();
        if self
            .tx
            .lock()
            .unwrap()
            .send(Request::Stats { reply: reply_tx })
            .is_err()
        {
            return EngineStats::default();
        }
        reply_rx.recv().unwrap_or_default()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// True when the manifest has an artifact of this exact name.
    pub fn has(&self, artifact: &str) -> bool {
        self.manifest.get(artifact).is_some()
    }
}

impl PjrtRuntime {
    /// Start the engine on the artifacts directory. Fails fast if the
    /// manifest is missing (run `make artifacts`).
    pub fn start(dir: impl AsRef<Path>) -> anyhow::Result<PjrtRuntime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = std::sync::Arc::new(Manifest::load(&dir)?);
        let (tx, rx) = mpsc::channel::<Request>();
        let m2 = std::sync::Arc::clone(&manifest);
        let thread = std::thread::Builder::new()
            .name("slec-pjrt".into())
            .spawn(move || engine_main(dir, m2, rx))?;
        Ok(PjrtRuntime {
            handle: PjrtHandleSync {
                tx: std::sync::Arc::new(std::sync::Mutex::new(tx)),
                manifest,
            },
            thread: Some(thread),
        })
    }

    /// Default artifacts directory: `$SLEC_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        crate::runtime::default_artifacts_dir()
    }

    pub fn handle(&self) -> PjrtHandleSync {
        self.handle.clone()
    }
}

impl Drop for PjrtRuntime {
    fn drop(&mut self) {
        let _ = self.handle.tx.lock().unwrap().send(Request::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn engine_main(dir: PathBuf, manifest: std::sync::Arc<Manifest>, rx: mpsc::Receiver<Request>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("[slec-pjrt] failed to create PJRT CPU client: {e}");
            // Drain requests with errors so callers don't hang.
            for req in rx {
                match req {
                    Request::Execute { reply, .. } => {
                        let _ = reply.send(Err(anyhow::anyhow!("no PJRT client")));
                    }
                    Request::Stats { reply } => {
                        let _ = reply.send(EngineStats::default());
                    }
                    Request::Shutdown => break,
                }
            }
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    let mut stats = EngineStats::default();

    for req in rx {
        match req {
            Request::Shutdown => break,
            Request::Stats { reply } => {
                let _ = reply.send(stats.clone());
            }
            Request::Execute {
                artifact,
                inputs,
                reply,
            } => {
                let result =
                    execute_one(&client, &dir, &manifest, &mut cache, &mut stats, &artifact, inputs);
                if result.is_err() {
                    stats.errors += 1;
                }
                let _ = reply.send(result);
            }
        }
    }
}

fn execute_one(
    client: &xla::PjRtClient,
    dir: &Path,
    manifest: &Manifest,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    stats: &mut EngineStats,
    artifact: &str,
    inputs: Vec<Tensor>,
) -> anyhow::Result<Vec<Tensor>> {
    let info = manifest
        .get(artifact)
        .ok_or_else(|| anyhow::anyhow!("artifact '{artifact}' not in manifest"))?;
    anyhow::ensure!(
        inputs.len() == info.inputs.len(),
        "artifact '{artifact}' wants {} inputs, got {}",
        info.inputs.len(),
        inputs.len()
    );
    for (i, (t, want)) in inputs.iter().zip(&info.inputs).enumerate() {
        let got: Vec<i64> = t.dims.clone();
        anyhow::ensure!(
            got == *want,
            "artifact '{artifact}' input {i}: shape {got:?} != manifest {want:?}"
        );
    }

    if !cache.contains_key(artifact) {
        let path = dir.join(&info.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {artifact}: {e}"))?;
        stats.compiles += 1;
        cache.insert(artifact.to_string(), exe);
    }
    let exe = cache.get(artifact).unwrap();

    let literals: Vec<xla::Literal> = inputs
        .iter()
        .map(|t| {
            xla::Literal::vec1(&t.data)
                .reshape(&t.dims)
                .map_err(|e| anyhow::anyhow!("reshaping input to {:?}: {e}", t.dims))
        })
        .collect::<anyhow::Result<Vec<_>>>()?;

    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| anyhow::anyhow!("executing {artifact}: {e}"))?;
    stats.executions += 1;
    let tuple = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("fetching result of {artifact}: {e}"))?;
    // aot.py lowers with return_tuple=True: unpack N outputs.
    let parts = tuple
        .to_tuple()
        .map_err(|e| anyhow::anyhow!("untupling result of {artifact}: {e}"))?;
    anyhow::ensure!(
        parts.len() == info.outputs.len(),
        "artifact '{artifact}': {} outputs vs manifest {}",
        parts.len(),
        info.outputs.len()
    );
    let mut out = Vec::with_capacity(parts.len());
    for (lit, dims) in parts.into_iter().zip(&info.outputs) {
        let data = lit
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("reading result of {artifact}: {e}"))?;
        out.push(Tensor {
            data,
            dims: dims.clone(),
        });
    }
    Ok(out)
}

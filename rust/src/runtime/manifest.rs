//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Parsed from `artifacts/manifest.json`.

use std::collections::HashMap;
use std::path::Path;

use crate::util::json::{self, Json};

/// One AOT-compiled artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    /// Input shapes in call order.
    pub inputs: Vec<Vec<i64>>,
    /// Output shapes in tuple order.
    pub outputs: Vec<Vec<i64>>,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    by_name: HashMap<String, ArtifactInfo>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        anyhow::ensure!(
            path.exists(),
            "no manifest at {} — run `make artifacts` first",
            path.display()
        );
        let root = json::load_file(&path)?;
        Self::from_json(&root)
    }

    pub fn from_json(root: &Json) -> anyhow::Result<Manifest> {
        let format = root
            .get("format")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'format'"))?;
        anyhow::ensure!(format == "hlo-text", "unsupported manifest format '{format}'");
        let arts = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'artifacts'"))?;
        let mut by_name = HashMap::new();
        for a in arts {
            let info = parse_artifact(a)?;
            anyhow::ensure!(
                by_name.insert(info.name.clone(), info.clone()).is_none(),
                "duplicate artifact '{}'",
                info.name
            );
        }
        Ok(Manifest { by_name })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactInfo> {
        self.by_name.get(name)
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    /// Sorted artifact names (stable listing for `slec inspect-artifacts`).
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.by_name.keys().map(String::as_str).collect();
        v.sort();
        v
    }
}

fn parse_artifact(a: &Json) -> anyhow::Result<ArtifactInfo> {
    let name = a
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("artifact missing 'name'"))?
        .to_string();
    let file = a
        .get("file")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("artifact '{name}' missing 'file'"))?
        .to_string();
    let shapes = |key: &str| -> anyhow::Result<Vec<Vec<i64>>> {
        a.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' missing '{key}'"))?
            .iter()
            .map(|entry| {
                entry
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow::anyhow!("artifact '{name}': bad '{key}' entry"))?
                    .iter()
                    .map(|d| {
                        d.as_u64()
                            .map(|x| x as i64)
                            .ok_or_else(|| anyhow::anyhow!("artifact '{name}': bad dim"))
                    })
                    .collect()
            })
            .collect()
    };
    Ok(ArtifactInfo {
        inputs: shapes("inputs")?,
        outputs: shapes("outputs")?,
        name,
        file,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "artifacts": [
        {"name": "matmul_bt_8x16x8", "file": "matmul_bt_8x16x8.hlo.txt",
         "inputs": [{"shape": [8,16], "dtype": "float32"},
                    {"shape": [8,16], "dtype": "float32"}],
         "outputs": [{"shape": [8,8], "dtype": "float32"}]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let root = crate::util::json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&root).unwrap();
        assert_eq!(m.len(), 1);
        let a = m.get("matmul_bt_8x16x8").unwrap();
        assert_eq!(a.inputs, vec![vec![8, 16], vec![8, 16]]);
        assert_eq!(a.outputs, vec![vec![8, 8]]);
        assert_eq!(m.names(), vec!["matmul_bt_8x16x8"]);
        assert!(m.get("other").is_none());
    }

    #[test]
    fn rejects_bad_format() {
        let root = crate::util::json::parse(r#"{"format": "proto", "artifacts": []}"#).unwrap();
        assert!(Manifest::from_json(&root).is_err());
    }

    #[test]
    fn rejects_duplicates() {
        let dup = SAMPLE.replace(
            "]\n    }",
            &format!(
                ", {}]\n    }}",
                r#"{"name": "matmul_bt_8x16x8", "file": "x", "inputs": [], "outputs": []}"#
            ),
        );
        let root = crate::util::json::parse(&dup).unwrap();
        assert!(Manifest::from_json(&root).is_err());
    }

    #[test]
    fn load_real_manifest_if_present() {
        // When `make artifacts` has run, the real manifest must parse.
        let dir = std::path::Path::new("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(m.len() >= 10, "expected the default artifact set");
            assert!(m.get("matmul_bt_64x256x64").is_some());
        }
    }
}

//! Manifests: the shape/location contracts between producers and
//! consumers of named binary blobs.
//!
//! Two live here:
//! - [`Manifest`] — the AOT-artifact contract between
//!   `python/compile/aot.py` and the PJRT runtime, parsed from
//!   `artifacts/manifest.json`.
//! - [`JobManifest`] — the per-job block index a coordinator stages into
//!   the object store (`<job_id>/manifest`) so stateless workers can
//!   locate a job's coded inputs, block-products and decoded results
//!   from the job id alone (the paper's Fig-2 dataflow, where S3 is the
//!   only rendezvous).

use std::collections::HashMap;
use std::path::Path;

use crate::storage::ObjectStore;
use crate::util::json::{self, obj, Json};

/// One AOT-compiled artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    /// Input shapes in call order.
    pub inputs: Vec<Vec<i64>>,
    /// Output shapes in tuple order.
    pub outputs: Vec<Vec<i64>>,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    by_name: HashMap<String, ArtifactInfo>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        anyhow::ensure!(
            path.exists(),
            "no manifest at {} — run `make artifacts` first",
            path.display()
        );
        let root = json::load_file(&path)?;
        Self::from_json(&root)
    }

    pub fn from_json(root: &Json) -> anyhow::Result<Manifest> {
        let format = root
            .get("format")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'format'"))?;
        anyhow::ensure!(format == "hlo-text", "unsupported manifest format '{format}'");
        let arts = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'artifacts'"))?;
        let mut by_name = HashMap::new();
        for a in arts {
            let info = parse_artifact(a)?;
            anyhow::ensure!(
                by_name.insert(info.name.clone(), info.clone()).is_none(),
                "duplicate artifact '{}'",
                info.name
            );
        }
        Ok(Manifest { by_name })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactInfo> {
        self.by_name.get(name)
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    /// Sorted artifact names (stable listing for `slec inspect-artifacts`).
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.by_name.keys().map(String::as_str).collect();
        v.sort();
        v
    }
}

fn parse_artifact(a: &Json) -> anyhow::Result<ArtifactInfo> {
    let name = a
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("artifact missing 'name'"))?
        .to_string();
    let file = a
        .get("file")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("artifact '{name}' missing 'file'"))?
        .to_string();
    let shapes = |key: &str| -> anyhow::Result<Vec<Vec<i64>>> {
        a.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' missing '{key}'"))?
            .iter()
            .map(|entry| {
                entry
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow::anyhow!("artifact '{name}': bad '{key}' entry"))?
                    .iter()
                    .map(|d| {
                        d.as_u64()
                            .map(|x| x as i64)
                            .ok_or_else(|| anyhow::anyhow!("artifact '{name}': bad dim"))
                    })
                    .collect()
            })
            .collect()
    };
    Ok(ArtifactInfo {
        inputs: shapes("inputs")?,
        outputs: shapes("outputs")?,
        name,
        file,
    })
}

// ---------------------------------------------------------------------------
// Per-job block manifests (object-store contract)
// ---------------------------------------------------------------------------

/// One staged block: its store key, matrix shape, and wire size
/// (`Matrix::to_bytes`: 16-byte header + 4 bytes per f32 entry).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobBlockInfo {
    pub key: String,
    pub rows: usize,
    pub cols: usize,
    pub bytes: u64,
}

/// Index of every block a job staged in the object store.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JobManifest {
    pub job_id: String,
    blocks: Vec<JobBlockInfo>,
}

impl JobManifest {
    pub fn new(job_id: &str) -> JobManifest {
        JobManifest {
            job_id: job_id.to_string(),
            blocks: Vec::new(),
        }
    }

    /// Store key the manifest itself lives under.
    pub fn store_key(job_id: &str) -> String {
        format!("{job_id}/manifest")
    }

    /// Record one staged matrix block.
    pub fn push(&mut self, key: impl Into<String>, rows: usize, cols: usize) {
        self.blocks.push(JobBlockInfo {
            key: key.into(),
            rows,
            cols,
            bytes: 16 + (rows * cols * 4) as u64,
        });
    }

    pub fn blocks(&self) -> &[JobBlockInfo] {
        &self.blocks
    }

    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// Wire bytes of everything listed (the job's storage footprint).
    pub fn total_bytes(&self) -> u64 {
        self.blocks.iter().map(|b| b.bytes).sum()
    }

    /// The entry for a key, if staged.
    pub fn get(&self, key: &str) -> Option<&JobBlockInfo> {
        self.blocks.iter().find(|b| b.key == key)
    }

    pub fn to_json(&self) -> Json {
        let blocks: Vec<Json> = self
            .blocks
            .iter()
            .map(|b| {
                obj()
                    .field("key", b.key.as_str())
                    .field("rows", b.rows)
                    .field("cols", b.cols)
                    .field("bytes", b.bytes)
                    .build()
            })
            .collect();
        obj()
            .field("format", "job-blocks")
            .field("job_id", self.job_id.as_str())
            .field("blocks", Json::Arr(blocks))
            .build()
    }

    pub fn from_json(root: &Json) -> anyhow::Result<JobManifest> {
        let format = root
            .get("format")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("job manifest missing 'format'"))?;
        anyhow::ensure!(
            format == "job-blocks",
            "unsupported job-manifest format '{format}'"
        );
        let job_id = root
            .get("job_id")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("job manifest missing 'job_id'"))?
            .to_string();
        let mut m = JobManifest {
            job_id,
            blocks: Vec::new(),
        };
        for b in root
            .get("blocks")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("job manifest missing 'blocks'"))?
        {
            let key = b
                .get("key")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("job-manifest block missing 'key'"))?;
            let dim = |k: &str| -> anyhow::Result<usize> {
                b.get(k)
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow::anyhow!("block '{key}' missing '{k}'"))
            };
            m.push(key, dim("rows")?, dim("cols")?);
        }
        Ok(m)
    }

    /// Serialize into the store under [`JobManifest::store_key`].
    pub fn save(&self, store: &dyn ObjectStore) {
        store.put(
            &Self::store_key(&self.job_id),
            self.to_json().to_string_pretty().into_bytes(),
        );
    }

    /// Fetch + parse a job's manifest from the store.
    pub fn load(store: &dyn ObjectStore, job_id: &str) -> anyhow::Result<JobManifest> {
        let blob = store
            .get(&Self::store_key(job_id))
            .ok_or_else(|| anyhow::anyhow!("no manifest staged for job '{job_id}'"))?;
        let text = std::str::from_utf8(&blob)
            .map_err(|e| anyhow::anyhow!("job '{job_id}' manifest is not UTF-8: {e}"))?;
        let root = json::parse(text)
            .map_err(|e| anyhow::anyhow!("job '{job_id}' manifest: {e}"))?;
        let m = Self::from_json(&root)?;
        anyhow::ensure!(
            m.job_id == job_id,
            "manifest under '{}' names job '{}'",
            Self::store_key(job_id),
            m.job_id
        );
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "artifacts": [
        {"name": "matmul_bt_8x16x8", "file": "matmul_bt_8x16x8.hlo.txt",
         "inputs": [{"shape": [8,16], "dtype": "float32"},
                    {"shape": [8,16], "dtype": "float32"}],
         "outputs": [{"shape": [8,8], "dtype": "float32"}]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let root = crate::util::json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&root).unwrap();
        assert_eq!(m.len(), 1);
        let a = m.get("matmul_bt_8x16x8").unwrap();
        assert_eq!(a.inputs, vec![vec![8, 16], vec![8, 16]]);
        assert_eq!(a.outputs, vec![vec![8, 8]]);
        assert_eq!(m.names(), vec!["matmul_bt_8x16x8"]);
        assert!(m.get("other").is_none());
    }

    #[test]
    fn rejects_bad_format() {
        let root = crate::util::json::parse(r#"{"format": "proto", "artifacts": []}"#).unwrap();
        assert!(Manifest::from_json(&root).is_err());
    }

    #[test]
    fn rejects_duplicates() {
        let dup = SAMPLE.replace(
            "]\n    }",
            &format!(
                ", {}]\n    }}",
                r#"{"name": "matmul_bt_8x16x8", "file": "x", "inputs": [], "outputs": []}"#
            ),
        );
        let root = crate::util::json::parse(&dup).unwrap();
        assert!(Manifest::from_json(&root).is_err());
    }

    #[test]
    fn job_manifest_roundtrips_through_the_store() {
        use crate::storage::MemStore;
        let mut m = JobManifest::new("j7");
        m.push("j7/coded/a/00000", 16, 64);
        m.push("j7/out/00000x00001", 16, 16);
        assert_eq!(m.len(), 2);
        assert_eq!(m.total_bytes(), (16 + 16 * 64 * 4) + (16 + 16 * 16 * 4));
        assert_eq!(m.get("j7/out/00000x00001").unwrap().rows, 16);
        assert!(m.get("absent").is_none());

        let store = MemStore::new();
        m.save(&store);
        assert_eq!(JobManifest::store_key("j7"), "j7/manifest");
        let back = JobManifest::load(&store, "j7").unwrap();
        assert_eq!(back, m);
        assert!(JobManifest::load(&store, "other").is_err());
    }

    #[test]
    fn job_manifest_rejects_malformed_documents() {
        let bad = [
            r#"{"job_id": "j", "blocks": []}"#,
            r#"{"format": "job-blocks", "blocks": []}"#,
            r#"{"format": "job-blocks", "job_id": "j"}"#,
            r#"{"format": "hlo-text", "job_id": "j", "blocks": []}"#,
            r#"{"format": "job-blocks", "job_id": "j", "blocks": [{"rows": 1, "cols": 1}]}"#,
        ];
        for src in bad {
            let root = crate::util::json::parse(src).unwrap();
            assert!(JobManifest::from_json(&root).is_err(), "{src}");
        }
    }

    #[test]
    fn load_real_manifest_if_present() {
        // When `make artifacts` has run, the real manifest must parse.
        let dir = std::path::Path::new("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(m.len() >= 10, "expected the default artifact set");
            assert!(m.get("matmul_bt_64x256x64").is_some());
        }
    }
}

//! Legacy phase API — a thin facade over the discrete-event core.
//!
//! A *phase* launches `n` stateless workers; each worker's virtual
//! duration is sampled from the [`super::straggler::StragglerModel`]. The
//! coordinator then applies a termination rule:
//!
//! - **wait-all** (uncoded): the phase ends at the slowest worker,
//! - **wait-k**: k-th order statistic (coded schemes with a recovery
//!   threshold),
//! - **speculative execution**: at the `wait_frac` completion time,
//!   relaunch every unfinished task on a fresh worker; a task completes
//!   at min(original, relaunch) — the paper's baseline (§I),
//! - **earliest-decodable**: the first virtual time at which the set of
//!   arrived results satisfies an arbitrary decodability predicate — the
//!   coded schemes' termination (§II-B).
//!
//! Since the event-core refactor every function here executes on an
//! **unbounded-pool [`EventSim`]** ([`super::event`]); in that regime the
//! event queue reproduces the historical order-statistics values bit for
//! bit (tasks start at submission, so completion time = sampled
//! duration), which keeps the old seeding contract intact. Callers that
//! need worker reuse, bounded pools or multi-job contention should use
//! [`super::event`] / [`super::scenario`] directly.
//!
//! Real numerics are computed separately by the coordinator; this module
//! is purely about *when* things happen on the simulated platform.
//!
//! **Deprecated**: every function here is a frozen compatibility shim.
//! New code should drive [`super::event`] (`PhaseState` +
//! `run_phase`) directly — it has the same determinism contract plus
//! bounded pools, worker reuse and multi-job contention. The facade
//! stays (with its n=0 regression tests) until external callers move.

use crate::platform::event::{run_phase, EventSim, PhaseState, Termination};
use crate::platform::straggler::{StragglerModel, WorkProfile};
use crate::util::rng::Pcg64;

/// Sampled phase: per-task virtual finish times (relative to phase start).
#[derive(Debug, Clone)]
pub struct Phase {
    pub finish: Vec<f64>,
    pub straggled: Vec<bool>,
}

/// Launch `n` tasks with the same work profile.
#[deprecated(since = "0.1.0", note = "drive platform::event (PhaseState + run_phase) directly")]
#[allow(deprecated)] // shims call shims
pub fn launch(model: &StragglerModel, work: &WorkProfile, n: usize, rng: &mut Pcg64) -> Phase {
    launch_tasks(model, &vec![*work; n], rng)
}

/// Launch tasks with heterogeneous profiles.
#[deprecated(since = "0.1.0", note = "drive platform::event (PhaseState + run_phase) directly")]
pub fn launch_tasks(model: &StragglerModel, works: &[WorkProfile], rng: &mut Pcg64) -> Phase {
    let mut sim = EventSim::unbounded();
    let mut ph = PhaseState::launch(&mut sim, model, works, 0, Termination::WaitAll, rng);
    run_phase(&mut sim, &mut ph, model, rng, &mut |_, _| false);
    Phase {
        finish: ph.completion_times(),
        straggled: ph.straggled_mask(),
    }
}

impl Phase {
    pub fn n(&self) -> usize {
        self.finish.len()
    }

    /// Wait-for-all makespan (0 for an empty phase).
    pub fn wait_all(&self) -> f64 {
        self.finish.iter().copied().fold(0.0, f64::max)
    }

    /// Time at which the k-th task (1-based) completes. `k = n` equals
    /// [`Phase::wait_all`].
    pub fn wait_k(&self, k: usize) -> f64 {
        assert!(k >= 1 && k <= self.n());
        let mut sorted = self.finish.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        sorted[k - 1]
    }

    /// Completion order: task indices sorted by finish time.
    pub fn arrival_order(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.n()).collect();
        idx.sort_by(|&a, &b| self.finish[a].partial_cmp(&self.finish[b]).unwrap());
        idx
    }
}

/// Outcome of a phase run under speculative execution.
#[derive(Debug, Clone)]
pub struct SpeculativeOutcome {
    /// Final per-task completion time (min of original and relaunch).
    pub completion: Vec<f64>,
    /// Phase makespan (all tasks complete).
    pub makespan: f64,
    /// Virtual time at which relaunch was triggered.
    pub trigger_time: f64,
    /// Number of tasks relaunched.
    pub relaunched: usize,
}

/// The paper's speculative-execution baseline: wait until `wait_frac` of
/// tasks have finished, then resubmit every unfinished task on a fresh
/// worker *without killing the original* — "the worker that finishes
/// first submits its results" (§I). An empty phase completes at once.
#[deprecated(since = "0.1.0", note = "drive platform::event (PhaseState + run_phase) directly")]
pub fn speculative(
    model: &StragglerModel,
    work: &WorkProfile,
    phase: &Phase,
    wait_frac: f64,
    rng: &mut Pcg64,
) -> SpeculativeOutcome {
    let n = phase.n();
    if n == 0 {
        return SpeculativeOutcome {
            completion: Vec::new(),
            makespan: 0.0,
            trigger_time: 0.0,
            relaunched: 0,
        };
    }
    let mut sim = EventSim::unbounded();
    let mut ph = PhaseState::from_durations(
        &mut sim,
        &phase.finish,
        &phase.straggled,
        vec![*work; n],
        0,
        Termination::Speculative { wait_frac },
    );
    run_phase(&mut sim, &mut ph, model, rng, &mut |_, _| false);
    SpeculativeOutcome {
        completion: ph.completion_times(),
        makespan: ph.duration(),
        trigger_time: ph.trigger_time,
        relaunched: ph.relaunched,
    }
}

/// Earliest-decodable termination: replay completions through the event
/// queue and stop at the first time `decodable(&arrived)` is true.
///
/// Returns `(stop_time, arrived_mask)`. If the predicate never fires, the
/// phase degenerates to wait-all with every task arrived; a phase that is
/// decodable with nothing stops at time 0.
#[deprecated(since = "0.1.0", note = "drive platform::event (PhaseState + run_phase) directly")]
pub fn earliest_decodable(
    phase: &Phase,
    mut decodable: impl FnMut(&[bool]) -> bool,
) -> (f64, Vec<bool>) {
    let n = phase.n();
    let mut sim = EventSim::unbounded();
    let mut ph = PhaseState::from_durations(
        &mut sim,
        &phase.finish,
        &phase.straggled,
        vec![WorkProfile::default(); n],
        0,
        Termination::EarliestDecodable,
    );
    // No relaunches happen under earliest-decodable, so the model/rng fed
    // to the driver are never consulted; use fixed ones to keep the
    // signature unchanged. The legacy predicate ignores the incremental
    // newly-arrived hint.
    let model = StragglerModel::new(Default::default(), Default::default());
    let mut rng = Pcg64::new(0);
    let mut wrapped = |mask: &[bool], _newly: Option<usize>| decodable(mask);
    run_phase(&mut sim, &mut ph, &model, &mut rng, &mut wrapped);
    (ph.end_time(), ph.arrived_mask())
}

/// Recompute stragglers: launch replacement tasks for `missing` at
/// `start_time`; returns the time all replacements are done.
#[deprecated(since = "0.1.0", note = "drive platform::event (PhaseState + run_phase) directly")]
#[allow(deprecated)] // shims call shims
pub fn recompute_round(
    model: &StragglerModel,
    work: &WorkProfile,
    missing: usize,
    start_time: f64,
    rng: &mut Pcg64,
) -> f64 {
    if missing == 0 {
        return start_time;
    }
    let replacements = launch(model, work, missing, rng);
    start_time + replacements.wait_all()
}

#[cfg(test)]
#[allow(deprecated)] // the facade keeps its own regression tests
mod tests {
    use super::*;
    use crate::platform::straggler::{StragglerParams, WorkerRates};

    fn model() -> StragglerModel {
        StragglerModel::new(StragglerParams::default(), WorkerRates::default())
    }

    fn work() -> WorkProfile {
        WorkProfile::block_product(512, 2048, 512)
    }

    #[test]
    fn order_statistics_consistent() {
        let mut rng = Pcg64::new(1);
        let phase = launch(&model(), &work(), 200, &mut rng);
        assert_eq!(phase.n(), 200);
        assert!((phase.wait_k(200) - phase.wait_all()).abs() < 1e-12);
        assert!(phase.wait_k(1) <= phase.wait_k(100));
        assert!(phase.wait_k(100) <= phase.wait_k(200));
        // Arrival order is sorted by finish time.
        let order = phase.arrival_order();
        for w in order.windows(2) {
            assert!(phase.finish[w[0]] <= phase.finish[w[1]]);
        }
    }

    #[test]
    fn launch_matches_direct_sampling() {
        // The event-core facade must reproduce the historical
        // order-statistics model exactly: completion = sampled duration.
        let m = model();
        let w = work();
        let mut r1 = Pcg64::new(21);
        let mut r2 = Pcg64::new(21);
        let phase = launch(&m, &w, 64, &mut r1);
        let direct = m.sample_fleet(&w, 64, &mut r2);
        assert_eq!(phase.finish, direct);
    }

    #[test]
    fn speculative_never_slower_than_uncoded_much() {
        // With stragglers present, speculative should usually beat
        // wait-all; it can never beat the trigger time.
        let mut rng = Pcg64::new(2);
        let mut spec_wins = 0;
        let trials = 40;
        for _ in 0..trials {
            let phase = launch(&model(), &work(), 300, &mut rng);
            let out = speculative(&model(), &work(), &phase, 0.9, &mut rng);
            assert!(out.makespan >= out.trigger_time);
            for (i, &c) in out.completion.iter().enumerate() {
                assert!(c <= phase.finish[i] + 1e-12);
            }
            if out.makespan < phase.wait_all() - 1e-9 {
                spec_wins += 1;
            }
        }
        assert!(spec_wins > trials / 2, "spec wins only {spec_wins}/{trials}");
    }

    #[test]
    fn speculative_relaunches_exactly_unfinished() {
        let mut rng = Pcg64::new(3);
        let phase = Phase {
            finish: vec![1.0, 2.0, 3.0, 10.0, 20.0],
            straggled: vec![false, false, false, true, true],
        };
        let out = speculative(&model(), &work(), &phase, 0.6, &mut rng);
        assert!((out.trigger_time - 3.0).abs() < 1e-12);
        assert_eq!(out.relaunched, 2);
    }

    // --- termination-rule edge cases -----------------------------------

    #[test]
    fn empty_phase_launch_does_not_panic() {
        let mut rng = Pcg64::new(30);
        let phase = launch(&model(), &work(), 0, &mut rng);
        assert_eq!(phase.n(), 0);
        assert_eq!(phase.wait_all(), 0.0);
        assert!(phase.arrival_order().is_empty());
        // Speculative over an empty phase is a no-op, not a panic.
        for frac in [0.0, 0.5, 1.0] {
            let out = speculative(&model(), &work(), &phase, frac, &mut rng);
            assert_eq!(out.makespan, 0.0);
            assert_eq!(out.relaunched, 0);
            assert!(out.completion.is_empty());
        }
        // Earliest-decodable over an empty phase consults the predicate
        // once on the empty mask.
        let (t, arrived) = earliest_decodable(&phase, |_| true);
        assert_eq!(t, 0.0);
        assert!(arrived.is_empty());
        let (t, arrived) = earliest_decodable(&phase, |_| false);
        assert_eq!(t, 0.0);
        assert!(arrived.is_empty());
    }

    #[test]
    fn speculative_wait_frac_zero_triggers_at_first_completion() {
        let mut rng = Pcg64::new(31);
        let phase = Phase {
            finish: vec![4.0, 1.0, 9.0],
            straggled: vec![false; 3],
        };
        let out = speculative(&model(), &work(), &phase, 0.0, &mut rng);
        // k clamps to 1: trigger at the fastest task, relaunch the rest.
        assert!((out.trigger_time - 1.0).abs() < 1e-12);
        assert_eq!(out.relaunched, 2);
        assert!(out.makespan >= out.trigger_time);
        for (i, &c) in out.completion.iter().enumerate() {
            assert!(c <= phase.finish[i] + 1e-12);
        }
    }

    #[test]
    fn speculative_wait_frac_one_never_relaunches() {
        let mut rng = Pcg64::new(32);
        let phase = launch(&model(), &work(), 50, &mut rng);
        let out = speculative(&model(), &work(), &phase, 1.0, &mut rng);
        // k = n: the trigger is the last completion; nothing is unfinished.
        assert_eq!(out.relaunched, 0);
        assert!((out.trigger_time - phase.wait_all()).abs() < 1e-12);
        assert!((out.makespan - phase.wait_all()).abs() < 1e-12);
        assert_eq!(out.completion, phase.finish);
    }

    #[test]
    fn wait_k_with_k_equal_n_is_wait_all() {
        let mut rng = Pcg64::new(33);
        for n in [1usize, 7, 40] {
            let phase = launch(&model(), &work(), n, &mut rng);
            assert_eq!(phase.wait_k(n), phase.wait_all());
        }
    }

    // --- earliest-decodable ---------------------------------------------

    #[test]
    fn earliest_decodable_waits_for_threshold() {
        let phase = Phase {
            finish: vec![5.0, 1.0, 3.0, 9.0],
            straggled: vec![false; 4],
        };
        // Decodable once any 2 arrived.
        let (t, arrived) =
            earliest_decodable(&phase, |a| a.iter().filter(|&&x| x).count() >= 2);
        assert!((t - 3.0).abs() < 1e-12);
        assert_eq!(arrived.iter().filter(|&&x| x).count(), 2);
        assert!(arrived[1] && arrived[2]);
    }

    #[test]
    fn earliest_decodable_never_fires_degenerates_to_wait_all() {
        let phase = Phase {
            finish: vec![2.0, 4.0],
            straggled: vec![false; 2],
        };
        let (t, arrived) = earliest_decodable(&phase, |_| false);
        assert!((t - 4.0).abs() < 1e-12);
        assert!(arrived.iter().all(|&x| x));
    }

    #[test]
    fn earliest_decodable_zero_requirement() {
        let phase = Phase {
            finish: vec![2.0],
            straggled: vec![false],
        };
        let (t, arrived) = earliest_decodable(&phase, |_| true);
        assert_eq!(t, 0.0);
        assert!(!arrived[0]);
    }

    #[test]
    fn recompute_round_advances_time() {
        let mut rng = Pcg64::new(4);
        let t = recompute_round(&model(), &work(), 3, 100.0, &mut rng);
        assert!(t > 100.0);
        assert_eq!(recompute_round(&model(), &work(), 0, 50.0, &mut rng), 50.0);
    }

    #[test]
    fn heterogeneous_launch() {
        let mut rng = Pcg64::new(5);
        let works = vec![
            WorkProfile::block_product(64, 64, 64),
            WorkProfile::block_product(2048, 8192, 2048),
        ];
        let phase = launch_tasks(&model(), &works, &mut rng);
        // The big task should essentially always dominate.
        assert!(phase.finish[1] > phase.finish[0]);
    }
}

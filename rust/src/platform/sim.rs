//! Virtual-time phase simulation: order statistics + termination rules.
//!
//! A *phase* launches `n` stateless workers; each worker's virtual
//! duration is sampled from the [`super::straggler::StragglerModel`]. The
//! coordinator then applies a termination rule:
//!
//! - **wait-all** (uncoded): the phase ends at the slowest worker,
//! - **wait-k**: k-th order statistic (coded schemes with a recovery
//!   threshold),
//! - **speculative execution**: at the `wait_frac` completion time,
//!   relaunch every unfinished task on a fresh worker; a task completes
//!   at min(original, relaunch) — the paper's baseline (§I),
//! - **earliest-decodable**: the first virtual time at which the set of
//!   arrived results satisfies an arbitrary decodability predicate — the
//!   coded schemes' termination (§II-B).
//!
//! Real numerics are computed separately by the coordinator; this module
//! is purely about *when* things happen on the simulated platform.

use crate::platform::straggler::{StragglerModel, WorkProfile};
use crate::util::rng::Pcg64;

/// Sampled phase: per-task virtual finish times (relative to phase start).
#[derive(Debug, Clone)]
pub struct Phase {
    pub finish: Vec<f64>,
    pub straggled: Vec<bool>,
}

/// Launch `n` tasks with the same work profile.
pub fn launch(model: &StragglerModel, work: &WorkProfile, n: usize, rng: &mut Pcg64) -> Phase {
    let mut finish = Vec::with_capacity(n);
    let mut straggled = Vec::with_capacity(n);
    for _ in 0..n {
        let s = model.sample(work, rng);
        finish.push(s.total());
        straggled.push(s.straggled);
    }
    Phase { finish, straggled }
}

/// Launch tasks with heterogeneous profiles.
pub fn launch_tasks(
    model: &StragglerModel,
    works: &[WorkProfile],
    rng: &mut Pcg64,
) -> Phase {
    let mut finish = Vec::with_capacity(works.len());
    let mut straggled = Vec::with_capacity(works.len());
    for w in works {
        let s = model.sample(w, rng);
        finish.push(s.total());
        straggled.push(s.straggled);
    }
    Phase { finish, straggled }
}

impl Phase {
    pub fn n(&self) -> usize {
        self.finish.len()
    }

    /// Wait-for-all makespan.
    pub fn wait_all(&self) -> f64 {
        self.finish.iter().copied().fold(0.0, f64::max)
    }

    /// Time at which the k-th task (1-based) completes.
    pub fn wait_k(&self, k: usize) -> f64 {
        assert!(k >= 1 && k <= self.n());
        let mut sorted = self.finish.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        sorted[k - 1]
    }

    /// Completion order: task indices sorted by finish time.
    pub fn arrival_order(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.n()).collect();
        idx.sort_by(|&a, &b| self.finish[a].partial_cmp(&self.finish[b]).unwrap());
        idx
    }
}

/// Outcome of a phase run under speculative execution.
#[derive(Debug, Clone)]
pub struct SpeculativeOutcome {
    /// Final per-task completion time (min of original and relaunch).
    pub completion: Vec<f64>,
    /// Phase makespan (all tasks complete).
    pub makespan: f64,
    /// Virtual time at which relaunch was triggered.
    pub trigger_time: f64,
    /// Number of tasks relaunched.
    pub relaunched: usize,
}

/// The paper's speculative-execution baseline: wait until `wait_frac` of
/// tasks have finished, then resubmit every unfinished task on a fresh
/// worker *without killing the original* — "the worker that finishes
/// first submits its results" (§I).
pub fn speculative(
    model: &StragglerModel,
    work: &WorkProfile,
    phase: &Phase,
    wait_frac: f64,
    rng: &mut Pcg64,
) -> SpeculativeOutcome {
    let n = phase.n();
    let k = ((n as f64 * wait_frac).ceil() as usize).clamp(1, n);
    let trigger_time = phase.wait_k(k);
    let mut completion = phase.finish.clone();
    let mut relaunched = 0;
    for c in completion.iter_mut() {
        if *c > trigger_time {
            relaunched += 1;
            let fresh = model.sample(work, rng).total();
            *c = (*c).min(trigger_time + fresh);
        }
    }
    let makespan = completion.iter().copied().fold(0.0, f64::max);
    SpeculativeOutcome {
        completion,
        makespan,
        trigger_time,
        relaunched,
    }
}

/// Earliest-decodable termination: walk completions in arrival order and
/// stop at the first time `decodable(&arrived)` is true.
///
/// Returns `(stop_time, arrived_mask)`. If the predicate never fires, the
/// phase degenerates to wait-all with every task arrived.
pub fn earliest_decodable(
    phase: &Phase,
    mut decodable: impl FnMut(&[bool]) -> bool,
) -> (f64, Vec<bool>) {
    let mut arrived = vec![false; phase.n()];
    // Cheap early exit: some schemes are decodable with nothing (n = 0).
    if decodable(&arrived) {
        return (0.0, arrived);
    }
    for &i in &phase.arrival_order() {
        arrived[i] = true;
        if decodable(&arrived) {
            return (phase.finish[i], arrived);
        }
    }
    (phase.wait_all(), arrived)
}

/// Recompute stragglers: launch replacement tasks for `missing` at
/// `start_time`; returns the time all replacements are done.
pub fn recompute_round(
    model: &StragglerModel,
    work: &WorkProfile,
    missing: usize,
    start_time: f64,
    rng: &mut Pcg64,
) -> f64 {
    if missing == 0 {
        return start_time;
    }
    let replacements = launch(model, work, missing, rng);
    start_time + replacements.wait_all()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::straggler::{StragglerParams, WorkerRates};

    fn model() -> StragglerModel {
        StragglerModel::new(StragglerParams::default(), WorkerRates::default())
    }

    fn work() -> WorkProfile {
        WorkProfile::block_product(512, 2048, 512)
    }

    #[test]
    fn order_statistics_consistent() {
        let mut rng = Pcg64::new(1);
        let phase = launch(&model(), &work(), 200, &mut rng);
        assert_eq!(phase.n(), 200);
        assert!((phase.wait_k(200) - phase.wait_all()).abs() < 1e-12);
        assert!(phase.wait_k(1) <= phase.wait_k(100));
        assert!(phase.wait_k(100) <= phase.wait_k(200));
        // Arrival order is sorted by finish time.
        let order = phase.arrival_order();
        for w in order.windows(2) {
            assert!(phase.finish[w[0]] <= phase.finish[w[1]]);
        }
    }

    #[test]
    fn speculative_never_slower_than_uncoded_much() {
        // With stragglers present, speculative should usually beat
        // wait-all; it can never beat the trigger time.
        let mut rng = Pcg64::new(2);
        let mut spec_wins = 0;
        let trials = 40;
        for _ in 0..trials {
            let phase = launch(&model(), &work(), 300, &mut rng);
            let out = speculative(&model(), &work(), &phase, 0.9, &mut rng);
            assert!(out.makespan >= out.trigger_time);
            for (i, &c) in out.completion.iter().enumerate() {
                assert!(c <= phase.finish[i] + 1e-12);
            }
            if out.makespan < phase.wait_all() - 1e-9 {
                spec_wins += 1;
            }
        }
        assert!(spec_wins > trials / 2, "spec wins only {spec_wins}/{trials}");
    }

    #[test]
    fn speculative_relaunches_exactly_unfinished() {
        let mut rng = Pcg64::new(3);
        let phase = Phase {
            finish: vec![1.0, 2.0, 3.0, 10.0, 20.0],
            straggled: vec![false, false, false, true, true],
        };
        let out = speculative(&model(), &work(), &phase, 0.6, &mut rng);
        assert!((out.trigger_time - 3.0).abs() < 1e-12);
        assert_eq!(out.relaunched, 2);
    }

    #[test]
    fn earliest_decodable_waits_for_threshold() {
        let phase = Phase {
            finish: vec![5.0, 1.0, 3.0, 9.0],
            straggled: vec![false; 4],
        };
        // Decodable once any 2 arrived.
        let (t, arrived) = earliest_decodable(&phase, |a| {
            a.iter().filter(|&&x| x).count() >= 2
        });
        assert!((t - 3.0).abs() < 1e-12);
        assert_eq!(arrived.iter().filter(|&&x| x).count(), 2);
        assert!(arrived[1] && arrived[2]);
    }

    #[test]
    fn earliest_decodable_never_fires_degenerates_to_wait_all() {
        let phase = Phase {
            finish: vec![2.0, 4.0],
            straggled: vec![false; 2],
        };
        let (t, arrived) = earliest_decodable(&phase, |_| false);
        assert!((t - 4.0).abs() < 1e-12);
        assert!(arrived.iter().all(|&x| x));
    }

    #[test]
    fn earliest_decodable_zero_requirement() {
        let phase = Phase {
            finish: vec![2.0],
            straggled: vec![false],
        };
        let (t, arrived) = earliest_decodable(&phase, |_| true);
        assert_eq!(t, 0.0);
        assert!(!arrived[0]);
    }

    #[test]
    fn recompute_round_advances_time() {
        let mut rng = Pcg64::new(4);
        let t = recompute_round(&model(), &work(), 3, 100.0, &mut rng);
        assert!(t > 100.0);
        assert_eq!(recompute_round(&model(), &work(), 0, 50.0, &mut rng), 50.0);
    }

    #[test]
    fn heterogeneous_launch() {
        let mut rng = Pcg64::new(5);
        let works = vec![
            WorkProfile::block_product(64, 64, 64),
            WorkProfile::block_product(2048, 8192, 2048),
        ];
        let phase = launch_tasks(&model(), &works, &mut rng);
        // The big task should essentially always dominate.
        assert!(phase.finish[1] > phase.finish[0]);
    }
}

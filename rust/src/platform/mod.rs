//! Serverless platform simulator — the AWS Lambda substitute.
//!
//! Two pieces: the straggler model ([`straggler`]) samples per-job virtual
//! durations calibrated to the paper's Fig 1 (median ≈135 s, p ≈ 0.02
//! heavy-tailed stragglers), and the phase simulator ([`sim`]) turns those
//! samples into phase makespans under each scheme's termination rule
//! (wait-all / wait-k / speculative relaunch / earliest-decodable).
//!
//! The simulator manipulates *virtual time only*; the numerics of every
//! task still execute for real (via the PJRT runtime or host kernels), so
//! end-to-end results remain verifiable against the uncoded product.

pub mod sim;
pub mod straggler;

pub use sim::{earliest_decodable, launch, launch_tasks, recompute_round, speculative, Phase};
pub use straggler::{JobSample, StragglerModel, StragglerParams, WorkProfile, WorkerRates};

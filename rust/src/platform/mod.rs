//! Serverless platform simulator — the AWS Lambda substitute.
//!
//! Three pieces: the straggler model ([`straggler`]) samples per-job
//! virtual durations calibrated to the paper's Fig 1 (median ≈135 s,
//! p ≈ 0.02 heavy-tailed stragglers); the discrete-event core ([`event`])
//! runs a virtual-clock event queue over a bounded pool of reusable
//! workers, with the schemes' termination rules (wait-all / wait-k /
//! speculative relaunch / earliest-decodable) as event-driven policies;
//! and the scenario harness ([`scenario`]) executes declarative JSON
//! scenarios — scheme × straggler model × workload × worker-pool sweeps,
//! with multiple jobs contending for one pool — into `JobReport`
//! summaries for the golden regression suite. The legacy phase API
//! ([`sim`]) survives as a facade over the event core.
//!
//! The simulator manipulates *virtual time only*; the numerics of every
//! task still execute for real (via the PJRT runtime or host kernels), so
//! end-to-end results remain verifiable against the uncoded product.

pub mod event;
pub mod scenario;
pub mod sim;
pub mod straggler;

pub use event::{Completion, EventSim, PhaseState, Pool, TaskId, Termination};
// The legacy phase facade is deprecated but stays re-exported so
// external callers keep compiling (with a deprecation warning at their
// use sites) while they migrate to the event core.
#[allow(deprecated)]
pub use sim::{earliest_decodable, launch, launch_tasks, recompute_round, speculative, Phase};
pub use straggler::{
    JobSample, SlowdownDist, StragglerModel, StragglerParams, WorkProfile, WorkerRates,
};

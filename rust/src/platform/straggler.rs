//! Straggler model calibrated to the paper's Fig 1.
//!
//! Fig 1 shows job-completion times of 3600 AWS Lambda workers running
//! distributed matmul: median ≈ 135 s and ~2% of workers take far longer
//! ("straggle consistently"). We model a worker's job time as
//!
//! `T = t_invoke + t_read + t_compute + t_write`, all log-normally
//! jittered, and with probability `p` the worker is a straggler: its
//! total is multiplied by a heavy-tailed factor (LogNormal clipped to
//! [min, max], default median ≈ 2.8×, tail to 8×) — matching the Fig-1
//! histogram's far-right bump.
//!
//! # Seeding contract (determinism)
//!
//! The model is **stateless**: every random draw flows through the
//! caller-provided [`Pcg64`], and each [`StragglerModel::sample`] call
//! consumes a fixed draw sequence (invoke jitter, read jitter, compute
//! jitter, write jitter, straggle Bernoulli, then — only for stragglers —
//! the slowdown factor). Consequences callers can rely on (verified by
//! `tests/platform_determinism.rs`):
//!
//! - Two runs with equal seeds produce **identical** job timelines and
//!   straggler sets, bit for bit — on any machine (no time, thread or
//!   platform dependence).
//! - Model instances are interchangeable: cloning or rebuilding a model
//!   never changes the stream; only the `Pcg64` position matters.
//! - Changing the *number* of draws (e.g. a straggler vs not) shifts the
//!   stream for subsequent tasks by design; simulations that must be
//!   comparable across configurations should use separate seeds or
//!   [`Pcg64::fork`] per phase.

use crate::util::rng::Pcg64;

/// Distribution of the straggler slowdown factor. Both draw exactly once
/// from the RNG stream per straggler, so swapping the distribution never
/// shifts the draw sequence of the surrounding fields.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SlowdownDist {
    /// LogNormal(slow_mu, slow_sigma), clamped to [slow_min, slow_max] —
    /// the Fig-1 calibration.
    LogNormal,
    /// Pareto with scale `slow_min` and shape `alpha`, clamped to
    /// `slow_max` — a heavier tail than Fig 1, used by scenario sweeps to
    /// stress the schemes beyond the paper's measured Lambda behaviour.
    Pareto { alpha: f64 },
}

/// Straggler-injection parameters.
#[derive(Debug, Clone, Copy)]
pub struct StragglerParams {
    /// Probability a worker straggles (paper: p ≈ 0.02 on Lambda).
    pub p: f64,
    /// LogNormal mu of the slowdown factor (of ln-factor).
    pub slow_mu: f64,
    /// LogNormal sigma of the slowdown factor.
    pub slow_sigma: f64,
    /// Clamp range of the slowdown factor.
    pub slow_min: f64,
    pub slow_max: f64,
    /// Multiplicative jitter sigma applied to every job's duration
    /// (system noise for non-stragglers).
    pub jitter_sigma: f64,
    /// Shape of the slowdown tail.
    pub slow_dist: SlowdownDist,
}

impl Default for StragglerParams {
    fn default() -> Self {
        StragglerParams {
            p: 0.02,
            slow_mu: 1.05, // median slowdown e^1.05 ≈ 2.86×
            slow_sigma: 0.35,
            slow_min: 1.8,
            slow_max: 8.0,
            jitter_sigma: 0.08,
            slow_dist: SlowdownDist::LogNormal,
        }
    }
}

/// Compute/communication rates of a simulated serverless worker.
#[derive(Debug, Clone, Copy)]
pub struct WorkerRates {
    /// Invocation (cold-start/queueing) latency mean, seconds.
    pub invoke_mean_s: f64,
    /// Invocation latency lognormal sigma.
    pub invoke_sigma: f64,
    /// Effective compute throughput, FLOP/s (Lambda-class single core).
    pub flops_per_s: f64,
    /// Storage model.
    pub cost: crate::storage::cost::CostModel,
}

impl Default for WorkerRates {
    fn default() -> Self {
        WorkerRates {
            invoke_mean_s: 1.5,
            invoke_sigma: 0.4,
            // Single Lambda worker running BLAS-backed numpy: ~1 GFLOP/s
            // effective on large blocks (calibrated so the Fig-1 workload
            // lands at the paper's ≈135 s median).
            flops_per_s: 1.0e9,
            cost: crate::storage::cost::CostModel::default(),
        }
    }
}

/// Description of one task's resource demands.
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkProfile {
    pub bytes_read: u64,
    pub read_ops: u64,
    pub flops: f64,
    pub bytes_written: u64,
    pub write_ops: u64,
}

impl WorkProfile {
    /// Profile of a block product `A_i (r×n) · B_jᵀ (n×c)`: read both
    /// blocks, 2rnc FLOPs, write the (r×c) result.
    pub fn block_product(r: usize, n: usize, c: usize) -> WorkProfile {
        WorkProfile {
            bytes_read: ((r * n + c * n) * 4) as u64,
            read_ops: 2,
            flops: 2.0 * r as f64 * n as f64 * c as f64,
            bytes_written: (r * c * 4) as u64,
            write_ops: 1,
        }
    }

    /// Profile of a parity-encode task: read `l` blocks of `rows×cols`,
    /// sum them, write one block.
    pub fn encode_parity(l: usize, rows: usize, cols: usize) -> WorkProfile {
        WorkProfile {
            bytes_read: (l * rows * cols * 4) as u64,
            read_ops: l as u64,
            flops: ((l - 1) * rows * cols) as f64,
            bytes_written: (rows * cols * 4) as u64,
            write_ops: 1,
        }
    }

    /// Column-sliced encode-phase profile (Remark 1): the side's parities
    /// total `groups·l` block-reads of `block_rows × k` each; `fleet`
    /// workers split the columns evenly, each writing its slice of every
    /// parity. Shared by the coordinator and the scenario runner.
    pub fn sliced_encode(
        groups: usize,
        l: usize,
        block_rows: usize,
        k: usize,
        fleet: usize,
    ) -> WorkProfile {
        let total_read = (groups * l * block_rows * k * 4) as u64;
        let total_write = (groups * block_rows * k * 4) as u64;
        WorkProfile {
            bytes_read: total_read / fleet as u64,
            // Ranged GETs, split across the fleet like the bytes.
            read_ops: (groups * l).div_ceil(fleet) as u64,
            flops: (groups * (l - 1).max(1) * block_rows * k) as f64 / fleet as f64,
            bytes_written: total_write / fleet as u64,
            write_ops: groups.div_ceil(fleet) as u64,
        }
    }

    /// Profile of a block matvec: read block (rows×cols) + vector chunk.
    pub fn block_matvec(rows: usize, cols: usize) -> WorkProfile {
        WorkProfile {
            bytes_read: ((rows * cols + cols) * 4) as u64,
            read_ops: 2,
            flops: 2.0 * rows as f64 * cols as f64,
            bytes_written: (rows * 4) as u64,
            write_ops: 1,
        }
    }
}

/// A sampled job execution in virtual time.
#[derive(Debug, Clone, Copy)]
pub struct JobSample {
    pub invoke: f64,
    pub io_read: f64,
    pub compute: f64,
    pub io_write: f64,
    pub straggle_factor: f64,
    pub straggled: bool,
}

impl JobSample {
    /// Total virtual duration from invocation to result-in-store.
    pub fn total(&self) -> f64 {
        (self.invoke + self.io_read + self.compute + self.io_write) * self.straggle_factor
    }
}

/// The sampling engine.
#[derive(Debug, Clone)]
pub struct StragglerModel {
    pub params: StragglerParams,
    pub rates: WorkerRates,
}

impl StragglerModel {
    pub fn new(params: StragglerParams, rates: WorkerRates) -> StragglerModel {
        StragglerModel { params, rates }
    }

    /// Sample one worker's execution of `work`.
    pub fn sample(&self, work: &WorkProfile, rng: &mut Pcg64) -> JobSample {
        let p = &self.params;
        let r = &self.rates;
        let jitter = |rng: &mut Pcg64| rng.lognormal(0.0, p.jitter_sigma);
        let invoke = r.invoke_mean_s * rng.lognormal(0.0, r.invoke_sigma);
        let io_read = r.cost.read_many(work.read_ops, work.bytes_read) * jitter(rng);
        let compute = work.flops / r.flops_per_s * jitter(rng);
        let io_write =
            r.cost.read_many(work.write_ops, work.bytes_written) * jitter(rng);
        let straggled = rng.bernoulli(p.p);
        let straggle_factor = if straggled {
            match p.slow_dist {
                SlowdownDist::LogNormal => rng
                    .lognormal(p.slow_mu, p.slow_sigma)
                    .clamp(p.slow_min, p.slow_max),
                SlowdownDist::Pareto { alpha } => rng
                    .pareto(p.slow_min.max(1.0), alpha)
                    .clamp(p.slow_min, p.slow_max),
            }
        } else {
            1.0
        };
        JobSample {
            invoke,
            io_read,
            compute,
            io_write,
            straggle_factor,
            straggled,
        }
    }

    /// Sample `n` independent workers on the same profile; returns total
    /// durations.
    pub fn sample_fleet(&self, work: &WorkProfile, n: usize, rng: &mut Pcg64) -> Vec<f64> {
        (0..n).map(|_| self.sample(work, rng).total()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    fn fig1_profile() -> WorkProfile {
        // A Fig-1-scale job: two 2048×16384 f32 blocks in, 2·2048²·16384
        // FLOPs (≈1.37e11 → ≈137 s at 1 GFLOP/s).
        WorkProfile::block_product(2048, 16384, 2048)
    }

    #[test]
    fn median_lands_near_paper_135s() {
        let model = StragglerModel::new(StragglerParams::default(), WorkerRates::default());
        let mut rng = Pcg64::new(1);
        let times = model.sample_fleet(&fig1_profile(), 3600, &mut rng);
        let s = Summary::of(&times);
        assert!(
            (s.p50 - 135.0).abs() < 20.0,
            "median {:.1}s should be ≈135s",
            s.p50
        );
    }

    #[test]
    fn straggler_rate_near_p() {
        let model = StragglerModel::new(StragglerParams::default(), WorkerRates::default());
        let mut rng = Pcg64::new(2);
        let n = 50_000;
        let stragglers = (0..n)
            .filter(|_| model.sample(&fig1_profile(), &mut rng).straggled)
            .count();
        let rate = stragglers as f64 / n as f64;
        assert!((rate - 0.02).abs() < 0.004, "rate={rate}");
    }

    #[test]
    fn stragglers_dominate_tail() {
        // ~2% of jobs should take ≥ 2× median (the Fig-1 bump).
        let model = StragglerModel::new(StragglerParams::default(), WorkerRates::default());
        let mut rng = Pcg64::new(3);
        let times = model.sample_fleet(&fig1_profile(), 20_000, &mut rng);
        let s = Summary::of(&times);
        let tail = times.iter().filter(|&&t| t >= 2.0 * s.p50).count() as f64
            / times.len() as f64;
        assert!(tail > 0.008 && tail < 0.035, "tail fraction {tail}");
    }

    #[test]
    fn straggle_factor_clamped() {
        let model = StragglerModel::new(StragglerParams::default(), WorkerRates::default());
        let mut rng = Pcg64::new(4);
        for _ in 0..5000 {
            let s = model.sample(&fig1_profile(), &mut rng);
            if s.straggled {
                assert!(s.straggle_factor >= 1.8 && s.straggle_factor <= 8.0);
            } else {
                assert_eq!(s.straggle_factor, 1.0);
            }
        }
    }

    #[test]
    fn profiles_scale_sensibly() {
        // Bigger work ⇒ more time; encode profile reads L blocks.
        let small = WorkProfile::block_product(256, 256, 256);
        let big = WorkProfile::block_product(512, 512, 512);
        assert!(big.flops > small.flops * 7.0);
        let enc = WorkProfile::encode_parity(10, 512, 512);
        assert_eq!(enc.read_ops, 10);
        assert_eq!(enc.bytes_read, 10 * 512 * 512 * 4);
        let mv = WorkProfile::block_matvec(1000, 2000);
        assert!((mv.flops - 4e6).abs() < 1.0);
    }

    #[test]
    fn pareto_slowdown_respects_clamp_and_stream() {
        let params = StragglerParams {
            p: 0.3,
            slow_dist: SlowdownDist::Pareto { alpha: 1.2 },
            ..Default::default()
        };
        let model = StragglerModel::new(params, WorkerRates::default());
        let mut rng = Pcg64::new(6);
        let mut straggled = 0;
        for _ in 0..3000 {
            let s = model.sample(&fig1_profile(), &mut rng);
            if s.straggled {
                straggled += 1;
                assert!(s.straggle_factor >= params.slow_min);
                assert!(s.straggle_factor <= params.slow_max);
            } else {
                assert_eq!(s.straggle_factor, 1.0);
            }
        }
        assert!(straggled > 0);
        // Same seed ⇒ same stream, for the alternate distribution too.
        let mut r1 = Pcg64::new(8);
        let mut r2 = Pcg64::new(8);
        assert_eq!(
            model.sample_fleet(&fig1_profile(), 50, &mut r1),
            model.sample_fleet(&fig1_profile(), 50, &mut r2)
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let model = StragglerModel::new(StragglerParams::default(), WorkerRates::default());
        let mut r1 = Pcg64::new(9);
        let mut r2 = Pcg64::new(9);
        let a = model.sample_fleet(&fig1_profile(), 100, &mut r1);
        let b = model.sample_fleet(&fig1_profile(), 100, &mut r2);
        assert_eq!(a, b);
    }
}

//! Straggler model calibrated to the paper's Fig 1.
//!
//! Fig 1 shows job-completion times of 3600 AWS Lambda workers running
//! distributed matmul: median ≈ 135 s and ~2% of workers take far longer
//! ("straggle consistently"). We model a worker's job time as
//!
//! `T = t_invoke + t_read + t_compute + t_write`, all log-normally
//! jittered, and with probability `p` the worker is a straggler: its
//! total is multiplied by a heavy-tailed factor (LogNormal clipped to
//! [min, max], default median ≈ 2.8×, tail to 8×) — matching the Fig-1
//! histogram's far-right bump.
//!
//! # Seeding contract (determinism)
//!
//! The model is **stateless**: every random draw flows through the
//! caller-provided [`Pcg64`], and each [`StragglerModel::sample`] call
//! consumes a fixed draw sequence (invoke jitter, read jitter, compute
//! jitter, write jitter, straggle Bernoulli, then — only for stragglers —
//! the slowdown factor). Consequences callers can rely on (verified by
//! `tests/platform_determinism.rs`):
//!
//! - Two runs with equal seeds produce **identical** job timelines and
//!   straggler sets, bit for bit — on any machine (no time, thread or
//!   platform dependence).
//! - Model instances are interchangeable: cloning or rebuilding a model
//!   never changes the stream; only the `Pcg64` position matters.
//! - Changing the *number* of draws (e.g. a straggler vs not) shifts the
//!   stream for subsequent tasks by design; simulations that must be
//!   comparable across configurations should use separate seeds or
//!   [`Pcg64::fork`] per phase.

use crate::util::rng::Pcg64;

/// Distribution of the straggler slowdown factor. Both draw exactly once
/// from the RNG stream per straggler, so swapping the distribution never
/// shifts the draw sequence of the surrounding fields.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SlowdownDist {
    /// LogNormal(slow_mu, slow_sigma), clamped to [slow_min, slow_max] —
    /// the Fig-1 calibration.
    LogNormal,
    /// Pareto with scale `slow_min` and shape `alpha`, clamped to
    /// `slow_max` — a heavier tail than Fig 1, used by scenario sweeps to
    /// stress the schemes beyond the paper's measured Lambda behaviour.
    Pareto { alpha: f64 },
}

/// Straggler-injection parameters.
#[derive(Debug, Clone, Copy)]
pub struct StragglerParams {
    /// Probability a worker straggles (paper: p ≈ 0.02 on Lambda).
    pub p: f64,
    /// LogNormal mu of the slowdown factor (of ln-factor).
    pub slow_mu: f64,
    /// LogNormal sigma of the slowdown factor.
    pub slow_sigma: f64,
    /// Clamp range of the slowdown factor.
    pub slow_min: f64,
    pub slow_max: f64,
    /// Multiplicative jitter sigma applied to every job's duration
    /// (system noise for non-stragglers).
    pub jitter_sigma: f64,
    /// Shape of the slowdown tail.
    pub slow_dist: SlowdownDist,
}

impl Default for StragglerParams {
    fn default() -> Self {
        StragglerParams {
            p: 0.02,
            slow_mu: 1.05, // median slowdown e^1.05 ≈ 2.86×
            slow_sigma: 0.35,
            slow_min: 1.8,
            slow_max: 8.0,
            jitter_sigma: 0.08,
            slow_dist: SlowdownDist::LogNormal,
        }
    }
}

/// Compute/communication rates of a simulated serverless worker.
#[derive(Debug, Clone, Copy)]
pub struct WorkerRates {
    /// Invocation (cold-start/queueing) latency mean, seconds.
    pub invoke_mean_s: f64,
    /// Invocation latency lognormal sigma.
    pub invoke_sigma: f64,
    /// Effective compute throughput, FLOP/s (Lambda-class single core).
    pub flops_per_s: f64,
    /// Storage model.
    pub cost: crate::storage::cost::CostModel,
}

impl Default for WorkerRates {
    fn default() -> Self {
        WorkerRates {
            invoke_mean_s: 1.5,
            invoke_sigma: 0.4,
            // Single Lambda worker running BLAS-backed numpy: ~1 GFLOP/s
            // effective on large blocks (calibrated so the Fig-1 workload
            // lands at the paper's ≈135 s median).
            flops_per_s: 1.0e9,
            cost: crate::storage::cost::CostModel::default(),
        }
    }
}

/// Description of one task's resource demands.
#[derive(Debug, Clone, Copy, Default)]
pub struct WorkProfile {
    pub bytes_read: u64,
    pub read_ops: u64,
    pub flops: f64,
    pub bytes_written: u64,
    pub write_ops: u64,
}

impl WorkProfile {
    /// Profile of a block product `A_i (r×n) · B_jᵀ (n×c)`: read both
    /// blocks, 2rnc FLOPs, write the (r×c) result.
    pub fn block_product(r: usize, n: usize, c: usize) -> WorkProfile {
        WorkProfile {
            bytes_read: ((r * n + c * n) * 4) as u64,
            read_ops: 2,
            flops: 2.0 * r as f64 * n as f64 * c as f64,
            bytes_written: (r * c * 4) as u64,
            write_ops: 1,
        }
    }

    /// The uncompleted remainder of this profile: every dimension scaled
    /// by `frac` (work stealing / retry-as-remainder under progress
    /// exploitation). Per-op counts are kept at ≥ 1 so a remainder still
    /// pays its invoke/IO constants; `frac` is clamped to `[0, 1]`.
    pub fn scaled(&self, frac: f64) -> WorkProfile {
        let frac = frac.clamp(0.0, 1.0);
        let scale_u = |v: u64| -> u64 { (v as f64 * frac).ceil() as u64 };
        WorkProfile {
            bytes_read: scale_u(self.bytes_read),
            read_ops: scale_u(self.read_ops).max(1),
            flops: self.flops * frac,
            bytes_written: scale_u(self.bytes_written),
            write_ops: scale_u(self.write_ops).max(1),
        }
    }

    /// Profile of a parity-encode task: read `l` blocks of `rows×cols`,
    /// sum them, write one block. Summing `l` blocks costs `l − 1` block
    /// additions — zero for the degenerate `l ≤ 1` copy-through cases
    /// (saturating, so `l == 0` cannot underflow).
    pub fn encode_parity(l: usize, rows: usize, cols: usize) -> WorkProfile {
        WorkProfile {
            bytes_read: (l * rows * cols * 4) as u64,
            read_ops: l as u64,
            flops: (l.saturating_sub(1) * rows * cols) as f64,
            bytes_written: (rows * cols * 4) as u64,
            write_ops: 1,
        }
    }

    /// Column-sliced encode-phase profile (Remark 1): the side's parities
    /// total `groups·l` block-reads of `block_rows × k` each; `fleet`
    /// workers split the columns evenly, each writing its slice of every
    /// parity. Shared by the coordinator and the scenario runner.
    pub fn sliced_encode(
        groups: usize,
        l: usize,
        block_rows: usize,
        k: usize,
        fleet: usize,
    ) -> WorkProfile {
        // A 0-worker fleet is a caller bug upstream; clamp rather than
        // divide by zero so a defensive profile stays finite.
        let fleet = fleet.max(1);
        let total_read = (groups * l * block_rows * k * 4) as u64;
        let total_write = (groups * block_rows * k * 4) as u64;
        WorkProfile {
            // Ceiling split: the straggler-bound worker carries the
            // remainder bytes instead of them vanishing from the model.
            bytes_read: total_read.div_ceil(fleet as u64),
            // Ranged GETs, split across the fleet like the bytes.
            read_ops: (groups * l).div_ceil(fleet) as u64,
            // Summing l blocks is l − 1 additions; l ≤ 1 means the single
            // data block is copied through with no arithmetic at all.
            flops: (groups * l.saturating_sub(1) * block_rows * k) as f64 / fleet as f64,
            bytes_written: total_write.div_ceil(fleet as u64),
            write_ops: groups.div_ceil(fleet) as u64,
        }
    }

    /// Profile of a block matvec: read block (rows×cols) + vector chunk.
    pub fn block_matvec(rows: usize, cols: usize) -> WorkProfile {
        WorkProfile {
            bytes_read: ((rows * cols + cols) * 4) as u64,
            read_ops: 2,
            flops: 2.0 * rows as f64 * cols as f64,
            bytes_written: (rows * 4) as u64,
            write_ops: 1,
        }
    }
}

/// One worker class of a heterogeneous fleet (cold-start model): a
/// provisioned / warm / cold tier drawn per attempt at pool admission.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerClass {
    pub name: String,
    /// Unnormalized admission weight (categorical draw).
    pub weight: f64,
    /// Multiplier on the invocation latency (cold starts ≫ 1,
    /// provisioned concurrency ≪ 1).
    pub invoke_mult: f64,
    /// Multiplier on effective compute throughput (≥ 1 = faster tier).
    pub flops_mult: f64,
}

/// Correlated slowdown: one cohort of the fleet (an AZ, or the readers
/// of one hot storage shard) runs `factor`× slower than the rest. The
/// cohort assignment is deterministic and RNG-free — it multiplies the
/// sampled duration without touching the draw stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CorrelatedSlowdown {
    /// Number of cohorts tasks are assigned to.
    pub cohorts: usize,
    /// Index of the slow cohort (< `cohorts`).
    pub slow_cohort: usize,
    /// Duration multiplier applied to the slow cohort's members.
    pub factor: f64,
    /// `true`: cohort = storage shard of the task's a-side input block
    /// (hooked to the sharded-MemStore placement; `cohorts` = shard
    /// count). `false`: round-robin over task index (an AZ-style
    /// worker-side cohort).
    pub by_shard: bool,
}

/// Fault-injection parameters layered on top of the straggler model
/// (the scenario `"failures"` section).
///
/// # RNG gating (determinism)
///
/// [`StragglerModel::sample_attempt`] draws **zero** extra values when
/// the model is inactive ([`FailureModel::is_active`] false): the draw
/// stream is then bit-identical to [`StragglerModel::sample`]. When
/// active, each attempt draws (in order, after the base sample): the
/// worker-class categorical (only if `classes` is non-empty), the death
/// Bernoulli (only if `death_p > 0`), and — only for dying attempts —
/// the kill-fraction uniform. Correlated slowdowns never draw.
#[derive(Debug, Clone, PartialEq)]
pub struct FailureModel {
    /// Probability an attempt's worker dies mid-flight.
    pub death_p: f64,
    /// Kill time as a uniform fraction of the attempt's duration,
    /// drawn from `[death_frac.0, death_frac.1)`.
    pub death_frac: (f64, f64),
    /// Re-dispatch bound per logical task (attempts beyond the first).
    pub max_retries: u32,
    /// Base re-dispatch backoff; retry `r` (1-based) is delayed by
    /// `backoff_s · 2^(r−1)` virtual seconds, charged to the attempt.
    pub backoff_s: f64,
    /// Cold-start worker classes; empty = homogeneous fleet (no draw).
    pub classes: Vec<WorkerClass>,
    /// Optional correlated-slowdown cohort.
    pub correlated: Option<CorrelatedSlowdown>,
}

impl Default for FailureModel {
    fn default() -> Self {
        FailureModel {
            death_p: 0.0,
            death_frac: (0.1, 0.9),
            max_retries: 2,
            backoff_s: 1.0,
            classes: Vec::new(),
            correlated: None,
        }
    }
}

impl FailureModel {
    /// True when sampling an attempt consumes extra RNG draws (deaths
    /// or worker classes). Inactive models leave the stream untouched.
    pub fn is_active(&self) -> bool {
        self.death_p > 0.0 || !self.classes.is_empty()
    }

    /// True when *any* failure feature is on — including draw-free
    /// correlated slowdowns. Gates fault-metrics emission.
    pub fn any(&self) -> bool {
        self.is_active() || self.correlated.is_some()
    }

    fn class_weights(&self) -> Vec<f64> {
        self.classes.iter().map(|c| c.weight).collect()
    }
}

/// One sampled attempt under an optional [`FailureModel`]: the final
/// duration (class and cohort effects applied), plus the injected kill
/// time when the attempt's worker dies before finishing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttemptSample {
    pub duration: f64,
    pub straggled: bool,
    /// Index into `FailureModel::classes`; `None` for homogeneous fleets.
    pub class: Option<usize>,
    /// Seconds after dispatch at which the worker dies (< `duration`);
    /// `None` = the attempt runs to completion.
    pub kill_after: Option<f64>,
}

/// A sampled job execution in virtual time.
#[derive(Debug, Clone, Copy)]
pub struct JobSample {
    pub invoke: f64,
    pub io_read: f64,
    pub compute: f64,
    pub io_write: f64,
    pub straggle_factor: f64,
    pub straggled: bool,
}

impl JobSample {
    /// Total virtual duration from invocation to result-in-store.
    pub fn total(&self) -> f64 {
        (self.invoke + self.io_read + self.compute + self.io_write) * self.straggle_factor
    }
}

/// The sampling engine.
#[derive(Debug, Clone)]
pub struct StragglerModel {
    pub params: StragglerParams,
    pub rates: WorkerRates,
}

impl StragglerModel {
    pub fn new(params: StragglerParams, rates: WorkerRates) -> StragglerModel {
        StragglerModel { params, rates }
    }

    /// Sample one worker's execution of `work`.
    pub fn sample(&self, work: &WorkProfile, rng: &mut Pcg64) -> JobSample {
        let p = &self.params;
        let r = &self.rates;
        let jitter = |rng: &mut Pcg64| rng.lognormal(0.0, p.jitter_sigma);
        let invoke = r.invoke_mean_s * rng.lognormal(0.0, r.invoke_sigma);
        let io_read = r.cost.read_many(work.read_ops, work.bytes_read) * jitter(rng);
        let compute = work.flops / r.flops_per_s * jitter(rng);
        let io_write =
            r.cost.read_many(work.write_ops, work.bytes_written) * jitter(rng);
        let straggled = rng.bernoulli(p.p);
        let straggle_factor = if straggled {
            match p.slow_dist {
                SlowdownDist::LogNormal => rng
                    .lognormal(p.slow_mu, p.slow_sigma)
                    .clamp(p.slow_min, p.slow_max),
                SlowdownDist::Pareto { alpha } => rng
                    .pareto(p.slow_min.max(1.0), alpha)
                    .clamp(p.slow_min, p.slow_max),
            }
        } else {
            1.0
        };
        JobSample {
            invoke,
            io_read,
            compute,
            io_write,
            straggle_factor,
            straggled,
        }
    }

    /// Sample `n` independent workers on the same profile; returns total
    /// durations.
    pub fn sample_fleet(&self, work: &WorkProfile, n: usize, rng: &mut Pcg64) -> Vec<f64> {
        (0..n).map(|_| self.sample(work, rng).total()).collect()
    }

    /// Sample one attempt under an optional [`FailureModel`].
    ///
    /// The base draw sequence is exactly [`StragglerModel::sample`];
    /// with `faults` `None` or inactive, no extra value is drawn and
    /// `duration == sample().total() * cohort_mult` bit for bit
    /// (`cohort_mult == 1.0` is the identity). Worker-class effects
    /// rescale the invoke and compute components before the straggle
    /// factor; the cohort multiplier applies to the whole duration.
    pub fn sample_attempt(
        &self,
        work: &WorkProfile,
        faults: Option<&FailureModel>,
        cohort_mult: f64,
        rng: &mut Pcg64,
    ) -> AttemptSample {
        let s = self.sample(work, rng);
        let fm = match faults {
            Some(fm) if fm.is_active() => fm,
            _ => {
                return AttemptSample {
                    duration: s.total() * cohort_mult,
                    straggled: s.straggled,
                    class: None,
                    kill_after: None,
                }
            }
        };
        let class = if fm.classes.is_empty() {
            None
        } else {
            Some(rng.categorical(&fm.class_weights()))
        };
        let mut duration = match class {
            None => s.total(),
            Some(ci) => {
                let c = &fm.classes[ci];
                (s.invoke * c.invoke_mult + s.io_read + s.compute / c.flops_mult + s.io_write)
                    * s.straggle_factor
            }
        };
        duration *= cohort_mult;
        let kill_after = if fm.death_p > 0.0 && rng.bernoulli(fm.death_p) {
            let (lo, hi) = fm.death_frac;
            Some(duration * rng.uniform(lo, hi))
        } else {
            None
        };
        AttemptSample {
            duration,
            straggled: s.straggled,
            class,
            kill_after,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Summary;

    fn fig1_profile() -> WorkProfile {
        // A Fig-1-scale job: two 2048×16384 f32 blocks in, 2·2048²·16384
        // FLOPs (≈1.37e11 → ≈137 s at 1 GFLOP/s).
        WorkProfile::block_product(2048, 16384, 2048)
    }

    #[test]
    fn median_lands_near_paper_135s() {
        let model = StragglerModel::new(StragglerParams::default(), WorkerRates::default());
        let mut rng = Pcg64::new(1);
        let times = model.sample_fleet(&fig1_profile(), 3600, &mut rng);
        let s = Summary::of(&times);
        assert!(
            (s.p50 - 135.0).abs() < 20.0,
            "median {:.1}s should be ≈135s",
            s.p50
        );
    }

    #[test]
    fn straggler_rate_near_p() {
        let model = StragglerModel::new(StragglerParams::default(), WorkerRates::default());
        let mut rng = Pcg64::new(2);
        let n = 50_000;
        let stragglers = (0..n)
            .filter(|_| model.sample(&fig1_profile(), &mut rng).straggled)
            .count();
        let rate = stragglers as f64 / n as f64;
        assert!((rate - 0.02).abs() < 0.004, "rate={rate}");
    }

    #[test]
    fn stragglers_dominate_tail() {
        // ~2% of jobs should take ≥ 2× median (the Fig-1 bump).
        let model = StragglerModel::new(StragglerParams::default(), WorkerRates::default());
        let mut rng = Pcg64::new(3);
        let times = model.sample_fleet(&fig1_profile(), 20_000, &mut rng);
        let s = Summary::of(&times);
        let tail = times.iter().filter(|&&t| t >= 2.0 * s.p50).count() as f64
            / times.len() as f64;
        assert!(tail > 0.008 && tail < 0.035, "tail fraction {tail}");
    }

    #[test]
    fn straggle_factor_clamped() {
        let model = StragglerModel::new(StragglerParams::default(), WorkerRates::default());
        let mut rng = Pcg64::new(4);
        for _ in 0..5000 {
            let s = model.sample(&fig1_profile(), &mut rng);
            if s.straggled {
                assert!(s.straggle_factor >= 1.8 && s.straggle_factor <= 8.0);
            } else {
                assert_eq!(s.straggle_factor, 1.0);
            }
        }
    }

    #[test]
    fn profiles_scale_sensibly() {
        // Bigger work ⇒ more time; encode profile reads L blocks.
        let small = WorkProfile::block_product(256, 256, 256);
        let big = WorkProfile::block_product(512, 512, 512);
        assert!(big.flops > small.flops * 7.0);
        let enc = WorkProfile::encode_parity(10, 512, 512);
        assert_eq!(enc.read_ops, 10);
        assert_eq!(enc.bytes_read, 10 * 512 * 512 * 4);
        let mv = WorkProfile::block_matvec(1000, 2000);
        assert!((mv.flops - 4e6).abs() < 1.0);
    }

    #[test]
    fn pareto_slowdown_respects_clamp_and_stream() {
        let params = StragglerParams {
            p: 0.3,
            slow_dist: SlowdownDist::Pareto { alpha: 1.2 },
            ..Default::default()
        };
        let model = StragglerModel::new(params, WorkerRates::default());
        let mut rng = Pcg64::new(6);
        let mut straggled = 0;
        for _ in 0..3000 {
            let s = model.sample(&fig1_profile(), &mut rng);
            if s.straggled {
                straggled += 1;
                assert!(s.straggle_factor >= params.slow_min);
                assert!(s.straggle_factor <= params.slow_max);
            } else {
                assert_eq!(s.straggle_factor, 1.0);
            }
        }
        assert!(straggled > 0);
        // Same seed ⇒ same stream, for the alternate distribution too.
        let mut r1 = Pcg64::new(8);
        let mut r2 = Pcg64::new(8);
        assert_eq!(
            model.sample_fleet(&fig1_profile(), 50, &mut r1),
            model.sample_fleet(&fig1_profile(), 50, &mut r2)
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let model = StragglerModel::new(StragglerParams::default(), WorkerRates::default());
        let mut r1 = Pcg64::new(9);
        let mut r2 = Pcg64::new(9);
        let a = model.sample_fleet(&fig1_profile(), 100, &mut r1);
        let b = model.sample_fleet(&fig1_profile(), 100, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn encode_parity_degenerate_group_sizes() {
        // l == 0 must not underflow (debug panic pre-fix) and l ≤ 1 is
        // a copy-through: no additions at all.
        let none = WorkProfile::encode_parity(0, 512, 512);
        assert_eq!(none.flops, 0.0);
        assert_eq!(none.bytes_read, 0);
        let copy = WorkProfile::encode_parity(1, 512, 512);
        assert_eq!(copy.flops, 0.0);
        assert_eq!(copy.bytes_read, 512 * 512 * 4);
        assert_eq!(copy.bytes_written, 512 * 512 * 4);
    }

    #[test]
    fn sliced_encode_non_divisible_fleet_keeps_remainder() {
        // 2 groups × l=3 × 100×7 blocks over a fleet of 5: totals are
        // not divisible, and the per-worker share must round *up* so the
        // remainder bytes don't vanish from the model.
        let p = WorkProfile::sliced_encode(2, 3, 100, 7, 5);
        let total_read = (2 * 3 * 100 * 7 * 4) as u64;
        let total_write = (2 * 100 * 7 * 4) as u64;
        assert_eq!(p.bytes_read, total_read.div_ceil(5));
        assert!(p.bytes_read * 5 >= total_read);
        assert_eq!(p.bytes_written, total_write.div_ceil(5));
        assert!(p.bytes_written * 5 >= total_write);
        // l = 1 copy-through: zero flops (was 1 full block-add pre-fix),
        // and l = 0 must not underflow.
        assert_eq!(WorkProfile::sliced_encode(4, 1, 100, 7, 2).flops, 0.0);
        assert_eq!(WorkProfile::sliced_encode(4, 0, 100, 7, 2).flops, 0.0);
        // A zero fleet is clamped, not a division by zero.
        let clamped = WorkProfile::sliced_encode(2, 3, 100, 7, 0);
        assert_eq!(clamped.bytes_read, total_read);
        // Divisible splits are exact (the golden-pinned regime).
        let even = WorkProfile::sliced_encode(4, 2, 100, 8, 4);
        assert_eq!(even.bytes_read * 4, (4 * 2 * 100 * 8 * 4) as u64);
    }

    #[test]
    fn sample_attempt_without_faults_matches_sample_stream() {
        // The churn-capable sampler must be a bit-identical superset of
        // the plain one when no failure model is present or active —
        // that is what keeps pre-churn goldens byte-identical.
        let model = StragglerModel::new(StragglerParams::default(), WorkerRates::default());
        let w = fig1_profile();
        let inert = FailureModel::default();
        assert!(!inert.is_active());
        let mut r1 = Pcg64::new(15);
        let mut r2 = Pcg64::new(15);
        let mut r3 = Pcg64::new(15);
        for _ in 0..200 {
            let plain = model.sample(&w, &mut r1);
            let none = model.sample_attempt(&w, None, 1.0, &mut r2);
            let quiet = model.sample_attempt(&w, Some(&inert), 1.0, &mut r3);
            assert_eq!(none.duration.to_bits(), plain.total().to_bits());
            assert_eq!(quiet.duration.to_bits(), plain.total().to_bits());
            assert_eq!(none.straggled, plain.straggled);
            assert!(none.class.is_none() && none.kill_after.is_none());
            assert!(quiet.class.is_none() && quiet.kill_after.is_none());
        }
        // And the three streams stay aligned afterwards.
        assert_eq!(r1.next_u64(), r2.next_u64());
    }

    #[test]
    fn sample_attempt_draws_classes_and_kills() {
        let model = StragglerModel::new(StragglerParams::default(), WorkerRates::default());
        let w = fig1_profile();
        let fm = FailureModel {
            death_p: 0.3,
            death_frac: (0.2, 0.8),
            classes: vec![
                WorkerClass {
                    name: "warm".into(),
                    weight: 0.7,
                    invoke_mult: 1.0,
                    flops_mult: 1.0,
                },
                WorkerClass {
                    name: "cold".into(),
                    weight: 0.3,
                    invoke_mult: 4.0,
                    flops_mult: 0.5,
                },
            ],
            ..Default::default()
        };
        let mut rng = Pcg64::new(16);
        let (mut deaths, mut cold) = (0, 0);
        for _ in 0..4000 {
            let s = model.sample_attempt(&w, Some(&fm), 1.0, &mut rng);
            assert!(s.duration.is_finite() && s.duration > 0.0);
            match s.class {
                Some(1) => cold += 1,
                Some(0) => {}
                other => panic!("unexpected class {other:?}"),
            }
            if let Some(k) = s.kill_after {
                deaths += 1;
                // The kill always strikes mid-flight.
                assert!(k > 0.0 && k < s.duration);
                assert!(k >= 0.2 * s.duration - 1e-9 && k <= 0.8 * s.duration + 1e-9);
            }
        }
        let death_rate = deaths as f64 / 4000.0;
        let cold_rate = cold as f64 / 4000.0;
        assert!((death_rate - 0.3).abs() < 0.03, "death rate {death_rate}");
        assert!((cold_rate - 0.3).abs() < 0.03, "cold rate {cold_rate}");
        // Cohort multiplier scales the duration without extra draws.
        let mut ra = Pcg64::new(17);
        let mut rb = Pcg64::new(17);
        let a = model.sample_attempt(&w, Some(&fm), 1.0, &mut ra);
        let b = model.sample_attempt(&w, Some(&fm), 2.5, &mut rb);
        assert!((b.duration - 2.5 * a.duration).abs() < 1e-9 * b.duration);
        assert_eq!(ra.next_u64(), rb.next_u64());
    }
}

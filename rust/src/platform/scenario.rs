//! Declarative scenario harness over the discrete-event core.
//!
//! A *scenario* is a JSON document (checked into `rust/scenarios/`)
//! describing a scheme × straggler-model × workload × worker-pool sweep:
//! one straggler calibration, a worker-pool sweep (`workers`, 0 =
//! unbounded), and a list of jobs — each a coded-matmul pipeline
//! (encode → compute → decode → recompute-fallback) with its own scheme,
//! partitioning, paper-scale dims and arrival time. All jobs of a run
//! share one [`EventSim`] worker pool, so staggered arrivals genuinely
//! contend for workers.
//!
//! The runner is **timing-only** and scheme-agnostic: every job drives a
//! [`CodingScheme`] object from the registry through the same phase
//! plans the coordinator uses (encode plan, termination policy,
//! decodability probe, decode plan), but no matrices are materialized,
//! so hundreds of scenario jobs run in milliseconds. Each job yields a
//! [`JobReport`] — the exact metrics schema of
//! `coordinator::run_matmul` (`rel_err` stays NaN/null) — and
//! `tests/scenarios_golden.rs` compares the resulting summaries against
//! checked-in golden files.
//!
//! Unknown JSON keys are configuration errors: a typo in a scenario,
//! straggler or job object fails loudly, naming the bad key.
//!
//! # Determinism
//!
//! Each job forks its own [`Pcg64`] stream off the scenario seed (in job
//! order, before any event is processed) and samples every task duration
//! at phase submission in task order. Consequently the sampled timeline
//! of a job is a pure function of `(seed, job index)` — event
//! interleaving and pool size never shift the draw sequence — and two
//! runs of a scenario are bit-identical.

use crate::codes::scheme::{CodingScheme, DecodeProbe, JobShape};
use crate::codes::Scheme;
use crate::coordinator::metrics::JobReport;
use crate::platform::event::{Completion, EventSim, PhaseState, Pool};
use crate::platform::straggler::{
    SlowdownDist, StragglerModel, StragglerParams, WorkerRates,
};
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg64;

/// One job of a scenario.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub scheme: Scheme,
    pub s_a: usize,
    pub s_b: usize,
    /// Virtual (paper-scale) dims `(rows_a, inner, rows_b)`.
    pub dims: (usize, usize, usize),
    pub decode_workers: usize,
    /// 0 ⇒ auto fleet = ceil(compute_tasks / 10) (Remark 1).
    pub encode_workers: usize,
    /// Virtual time the job enters the system.
    pub arrival: f64,
}

impl JobSpec {
    fn shape(&self) -> JobShape {
        JobShape::new(self.s_a, self.s_b, self.dims)
    }

    fn encode_fleet(&self, compute_tasks: usize) -> usize {
        if self.encode_workers > 0 {
            self.encode_workers
        } else {
            compute_tasks.div_ceil(10).max(1)
        }
    }
}

/// A parsed scenario file.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub description: String,
    pub seed: u64,
    /// Worker-pool sweep; each entry is one run (0 = unbounded).
    pub workers: Vec<usize>,
    pub straggler: StragglerParams,
    pub rates: WorkerRates,
    pub jobs: Vec<JobSpec>,
}

/// Reject unknown keys so config typos fail loudly, naming the bad key.
fn ensure_known_keys(ctx: &str, j: &Json, known: &[&str]) -> anyhow::Result<()> {
    if let Some(fields) = j.as_obj() {
        for (k, _) in fields {
            anyhow::ensure!(
                known.contains(&k.as_str()),
                "unknown {ctx} key '{k}' (known: {})",
                known.join(", ")
            );
        }
    }
    Ok(())
}

/// Parse a scenario document (see EXPERIMENTS.md §Scenario suite for the
/// schema).
pub fn parse_scenario(doc: &Json) -> anyhow::Result<Scenario> {
    ensure_known_keys(
        "scenario",
        doc,
        &["name", "description", "seed", "workers", "straggler", "jobs"],
    )?;
    let name = doc
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("scenario needs a string 'name'"))?
        .to_string();
    let description = doc
        .get("description")
        .and_then(Json::as_str)
        .unwrap_or("")
        .to_string();
    let seed = doc
        .get("seed")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow::anyhow!("scenario '{name}' needs an integer 'seed'"))?;

    let workers = match doc.get("workers") {
        None => vec![0],
        Some(n @ Json::Num(_)) => vec![n
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("'workers' must be a non-negative integer"))?],
        Some(Json::Arr(items)) => {
            let mut ws = Vec::with_capacity(items.len());
            for it in items {
                ws.push(
                    it.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("'workers' entries must be integers"))?,
                );
            }
            anyhow::ensure!(!ws.is_empty(), "'workers' sweep must be non-empty");
            ws
        }
        Some(_) => anyhow::bail!("'workers' must be an integer or an array of integers"),
    };

    let straggler = parse_straggler(doc.get("straggler"))?;

    let jobs_json = doc
        .get("jobs")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("scenario '{name}' needs a 'jobs' array"))?;
    anyhow::ensure!(!jobs_json.is_empty(), "scenario '{name}' has no jobs");
    let mut jobs = Vec::with_capacity(jobs_json.len());
    for (i, jj) in jobs_json.iter().enumerate() {
        jobs.push(parse_job(jj).map_err(|e| anyhow::anyhow!("job {i} of '{name}': {e}"))?);
    }

    Ok(Scenario {
        name,
        description,
        seed,
        workers,
        straggler,
        rates: WorkerRates::default(),
        jobs,
    })
}

fn parse_straggler(j: Option<&Json>) -> anyhow::Result<StragglerParams> {
    let mut p = StragglerParams::default();
    let Some(j) = j else { return Ok(p) };
    anyhow::ensure!(
        j.as_obj().is_some(),
        "'straggler' must be an object, got {}",
        j.to_string_compact()
    );
    ensure_known_keys(
        "straggler",
        j,
        &[
            "p",
            "slow_mu",
            "slow_sigma",
            "slow_min",
            "slow_max",
            "jitter_sigma",
            "dist",
            "pareto_alpha",
        ],
    )?;
    let num = |key: &str| j.get(key).and_then(Json::as_f64);
    if let Some(v) = num("p") {
        p.p = v;
    }
    if let Some(v) = num("slow_mu") {
        p.slow_mu = v;
    }
    if let Some(v) = num("slow_sigma") {
        p.slow_sigma = v;
    }
    if let Some(v) = num("slow_min") {
        p.slow_min = v;
    }
    if let Some(v) = num("slow_max") {
        p.slow_max = v;
    }
    if let Some(v) = num("jitter_sigma") {
        p.jitter_sigma = v;
    }
    match j.get("dist").and_then(Json::as_str) {
        None | Some("lognormal") => {}
        Some("pareto") => {
            let alpha = num("pareto_alpha").unwrap_or(1.5);
            p.slow_dist = SlowdownDist::Pareto { alpha };
        }
        Some(other) => anyhow::bail!("unknown straggler dist '{other}'"),
    }
    Ok(p)
}

fn parse_job(j: &Json) -> anyhow::Result<JobSpec> {
    ensure_known_keys(
        "job",
        j,
        &[
            "scheme",
            "s_a",
            "s_b",
            "dims",
            "decode_workers",
            "encode_workers",
            "arrival",
        ],
    )?;
    let scheme_str = j
        .get("scheme")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("job needs a 'scheme' string"))?;
    let scheme = Scheme::parse(scheme_str)?;
    let s_a = j
        .get("s_a")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow::anyhow!("job needs integer 's_a'"))?;
    let s_b = j
        .get("s_b")
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow::anyhow!("job needs integer 's_b'"))?;
    let dims = match j.get("dims") {
        Some(Json::Arr(items)) if items.len() == 3 => {
            let d: Vec<usize> = items
                .iter()
                .map(|it| it.as_usize().unwrap_or(0))
                .collect();
            anyhow::ensure!(d.iter().all(|&x| x > 0), "'dims' must be positive");
            (d[0], d[1], d[2])
        }
        Some(Json::Num(_)) => {
            let n = j.get("dims").unwrap().as_usize().unwrap_or(0);
            anyhow::ensure!(n > 0, "'dims' must be positive");
            (n, n, n)
        }
        _ => anyhow::bail!("job needs 'dims' (an [m, k, l] array or one cube dim)"),
    };
    anyhow::ensure!(s_a > 0 && s_b > 0, "'s_a' and 's_b' must be positive");
    anyhow::ensure!(dims.0 % s_a == 0, "s_a must divide dims[0]");
    anyhow::ensure!(dims.2 % s_b == 0, "s_b must divide dims[2]");
    let decode_workers = j.get("decode_workers").and_then(Json::as_usize).unwrap_or(4);
    let encode_workers = j.get("encode_workers").and_then(Json::as_usize).unwrap_or(0);
    let arrival = j.get("arrival").and_then(Json::as_f64).unwrap_or(0.0);
    anyhow::ensure!(arrival >= 0.0, "'arrival' must be non-negative");
    // Validate the scheme's parameters against the partitioning through
    // the same registry instantiation the runner uses.
    scheme.instantiate(s_a, s_b)?;
    Ok(JobSpec {
        scheme,
        s_a,
        s_b,
        dims,
        decode_workers,
        encode_workers,
        arrival,
    })
}

// ---------------------------------------------------------------------------
// Job state machine
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Stage {
    Encode,
    Compute,
    Decode,
    Recompute,
}

/// One job's pipeline advancing through the shared event queue; drives
/// the job's [`CodingScheme`] phase plans (timing only) — the same
/// contract the coordinator's generic driver executes numerically.
struct JobRun {
    index: usize,
    spec: JobSpec,
    scheme: Box<dyn CodingScheme>,
    shape: JobShape,
    rng: Pcg64,
    report: JobReport,
    stage: Stage,
    phase: Option<PhaseState>,
    /// Live decodability probe of the compute stage.
    probe: Option<DecodeProbe>,
    done: bool,
    finish: f64,
    /// Cells the decode plan could not recover (recompute fallback).
    undecodable: usize,
}

impl JobRun {
    fn new(index: usize, spec: JobSpec, rng: Pcg64) -> anyhow::Result<JobRun> {
        let scheme = spec.scheme.instantiate(spec.s_a, spec.s_b)?;
        let mut report = JobReport::new(scheme.name());
        report.redundancy = scheme.redundancy();
        report.numerics_ok = scheme.numerics_feasible();
        let shape = spec.shape();
        Ok(JobRun {
            index,
            spec,
            scheme,
            shape,
            rng,
            report,
            stage: Stage::Encode,
            phase: None,
            probe: None,
            done: false,
            finish: 0.0,
            undecodable: 0,
        })
    }

    /// Begin the pipeline at the job's arrival time (sim clock is there).
    fn start(&mut self, sim: &mut EventSim, model: &StragglerModel) {
        let fleet = self.spec.encode_fleet(self.scheme.compute_tasks());
        match self.scheme.encode_plan(&self.shape, fleet) {
            Some(plan) => self.start_encode(sim, model, fleet, plan),
            None => self.start_compute(sim, model),
        }
        self.pump(sim, model);
    }

    fn start_encode(
        &mut self,
        sim: &mut EventSim,
        model: &StragglerModel,
        fleet: usize,
        plan: crate::codes::scheme::EncodePlan,
    ) {
        self.stage = Stage::Encode;
        self.report.enc.blocks_read = plan.blocks_read;
        self.phase = Some(PhaseState::launch_uniform(
            sim,
            model,
            &plan.profile,
            fleet,
            self.index,
            plan.termination,
            &mut self.rng,
        ));
    }

    fn start_compute(&mut self, sim: &mut EventSim, model: &StragglerModel) {
        self.stage = Stage::Compute;
        self.probe = Some(self.scheme.decode_probe());
        self.phase = Some(PhaseState::launch_uniform(
            sim,
            model,
            &self.shape.compute_profile(),
            self.scheme.compute_tasks(),
            self.index,
            self.scheme.compute_termination(),
            &mut self.rng,
        ));
    }

    fn start_decode(&mut self, sim: &mut EventSim, model: &StragglerModel, arrived: &[bool]) {
        let plan = self
            .scheme
            .decode_plan(arrived, &self.shape, self.spec.decode_workers);
        self.undecodable = plan.undecodable;
        self.report.dec.blocks_read = plan.blocks_read;
        self.report.dec.tasks = plan.profiles.len();
        self.report.decode_ok = plan.undecodable == 0;
        if plan.profiles.is_empty() {
            self.start_recompute(sim, model);
        } else {
            self.stage = Stage::Decode;
            self.phase = Some(PhaseState::launch(
                sim,
                model,
                &plan.profiles,
                self.index,
                plan.termination,
                &mut self.rng,
            ));
        }
    }

    // Defensive fallback, unreachable under earliest-decodable
    // termination (see `JobReport::decode_ok`): kept for cutoff policies
    // that cannot guarantee a decodable mask.
    fn start_recompute(&mut self, sim: &mut EventSim, model: &StragglerModel) {
        if self.undecodable == 0 {
            self.finish_job(sim.now());
            return;
        }
        self.stage = Stage::Recompute;
        self.phase = Some(PhaseState::launch_uniform(
            sim,
            model,
            &self.shape.compute_profile(),
            self.undecodable,
            self.index,
            crate::platform::event::Termination::WaitAll,
            &mut self.rng,
        ));
    }

    fn finish_job(&mut self, t: f64) {
        self.done = true;
        self.finish = t;
        self.phase = None;
        self.probe = None;
    }

    /// Route one completion of this job to its live phase.
    fn on_completion(&mut self, sim: &mut EventSim, model: &StragglerModel, c: &Completion) {
        if self.done {
            return;
        }
        let mut ps = match self.phase.take() {
            Some(p) => p,
            None => return,
        };
        if self.stage == Stage::Compute {
            let mut probe = self.probe.take().expect("compute stage keeps its probe");
            ps.on_completion(sim, model, &mut self.rng, c, &mut *probe);
            self.probe = Some(probe);
        } else {
            ps.on_completion(sim, model, &mut self.rng, c, &mut |_, _| false);
        }
        self.phase = Some(ps);
        self.pump(sim, model);
    }

    /// Advance through any phases that have reached termination (also
    /// covers phases that finish at birth, e.g. zero decode work).
    fn pump(&mut self, sim: &mut EventSim, model: &StragglerModel) {
        while !self.done {
            let ps = match self.phase.take() {
                Some(p) => p,
                None => break,
            };
            if !ps.is_finished() {
                self.phase = Some(ps);
                break;
            }
            match self.stage {
                Stage::Encode => {
                    self.report.enc.tasks = ps.n();
                    self.report.enc.stragglers = ps.stragglers();
                    self.report.enc.relaunched = ps.relaunched;
                    self.report.enc.virtual_secs = ps.duration();
                    self.start_compute(sim, model);
                }
                Stage::Compute => {
                    self.report.comp.tasks = ps.n();
                    self.report.comp.stragglers = ps.stragglers();
                    self.report.comp.relaunched = ps.relaunched;
                    self.report.comp.virtual_secs = ps.duration();
                    self.probe = None;
                    let mask = ps.arrived_mask();
                    self.start_decode(sim, model, &mask);
                }
                Stage::Decode => {
                    self.report.dec.relaunched += ps.relaunched;
                    self.report.dec.virtual_secs += ps.duration();
                    self.start_recompute(sim, model);
                }
                Stage::Recompute => {
                    self.report.dec.virtual_secs += ps.duration();
                    self.report.dec.relaunched += self.undecodable;
                    let t = ps.end_time();
                    self.finish_job(t);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Scenario executor
// ---------------------------------------------------------------------------

/// Execute every `workers` run of the scenario and return the summary
/// document compared by the golden suite.
pub fn run_scenario(sc: &Scenario) -> anyhow::Result<Json> {
    let model = StragglerModel::new(sc.straggler, sc.rates);
    let mut runs = Vec::with_capacity(sc.workers.len());
    for &workers in &sc.workers {
        let mut sim = EventSim::new(Pool::from_option(Some(workers)));
        // Fork per-job streams up front, in job order: the timeline of a
        // job is a function of (seed, job index) only.
        let mut root = Pcg64::new(sc.seed);
        let mut jobs: Vec<JobRun> = Vec::with_capacity(sc.jobs.len());
        for (i, spec) in sc.jobs.iter().enumerate() {
            jobs.push(JobRun::new(i, spec.clone(), root.fork(i as u64))?);
        }
        // Arrival order (ties by job index).
        let mut order: Vec<usize> = (0..jobs.len()).collect();
        order.sort_by(|&x, &y| {
            jobs[x]
                .spec
                .arrival
                .total_cmp(&jobs[y].spec.arrival)
                .then(x.cmp(&y))
        });
        let mut next_arrival = 0usize;
        loop {
            let next_ev = sim.peek_time();
            let next_arr = if next_arrival < order.len() {
                Some(jobs[order[next_arrival]].spec.arrival)
            } else {
                None
            };
            let start_now = match (next_arr, next_ev) {
                (Some(a), Some(e)) => a <= e,
                (Some(_), None) => true,
                (None, _) => false,
            };
            if start_now {
                let j = order[next_arrival];
                next_arrival += 1;
                let at = jobs[j].spec.arrival.max(sim.now());
                sim.advance_to(at);
                jobs[j].start(&mut sim, &model);
            } else if next_ev.is_some() {
                let c = sim.step().expect("peeked event must pop");
                let j = c.job;
                jobs[j].on_completion(&mut sim, &model, &c);
            } else {
                break;
            }
        }
        for job in &jobs {
            anyhow::ensure!(
                job.done,
                "scenario '{}' job {} did not run to completion",
                sc.name,
                job.index
            );
        }

        let jobs_json: Vec<Json> = jobs
            .iter()
            .map(|job| {
                let mut jj = job.report.to_json();
                jj.set("arrival", Json::from(job.spec.arrival));
                jj.set("finish", Json::from(job.finish));
                jj
            })
            .collect();
        runs.push(
            obj()
                .field("workers", workers)
                .field("jobs", Json::Arr(jobs_json))
                .build(),
        );
    }

    Ok(obj()
        .field("scenario", sc.name.as_str())
        .field("seed", sc.seed)
        .field(
            "straggler",
            obj()
                .field(
                    "dist",
                    match sc.straggler.slow_dist {
                        SlowdownDist::LogNormal => "lognormal",
                        SlowdownDist::Pareto { .. } => "pareto",
                    },
                )
                .field("p", sc.straggler.p)
                .build(),
        )
        .field("runs", Json::Arr(runs))
        .build())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn scenario_from(src: &str) -> Scenario {
        parse_scenario(&parse(src).unwrap()).unwrap()
    }

    #[test]
    fn parses_minimal_scenario() {
        let sc = scenario_from(
            r#"{
                "name": "mini",
                "seed": 3,
                "jobs": [
                    {"scheme": "uncoded", "s_a": 2, "s_b": 2, "dims": 1000}
                ]
            }"#,
        );
        assert_eq!(sc.name, "mini");
        assert_eq!(sc.workers, vec![0]);
        assert_eq!(sc.jobs.len(), 1);
        assert_eq!(sc.jobs[0].dims, (1000, 1000, 1000));
        assert_eq!(sc.jobs[0].decode_workers, 4);
        assert_eq!(sc.straggler.slow_dist, SlowdownDist::LogNormal);
    }

    #[test]
    fn parses_straggler_and_sweep() {
        let sc = scenario_from(
            r#"{
                "name": "full",
                "seed": 9,
                "workers": [0, 50],
                "straggler": {"dist": "pareto", "pareto_alpha": 1.2, "p": 0.05},
                "jobs": [
                    {"scheme": "local-product:2x2", "s_a": 4, "s_b": 4,
                     "dims": [4000, 2000, 4000], "arrival": 10.5,
                     "decode_workers": 3, "encode_workers": 2}
                ]
            }"#,
        );
        assert_eq!(sc.workers, vec![0, 50]);
        assert_eq!(sc.straggler.p, 0.05);
        assert_eq!(sc.straggler.slow_dist, SlowdownDist::Pareto { alpha: 1.2 });
        assert_eq!(sc.jobs[0].arrival, 10.5);
        assert_eq!(sc.jobs[0].encode_workers, 2);
    }

    #[test]
    fn rejects_malformed_scenarios() {
        let bad = [
            r#"{"seed": 1, "jobs": [{"scheme": "uncoded", "s_a": 2, "s_b": 2, "dims": 100}]}"#,
            r#"{"name": "x", "seed": 1, "jobs": []}"#,
            r#"{"name": "x", "seed": 1, "jobs": [{"scheme": "bogus", "s_a": 2, "s_b": 2, "dims": 100}]}"#,
            r#"{"name": "x", "seed": 1, "jobs": [{"scheme": "local-product:3x3", "s_a": 4, "s_b": 4, "dims": 100}]}"#,
            r#"{"name": "x", "seed": 1, "straggler": {"dist": "weird"}, "jobs": [{"scheme": "uncoded", "s_a": 2, "s_b": 2, "dims": 100}]}"#,
            r#"{"name": "x", "seed": 1, "jobs": [{"scheme": "uncoded", "s_a": 0, "s_b": 2, "dims": 100}]}"#,
            r#"{"name": "x", "seed": 1, "workers": 7.5, "jobs": [{"scheme": "uncoded", "s_a": 2, "s_b": 2, "dims": 100}]}"#,
            r#"{"name": "x", "seed": 1, "jobs": [{"scheme": "local-product:0x2", "s_a": 4, "s_b": 4, "dims": 100}]}"#,
            r#"{"name": "x", "seed": 1, "jobs": [{"scheme": "polynomial:-0.5", "s_a": 4, "s_b": 4, "dims": 100}]}"#,
            r#"{"name": "x", "seed": 1, "straggler": "pareto", "jobs": [{"scheme": "uncoded", "s_a": 2, "s_b": 2, "dims": 100}]}"#,
        ];
        for src in bad {
            assert!(
                parse_scenario(&parse(src).unwrap()).is_err(),
                "should reject: {src}"
            );
        }
    }

    #[test]
    fn rejects_unknown_keys_naming_the_culprit() {
        // Top-level typo.
        let err = parse_scenario(
            &parse(
                r#"{"name": "x", "seed": 1, "wrokers": 5,
                    "jobs": [{"scheme": "uncoded", "s_a": 2, "s_b": 2, "dims": 100}]}"#,
            )
            .unwrap(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("unknown scenario key 'wrokers'"), "{err}");

        // Straggler typo.
        let err = parse_scenario(
            &parse(
                r#"{"name": "x", "seed": 1, "straggler": {"slowmu": 1.0},
                    "jobs": [{"scheme": "uncoded", "s_a": 2, "s_b": 2, "dims": 100}]}"#,
            )
            .unwrap(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("unknown straggler key 'slowmu'"), "{err}");

        // Job typo.
        let err = parse_scenario(
            &parse(
                r#"{"name": "x", "seed": 1,
                    "jobs": [{"scheme": "uncoded", "s_a": 2, "s_b": 2, "dims": 100, "decode_worker": 3}]}"#,
            )
            .unwrap(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("unknown job key 'decode_worker'"), "{err}");
    }

    #[test]
    fn single_job_runs_and_is_deterministic() {
        let sc = scenario_from(
            r#"{
                "name": "one",
                "seed": 17,
                "jobs": [
                    {"scheme": "local-product:5x5", "s_a": 10, "s_b": 10,
                     "dims": [20000, 20000, 20000], "decode_workers": 5}
                ]
            }"#,
        );
        let a = run_scenario(&sc).unwrap();
        let b = run_scenario(&sc).unwrap();
        assert_eq!(a.to_string_pretty(), b.to_string_pretty());
        let runs = a.get("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 1);
        let jobs = runs[0].get("jobs").unwrap().as_arr().unwrap();
        let job = &jobs[0];
        assert_eq!(job.get("scheme").unwrap().as_str(), Some("local-product"));
        // 12×12 coded grid.
        assert_eq!(
            job.get("comp").unwrap().get("tasks").unwrap().as_usize(),
            Some(144)
        );
        assert!(job.get("t_total").unwrap().as_f64().unwrap() > 0.0);
        assert!(job.get("finish").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn all_schemes_complete_on_shared_bounded_pool() {
        let sc = scenario_from(
            r#"{
                "name": "contention",
                "seed": 23,
                "workers": 12,
                "jobs": [
                    {"scheme": "uncoded", "s_a": 4, "s_b": 4, "dims": 8000},
                    {"scheme": "speculative:0.75", "s_a": 4, "s_b": 4, "dims": 8000, "arrival": 50},
                    {"scheme": "local-product:2x2", "s_a": 4, "s_b": 4, "dims": 8000, "arrival": 100},
                    {"scheme": "product:1x1", "s_a": 4, "s_b": 4, "dims": 8000, "arrival": 150},
                    {"scheme": "polynomial:0.25", "s_a": 2, "s_b": 2, "dims": 8000, "arrival": 200}
                ]
            }"#,
        );
        let out = run_scenario(&sc).unwrap();
        let runs = out.get("runs").unwrap().as_arr().unwrap();
        let jobs = runs[0].get("jobs").unwrap().as_arr().unwrap();
        assert_eq!(jobs.len(), 5);
        for job in jobs {
            let arrival = job.get("arrival").unwrap().as_f64().unwrap();
            let finish = job.get("finish").unwrap().as_f64().unwrap();
            assert!(finish > arrival, "{:?}", job.get("scheme"));
            assert!(job.get("t_total").unwrap().as_f64().unwrap() > 0.0);
        }
        // Polynomial at K=4 is numerically feasible.
        assert_eq!(jobs[4].get("numerics_ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn pool_sweep_produces_one_run_per_width() {
        let sc = scenario_from(
            r#"{
                "name": "sweep",
                "seed": 29,
                "workers": [0, 100, 8],
                "jobs": [
                    {"scheme": "uncoded", "s_a": 4, "s_b": 4, "dims": 8000}
                ]
            }"#,
        );
        let out = run_scenario(&sc).unwrap();
        let runs = out.get("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 3);
        let total = |run: &Json| -> f64 {
            run.get("jobs").unwrap().as_arr().unwrap()[0]
                .get("t_total")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        // Wait-all with a fixed duration set: a pool at least as wide as
        // the fan-out matches unbounded bit for bit, and a tight pool can
        // only delay completions (same durations, queued starts).
        assert_eq!(total(&runs[0]), total(&runs[1]));
        assert!(total(&runs[2]) >= total(&runs[0]) - 1e-9);
    }
}
